//! Quickstart: the smallest end-to-end FedCompress run.
//!
//! Loads the AOT artifacts, builds a tiny synthetic federated
//! environment, trains a few rounds with the full pipeline (client-side
//! weight clustering, snapped uploads, server-side distillation,
//! dynamic cluster count) and prints the communication ledger.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use fedcompress::compression::accounting::Direction;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::run_federated;
use fedcompress::runtime::Engine;
use fedcompress::util::logging;

fn main() -> Result<()> {
    logging::init();
    let engine = Engine::load_default()?;

    let mut cfg = FedConfig::quick("cifar10");
    cfg.rounds = 6;
    cfg.clients = 4;
    cfg.validate()?;

    println!("== FedCompress quickstart: {} ==", cfg.dataset);
    let result = run_federated(&engine, &cfg, "fedcompress")?;

    println!("\nround  acc     E-score  C   up(B)    down(B)");
    for r in &result.rounds {
        println!(
            "{:>4}   {:.4}  {:>6.2}  {:>2}  {:>8}  {:>8}",
            r.round, r.accuracy, r.score, r.clusters, r.up_bytes, r.down_bytes
        );
    }
    println!(
        "\nfinal accuracy     : {:.4}\nmodel compression  : {:.2}x ({} B -> {} B)\nbytes upstream     : {}\nbytes downstream   : {}\ntotal communication: {} B",
        result.final_accuracy,
        result.mcr(),
        result.dense_model_bytes,
        result.final_model_bytes,
        result.ledger.bytes_in(Direction::Up),
        result.ledger.bytes_in(Direction::Down),
        result.total_bytes(),
    );
    Ok(())
}
