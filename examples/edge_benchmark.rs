//! Edge-deployment example (Table 2 scenario): report per-device
//! latency/speedup from the edge roofline model for both architectures,
//! plus actually-measured PJRT inference latency on this host as a
//! sanity anchor, plus the uint8 quantization error on real weights.
//!
//!     cargo run --release --example edge_benchmark

use anyhow::Result;
use std::time::Instant;

use fedcompress::edge::quantize;
use fedcompress::edge::{inference_latency, Precision, WeightFormat, EDGE_DEVICES};
use fedcompress::runtime::literals::{literal_to_f32, Arg};
use fedcompress::runtime::Engine;
use fedcompress::util::logging;
use fedcompress::util::rng::Rng;

fn main() -> Result<()> {
    logging::init();
    let engine = Engine::load_default()?;

    for dataset in ["cifar10", "speechcommands"] {
        let spec = engine.manifest.dataset(dataset)?.spec.clone();
        let model = if spec.domain == "vision" {
            "ResNetLite"
        } else {
            "MobileNetLite"
        };
        println!("\n== {model} ({dataset}) — {} params ==", spec.param_count);

        // measured on-host inference (dense)
        let theta = engine.init_theta(dataset)?;
        let mut rng = Rng::new(7);
        let (c, h, w) = spec.input_shape;
        let batch = engine.manifest.eval_batch;
        let xs: Vec<f32> = (0..batch * c * h * w).map(|_| rng.normal()).collect();
        let _ = engine.run(dataset, "embed", &[Arg::F32(&theta), Arg::F32(&xs)])?;
        let t0 = Instant::now();
        let iters = 20;
        for _ in 0..iters {
            let out = engine.run(dataset, "embed", &[Arg::F32(&theta), Arg::F32(&xs)])?;
            let _ = literal_to_f32(&out[0])?;
        }
        let host_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
        println!("measured host PJRT inference (batch {batch}): {host_us:.0} us/batch");

        // int8 quantization error on the real weights
        let scale = quantize::scale_for(&theta);
        let q = quantize::quantize(&theta, scale);
        let dq = quantize::dequantize(&q, scale);
        let rms: f64 = (theta
            .iter()
            .zip(&dq)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / theta.len() as f64)
            .sqrt();
        println!("uint8 quantization RMS error on weights: {rms:.5}");

        // modeled edge latencies for the *deployment-scale* counterpart
        // (ResNet-20 / MobileNet — the speedup mechanism only engages
        // once weights outgrow the devices' caches; the lite testbed
        // models correctly show ~1.0x)
        let paper_spec = if spec.domain == "vision" {
            fedcompress::edge::paper_models::resnet20()
        } else {
            fedcompress::edge::paper_models::mobilenet()
        };
        println!(
            "deployment-scale model ({}, {} params):",
            paper_spec.name, paper_spec.param_count
        );
        println!(
            "{:<12} {:>12} {:>15} {:>10} {:>10}",
            "device", "dense f32", "clustered f32", "f32 spd", "u8 spd"
        );
        for d in &EDGE_DEVICES {
            let dense = inference_latency(&paper_spec, d, Precision::F32, WeightFormat::Dense);
            let clustered = inference_latency(
                &paper_spec,
                d,
                Precision::F32,
                WeightFormat::Clustered { c: 16 },
            );
            let dense8 = inference_latency(&paper_spec, d, Precision::U8, WeightFormat::Dense);
            let clustered8 = inference_latency(
                &paper_spec,
                d,
                Precision::U8,
                WeightFormat::Clustered { c: 16 },
            );
            println!(
                "{:<12} {:>10.1}us {:>13.1}us {:>9.3}x {:>9.3}x",
                d.name,
                dense,
                clustered,
                dense / clustered,
                dense8 / clustered8
            );
        }
    }
    Ok(())
}
