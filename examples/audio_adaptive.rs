//! Audio-domain example: keyword-spotting analogue (MobileNetLite on
//! synthetic spectrograms) with the *adaptive cluster controller* under
//! the microscope — logs the representation-quality score and every C
//! growth event; optionally writes the Figure-2-style CSV.
//!
//!     cargo run --release --example audio_adaptive [out.csv]

use anyhow::Result;

use fedcompress::config::FedConfig;
use fedcompress::coordinator::run_federated;
use fedcompress::exp::figure2;
use fedcompress::runtime::Engine;
use fedcompress::util::logging;
use fedcompress::util::stats::pearson;

fn main() -> Result<()> {
    logging::init();
    let out = std::env::args().nth(1);

    let engine = Engine::load_default()?;
    let mut cfg = FedConfig::quick("speechcommands");
    cfg.rounds = 10;
    cfg.validate()?;

    println!("== audio_adaptive: synthetic SpeechCommands, dynamic C ==");
    let result = run_federated(&engine, &cfg, "fedcompress")?;

    let mut last_c = 0usize;
    println!("\nround  score E   val acc   C");
    for r in &result.rounds {
        let grew = if r.clusters > last_c && last_c != 0 {
            "  <- controller grew C"
        } else {
            ""
        };
        println!(
            "{:>5}  {:>7.3}  {:>7.4}  {:>2}{}",
            r.round, r.score, r.accuracy, r.clusters, grew
        );
        last_c = r.clusters;
    }

    let scores: Vec<f64> = result.rounds.iter().map(|r| r.score).collect();
    let accs: Vec<f64> = result.rounds.iter().map(|r| r.accuracy).collect();
    let r = pearson(&scores, &accs);
    println!("\nscore <-> accuracy Pearson r = {r:.3}");

    if let Some(path) = out {
        let series = figure2::Figure2Series {
            dataset: cfg.dataset.clone(),
            rounds: (0..result.rounds.len()).collect(),
            score: scores,
            accuracy: accs,
            correlation: r,
        };
        figure2::write_csv(&series, std::path::Path::new(&path))?;
        println!("wrote {path}");
    }
    Ok(())
}
