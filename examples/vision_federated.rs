//! End-to-end driver (DESIGN.md E5): full federated training on the
//! synthetic CIFAR-10 analogue, FedAvg vs FedCompress side by side on
//! the *same* data environment, logging the loss/accuracy curve each
//! round and the final communication/compression report.
//!
//! This is the repository's proof that all layers compose: synthetic
//! data -> rust coordinator -> PJRT-executed JAX/Pallas train steps ->
//! aggregation -> server-side distillation -> codecs -> metrics.
//!
//!     cargo run --release --example vision_federated [rounds]

use anyhow::Result;

use fedcompress::compression::accounting::ccr;
use fedcompress::config::FedConfig;
use fedcompress::coordinator::server::{build_data, run_federated_with_data};
use fedcompress::runtime::Engine;
use fedcompress::util::logging;

fn main() -> Result<()> {
    logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("rounds must be an integer"))
        .unwrap_or(12);

    let engine = Engine::load_default()?;
    let mut cfg = FedConfig::quick("cifar10");
    cfg.rounds = rounds;
    cfg.clients = 8;
    cfg.train_size = 1280;
    // compression needs enough local steps per round that CE drift can
    // cross centroid boundaries between snaps (EXPERIMENTS.md §Notes)
    cfg.local_epochs = 10;
    cfg.beta_warmup_epochs = 5;
    cfg.warmup_rounds = 3;
    cfg.validate()?;

    println!(
        "== vision_federated: synthetic CIFAR-10, {} rounds, {} clients ==",
        cfg.rounds, cfg.clients
    );
    let data = build_data(&engine, &cfg)?;

    let fedavg = run_federated_with_data(&engine, &cfg, "fedavg", &data)?;
    let fedcmp = run_federated_with_data(&engine, &cfg, "fedcompress", &data)?;

    println!("\nround | fedavg acc / loss | fedcompress acc / loss | C | round bytes (fc)");
    for (a, b) in fedavg.rounds.iter().zip(&fedcmp.rounds) {
        println!(
            "{:>5} |  {:.4} / {:>6.3}  |   {:.4} / {:>6.3}      | {:>2} | {:>9}",
            a.round,
            a.accuracy,
            a.test_loss,
            b.accuracy,
            b.test_loss,
            b.clusters,
            b.up_bytes + b.down_bytes,
        );
    }

    println!(
        "\nfinal: fedavg={:.4}  fedcompress={:.4}  (delta {:+.2} pp)",
        fedavg.final_accuracy,
        fedcmp.final_accuracy,
        (fedcmp.final_accuracy - fedavg.final_accuracy) * 100.0
    );
    println!(
        "communication: fedavg={} B  fedcompress={} B  CCR={:.2}x",
        fedavg.total_bytes(),
        fedcmp.total_bytes(),
        ccr(&fedavg.ledger, &fedcmp.ledger)
    );
    println!(
        "model: MCR={:.2}x ({} B on the wire)",
        fedcmp.mcr(),
        fedcmp.final_model_bytes
    );
    Ok(())
}
