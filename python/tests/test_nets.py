"""Network-level structural tests: layer specs, shapes, gradient flow."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.nets import audio, layers as L, vision


def test_conv_spec_param_shapes():
    s = L.conv_spec("c", 8, 16, 3, stride=2)
    assert s["shapes"]["w"] == (16, 8, 3, 3)
    assert s["shapes"]["b"] == (16,)
    assert s["fan_in"] == 72


def test_depthwise_conv_spec():
    s = L.conv_spec("dw", 16, 16, 3, groups=16)
    assert s["shapes"]["w"] == (16, 1, 3, 3)
    assert s["fan_in"] == 9


def test_depthwise_conv_is_channelwise():
    # a depthwise conv must not mix channels: zeroing one input channel
    # zeroes exactly the corresponding output channel
    s = L.conv_spec("dw", 4, 4, 3, groups=4)
    key = jax.random.PRNGKey(0)
    p = L.init_param(s, key)
    x = jnp.ones((1, 4, 8, 8))
    x = x.at[:, 2].set(0.0)
    y = L.apply_conv(s, {"w": p["w"], "b": jnp.zeros(4)}, x)
    assert float(jnp.abs(y[:, 2]).max()) == 0.0
    assert float(jnp.abs(y[:, 0]).max()) > 0.0


def test_strided_conv_halves_spatial():
    s = L.conv_spec("c", 3, 8, 3, stride=2)
    p = L.init_param(s, jax.random.PRNGKey(1))
    y = L.apply_conv(s, p, jnp.ones((2, 3, 16, 16)))
    assert y.shape == (2, 8, 8, 8)


@pytest.mark.parametrize("name", ["cifar10", "speechcommands"])
def test_forward_shapes(name):
    cfg = next(c for c in model.DATASETS if c.name == name)
    specs, forward = model.net_for(cfg)
    layout = model.ParamLayout(specs)
    params = layout.unflatten(layout.init_flat(0))
    x = jnp.ones((4,) + cfg.input_shape)
    logits, emb = forward(specs, params, x)
    assert logits.shape == (4, cfg.num_classes)
    assert emb.shape == (4, cfg.emb_dim)


def test_gradients_flow_to_every_parameter():
    cfg = next(c for c in model.DATASETS if c.name == "cifar10")
    specs, forward = model.net_for(cfg)
    layout = model.ParamLayout(specs)
    flat = layout.init_flat(2)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(8,) + cfg.input_shape), jnp.float32
    )
    y = jnp.asarray(np.arange(8) % cfg.num_classes, jnp.int32)

    def loss(f):
        logits, _ = forward(specs, layout.unflatten(f), x)
        return model.cross_entropy(logits, y)

    g = jax.grad(loss)(flat)
    # every layout entry must receive some gradient signal
    for i, field, shape, off, size in layout.entries:
        seg = np.asarray(g[off : off + size])
        assert np.any(seg != 0.0), f"dead gradient at {specs[i]['name']}.{field}"


def test_residual_skip_changes_output():
    # zeroing residual-branch weights must still produce signal via skip
    cfg = next(c for c in model.DATASETS if c.name == "cifar10")
    specs, forward = model.net_for(cfg)
    layout = model.ParamLayout(specs)
    flat = layout.init_flat(3)
    params = layout.unflatten(flat)
    x = jnp.ones((2,) + cfg.input_shape)
    base, _ = forward(specs, params, x)
    # zero the s1 conv weights (keep skips): output must change but stay finite
    z = dict(params[1])  # s1.conv1
    z["w"] = jnp.zeros_like(z["w"])
    params2 = list(params)
    params2[1] = z
    out, _ = forward(specs, params2, x)
    assert np.all(np.isfinite(np.asarray(out)))
    assert not np.allclose(np.asarray(base), np.asarray(out))


def test_kld_zero_for_identical_logits():
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(8, 10)), jnp.float32)
    kl = model.kld(logits, logits, jnp.float32(2.0))
    assert abs(float(kl)) < 1e-6


def test_kld_positive_and_temperature_scaled():
    rng = np.random.default_rng(2)
    t = jnp.asarray(rng.normal(size=(8, 10)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(8, 10)), jnp.float32)
    kl1 = float(model.kld(t, s, jnp.float32(1.0)))
    assert kl1 > 0
    # higher temperature softens distributions -> raw KL shrinks, but the
    # lambda^2 factor keeps gradients comparable; just check finiteness
    kl4 = float(model.kld(t, s, jnp.float32(4.0)))
    assert np.isfinite(kl4) and kl4 > 0


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]], jnp.float32)
    y = jnp.asarray([0, 1], jnp.int32)
    ce = float(model.cross_entropy(logits, y))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    p1 = np.exp(1.0) / (np.exp(1.0) + 2)
    want = -(np.log(p0) + np.log(p1)) / 2
    assert abs(ce - want) < 1e-6


def test_vision_and_audio_use_distinct_architectures():
    v = vision.specs(10)
    a = audio.specs(12)
    v_kinds = [s.get("groups", 1) for s in v]
    a_kinds = [s.get("groups", 1) for s in a]
    assert all(g == 1 for g in v_kinds)  # plain convs
    assert any(g > 1 for g in a_kinds)  # depthwise present
