"""Manifest / artifact integrity: everything the rust runtime will trust.

Skipped when artifacts/ has not been built yet (run `make artifacts`).
"""

import json
import os

import numpy as np
import pytest

from compile import model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_datasets(manifest):
    assert set(manifest["datasets"]) == {c.name for c in model.DATASETS}
    assert manifest["c_max"] == model.C_MAX
    assert manifest["batch"] == model.BATCH


def test_every_artifact_file_exists_and_is_hlo(manifest):
    for name, ds in manifest["datasets"].items():
        for entry, fname in ds["artifacts"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), path
            head = open(path).read(200)
            assert "HloModule" in head, f"{path} is not HLO text"


def test_param_counts_match_layouts(manifest):
    for cfg in model.DATASETS:
        specs, _ = model.net_for(cfg)
        layout = model.ParamLayout(specs)
        ds = manifest["datasets"][cfg.name]
        assert ds["param_count"] == layout.total
        assert sum(e["size"] for e in ds["layers"]) == layout.total


def test_init_theta_binary_matches(manifest):
    for cfg in model.DATASETS:
        ds = manifest["datasets"][cfg.name]
        path = os.path.join(ART, ds["init_theta"])
        raw = np.fromfile(path, dtype=np.float32)
        assert raw.shape[0] == ds["param_count"]
        specs, _ = model.net_for(cfg)
        layout = model.ParamLayout(specs)
        np.testing.assert_array_equal(raw, np.asarray(layout.init_flat(0)))


def test_goldens_are_self_consistent(manifest):
    """Re-execute each entry on its stored golden inputs; outputs match."""
    for cfg in model.DATASETS[:2]:  # two configs keep the suite fast
        ds = manifest["datasets"][cfg.name]
        gdir = os.path.join(ART, ds["golden_dir"])
        with open(os.path.join(gdir, "goldens.json")) as f:
            goldens = json.load(f)
        ep = model.build_entry_points(cfg, tau=manifest["tau"], block=manifest["block"])
        import jax
        import jax.numpy as jnp

        for entry, record in goldens.items():
            fn = jax.jit(ep["entries"][entry][0])
            ins = []
            for spec in record["inputs"]:
                dt = np.float32 if spec["dtype"] == "f32" else np.int32
                a = np.fromfile(os.path.join(gdir, spec["file"]), dtype=dt)
                ins.append(jnp.asarray(a.reshape(spec["shape"])))
            outs = fn(*ins)
            if not isinstance(outs, tuple):
                outs = (outs,)
            for spec, got in zip(record["outputs"], outs):
                dt = np.float32 if spec["dtype"] == "f32" else np.int32
                want = np.fromfile(os.path.join(gdir, spec["file"]), dtype=dt)
                np.testing.assert_allclose(
                    np.asarray(got).ravel(), want, rtol=1e-5, atol=1e-6
                )
