"""Pallas snap/assign kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import kmeans as K
from compile.kernels import ref

C_MAX = 32


def make_case(seed, p, c_active):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(size=p), jnp.float32)
    mu = jnp.asarray(np.sort(rng.normal(size=C_MAX)), jnp.float32)
    mask = jnp.asarray((np.arange(C_MAX) < c_active).astype(np.float32))
    return theta, mu, mask


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    p=st.integers(1, 5000),
    c_active=st.integers(1, C_MAX),
    block=st.sampled_from([256, 1024, 2048]),
)
def test_snap_matches_ref(seed, p, c_active, block):
    theta, mu, mask = make_case(seed, p, c_active)
    snapped, idx, sums, counts = K.snap(theta, mu, mask, block)
    want_snapped, want_idx = ref.snap(theta, mu, mask)
    want_sums, want_counts = ref.cluster_stats(theta, mu, mask)
    np.testing.assert_array_equal(idx, want_idx)
    np.testing.assert_allclose(snapped, want_snapped)
    np.testing.assert_allclose(sums, want_sums, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(counts, want_counts)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), c_active=st.integers(1, C_MAX))
def test_assignment_is_optimal(seed, c_active):
    """Property: no other active centroid is closer than the assigned one."""
    theta, mu, mask = make_case(seed, 800, c_active)
    _, idx, _, _ = K.snap(theta, mu, mask, 512)
    t = np.asarray(theta)
    m = np.asarray(mu)
    act = np.asarray(mask) > 0
    assigned_d = (t - m[np.asarray(idx)]) ** 2
    for j in np.nonzero(act)[0]:
        assert np.all(assigned_d <= (t - m[j]) ** 2 + 1e-6)


def test_counts_sum_to_p():
    theta, mu, mask = make_case(3, 2049, 10)
    _, _, _, counts = K.snap(theta, mu, mask, 2048)
    assert float(jnp.sum(counts)) == 2049.0


def test_inactive_centroids_never_assigned():
    theta, mu, mask = make_case(4, 1000, 5)
    _, idx, _, counts = K.snap(theta, mu, mask, 512)
    assert int(np.max(np.asarray(idx))) < 5
    np.testing.assert_allclose(np.asarray(counts)[5:], 0.0)


def test_lloyd_step_reduces_inertia():
    """sums/counts implement the Lloyd update; inertia must not increase."""
    theta, mu, mask = make_case(8, 4000, 16)
    for _ in range(3):
        snapped, _, sums, counts = K.snap(theta, mu, mask, 1024)
        inertia0 = float(jnp.sum((theta - snapped) ** 2))
        new_mu = np.asarray(mu).copy()
        c = np.asarray(counts)
        s = np.asarray(sums)
        nz = c > 0
        new_mu[nz] = s[nz] / c[nz]
        mu = jnp.asarray(new_mu)
        snapped2, _, _, _ = K.snap(theta, mu, mask, 1024)
        inertia1 = float(jnp.sum((theta - snapped2) ** 2))
        assert inertia1 <= inertia0 + 1e-5
