"""Pallas wc_loss kernel vs the pure-jnp oracle (ref.py).

This is the core L1 correctness signal: hypothesis sweeps parameter
counts, cluster counts, active masks, temperatures and block sizes, and
asserts forward + backward allclose against the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import wc_loss as K

C_MAX = 32


def make_case(seed, p, c_active, spread=1.0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(scale=spread, size=p), jnp.float32)
    mu = jnp.asarray(rng.normal(scale=spread, size=C_MAX), jnp.float32)
    mask = jnp.asarray(
        (np.arange(C_MAX) < c_active).astype(np.float32)
    )
    return theta, mu, mask


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    p=st.integers(3, 6000),
    c_active=st.integers(1, C_MAX),
    tau=st.sampled_from([0.01, 0.05, 0.3, 1.0]),
    block=st.sampled_from([256, 1024, 2048]),
)
def test_forward_matches_ref(seed, p, c_active, tau, block):
    theta, mu, mask = make_case(seed, p, c_active)
    got = K.wc_loss(theta, mu, mask, jnp.float32(tau), block)
    want = ref.wc_loss(theta, mu, mask, tau)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    p=st.integers(3, 4000),
    c_active=st.integers(1, C_MAX),
    tau=st.sampled_from([0.05, 0.3]),
    block=st.sampled_from([512, 2048]),
)
def test_backward_matches_closed_form(seed, p, c_active, tau, block):
    theta, mu, mask = make_case(seed, p, c_active)
    dtheta, dmu = jax.grad(
        lambda t, m: K.wc_loss(t, m, mask, jnp.float32(tau), block),
        argnums=(0, 1),
    )(theta, mu)
    want_dt, want_dm = ref.wc_loss_grads(theta, mu, mask, tau)
    np.testing.assert_allclose(dtheta, want_dt, rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(dmu, want_dm, rtol=1e-4, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    p=st.integers(8, 1500),
    c_active=st.integers(2, C_MAX),
)
def test_backward_matches_autodiff_of_ref(seed, p, c_active):
    """The closed-form Pallas backward == jax autodiff of the oracle."""
    tau = 0.1
    theta, mu, mask = make_case(seed, p, c_active)
    got = jax.grad(
        lambda t, m: K.wc_loss(t, m, mask, jnp.float32(tau), 512),
        argnums=(0, 1),
    )(theta, mu)
    want = jax.grad(
        lambda t, m: ref.wc_loss(t, m, mask, tau), argnums=(0, 1)
    )(theta, mu)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-3, atol=1e-6)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-3, atol=1e-6)


def test_loss_is_nonnegative_and_small_at_centroids():
    # Weights sitting exactly on centroids: the *soft* loss keeps a small
    # residual from neighbour-centroid mass (e^{-d/tau} * d), so it is
    # near-zero but not exactly zero. At tau=0.001 with centroid spacing
    # 2/31 the residual is ~1e-4.
    mu = jnp.linspace(-1, 1, C_MAX)
    mask = jnp.ones(C_MAX)
    theta = jnp.tile(mu, 10)
    loss = K.wc_loss(theta, mu, mask, jnp.float32(0.001), 256)
    assert float(loss) >= 0.0
    assert float(loss) < 0.2  # 320 weights x ~1e-4 soft residual each


def test_inactive_centroids_get_zero_grad():
    theta, mu, mask = make_case(7, 1000, 8)
    _, dmu = jax.grad(
        lambda t, m: K.wc_loss(t, m, mask, jnp.float32(0.05), 512),
        argnums=(0, 1),
    )(theta, mu)
    np.testing.assert_allclose(np.asarray(dmu)[8:], 0.0, atol=1e-8)


def test_single_active_centroid_loss_is_sum_sq_dist():
    theta, mu, mask = make_case(3, 500, 1)
    loss = K.wc_loss(theta, mu, mask, jnp.float32(0.05), 256)
    want = jnp.sum((theta - mu[0]) ** 2)
    np.testing.assert_allclose(loss, want, rtol=1e-5)


def test_gradient_descent_on_kernel_clusters_weights():
    """Sanity: SGD on the kernel's own grads clusters the weights.

    The soft loss has an entropy-like floor, so we assert on the *hard*
    quantization error (what the wire codec sees), which must collapse.
    """
    theta, mu, mask = make_case(11, 2000, 16)
    tau = jnp.float32(0.05)
    loss_fn = lambda t, m: K.wc_loss(t, m, mask, tau, 1024)

    def hard_err(t, m):
        snapped, _ = ref.snap(t, m, mask)
        return float(jnp.mean((t - snapped) ** 2))

    e0 = hard_err(theta, mu)
    g = jax.jit(jax.grad(loss_fn, argnums=(0, 1)))
    for _ in range(50):
        dt, dm = g(theta, mu)
        # unnormalized loss: per-weight steps are O(2*lr*diff), so the
        # stable lr is small; dmu aggregates P terms and needs smaller yet
        theta = theta - 0.02 * dt
        mu = mu - 0.02 / theta.shape[0] * dm
    e1 = hard_err(theta, mu)
    assert e1 < 0.25 * e0, (e0, e1)


def test_block_size_invariance():
    theta, mu, mask = make_case(5, 3333, 12)
    vals = [
        float(K.wc_loss(theta, mu, mask, jnp.float32(0.05), b))
        for b in (128, 512, 2048, 4096)
    ]
    np.testing.assert_allclose(vals, vals[0], rtol=2e-6)


def test_padding_does_not_leak():
    """P far from a block multiple: tail lanes must not contribute."""
    theta, mu, mask = make_case(9, 2049, 8)
    got = K.wc_loss(theta, mu, mask, jnp.float32(0.05), 2048)
    want = ref.wc_loss(theta, mu, mask, 0.05)
    np.testing.assert_allclose(got, want, rtol=2e-5)
