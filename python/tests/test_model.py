"""L2 model-level tests: layouts, entry-point semantics, training sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module", params=["cifar10", "speechcommands"])
def built(request):
    cfg = next(c for c in model.DATASETS if c.name == request.param)
    return cfg, model.build_entry_points(cfg, tau=0.05, block=2048)


def make_inputs(cfg, layout, seed=0, batch=model.BATCH):
    rng = np.random.default_rng(seed)
    theta = layout.init_flat(seed)
    mu = jnp.linspace(-0.5, 0.5, model.C_MAX)
    mask = jnp.asarray((np.arange(model.C_MAX) < 16).astype(np.float32))
    x = jnp.asarray(
        rng.normal(size=(batch,) + cfg.input_shape), jnp.float32
    )
    y = jnp.asarray(rng.integers(0, cfg.num_classes, batch), jnp.int32)
    return theta, mu, mask, x, y


def test_layout_roundtrip(built):
    _, ep = built
    layout = ep["layout"]
    flat = layout.init_flat(3)
    params = layout.unflatten(flat)
    flat2 = layout.flatten(params)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))


def test_layout_describe_covers_everything(built):
    _, ep = built
    layout = ep["layout"]
    desc = layout.describe()
    assert sum(e["size"] for e in desc) == layout.total
    # offsets are contiguous and ordered
    off = 0
    for e in desc:
        assert e["offset"] == off
        off += e["size"]


def test_train_step_reduces_ce_over_steps(built):
    cfg, ep = built
    layout = ep["layout"]
    fn = jax.jit(ep["entries"]["train_step"][0])
    theta, mu, mask, x, y = make_inputs(cfg, layout)
    # lr/steps sized for the *hardest* case here: the audio net memorizing
    # unstructured noise inputs descends slowly; real learnability on
    # structured data is covered by the rust end-to-end tests.
    lr, beta = jnp.float32(0.3), jnp.float32(0.0)
    first_ce = None
    for i in range(60):
        theta, mu, loss, ce = fn(theta, mu, mask, x, y, lr, beta)
        if first_ce is None:
            first_ce = float(ce)
    assert float(ce) < 0.8 * first_ce, (first_ce, float(ce))


def test_train_step_with_beta_pulls_weights_to_centroids(built):
    cfg, ep = built
    layout = ep["layout"]
    fn = jax.jit(ep["entries"]["train_step"][0])
    snap_fn = jax.jit(ep["entries"]["snap"][0])
    theta, mu, mask, x, y = make_inputs(cfg, layout)

    def snap_err(th, m):
        snapped, _, _, _ = snap_fn(th, m, mask)
        return float(jnp.mean((th - snapped) ** 2))

    e0 = snap_err(theta, mu)
    lr, beta = jnp.float32(0.05), jnp.float32(4.0)
    for _ in range(40):
        theta, mu, _, _ = fn(theta, mu, mask, x, y, lr, beta)
    e1 = snap_err(theta, mu)
    assert e1 < 0.5 * e0, (e0, e1)


def test_distill_step_matches_teacher(built):
    cfg, ep = built
    layout = ep["layout"]
    fn = jax.jit(ep["entries"]["distill_step"][0])
    theta, mu, mask, x, _ = make_inputs(cfg, layout)
    teacher = theta
    rng = np.random.default_rng(1)
    student = theta + 0.05 * jnp.asarray(
        rng.normal(size=theta.shape), jnp.float32
    )
    lr, beta, temp = jnp.float32(0.05), jnp.float32(0.0), jnp.float32(2.0)
    first_kl = None
    for _ in range(60):
        student, mu, loss, kl = fn(student, teacher, mu, mask, x, lr, beta, temp)
        if first_kl is None:
            first_kl = float(kl)
    assert float(kl) < 0.25 * first_kl, (first_kl, float(kl))


def test_eval_step_counts(built):
    cfg, ep = built
    layout = ep["layout"]
    fn = jax.jit(ep["entries"]["eval_step"][0])
    theta, _, _, _, _ = make_inputs(cfg, layout)
    rng = np.random.default_rng(2)
    x = jnp.asarray(
        rng.normal(size=(model.EVAL_BATCH,) + cfg.input_shape), jnp.float32
    )
    y = jnp.asarray(rng.integers(0, cfg.num_classes, model.EVAL_BATCH), jnp.int32)
    correct, loss_sum = fn(theta, x, y)
    assert 0 <= float(correct) <= model.EVAL_BATCH
    assert float(loss_sum) > 0


def test_embed_shape_and_nonneg(built):
    cfg, ep = built
    layout = ep["layout"]
    fn = jax.jit(ep["entries"]["embed"][0])
    theta, _, _, _, _ = make_inputs(cfg, layout)
    rng = np.random.default_rng(2)
    x = jnp.asarray(
        rng.normal(size=(model.EVAL_BATCH,) + cfg.input_shape), jnp.float32
    )
    (emb,) = fn(theta, x)
    assert emb.shape == (model.EVAL_BATCH, cfg.emb_dim)
    assert float(jnp.min(emb)) >= 0.0  # post-ReLU


def test_snap_is_idempotent(built):
    cfg, ep = built
    layout = ep["layout"]
    fn = jax.jit(ep["entries"]["snap"][0])
    theta, mu, mask, _, _ = make_inputs(cfg, layout)
    s1, i1, _, _ = fn(theta, mu, mask)
    s2, i2, _, _ = fn(s1, mu, mask)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))


def test_param_counts_are_plausible():
    for cfg in model.DATASETS:
        specs, _ = model.net_for(cfg)
        layout = model.ParamLayout(specs)
        if cfg.domain == "vision":
            assert 15_000 < layout.total < 40_000
        else:
            assert 3_000 < layout.total < 15_000
