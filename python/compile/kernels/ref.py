"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: `python/tests/` sweeps shapes,
seeds and cluster counts with hypothesis and asserts the Pallas kernels
(interpret mode) match these to tight tolerances.

Conventions shared with the kernels:
  theta : f32[P]     flat parameter vector
  mu    : f32[C]     centroid table (C = C_max, statically sized)
  mask  : f32[C]     1.0 for active centroids, 0.0 for inactive
  tau   : f32        soft-assignment temperature (>0)

The weight-clustering loss is the paper's
    L_wc = sum_i sum_j u_ij * ||theta_i - mu_j||^2
with a soft assignment u_ij = softmax_j(-d_ij / tau) so that the loss is
differentiable in both theta and mu, normalized by P so that beta has a
scale-free meaning across model sizes.
"""

import jax.numpy as jnp

MASK_NEG = 1e9  # additive logit penalty for inactive centroids
HARD_BIG = 1e30  # distance penalty for inactive centroids (hard assign)


def pairwise_sq_dists(theta, mu):
    """d[i, j] = (theta_i - mu_j)^2 for flat weights."""
    diff = theta[:, None] - mu[None, :]
    return diff * diff


def soft_assign(theta, mu, mask, tau):
    """u[i, j] = masked softmax_j(-d_ij / tau)."""
    d = pairwise_sq_dists(theta, mu)
    logits = -d / tau - (1.0 - mask)[None, :] * MASK_NEG
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits)
    return e / jnp.sum(e, axis=1, keepdims=True)


def wc_loss(theta, mu, mask, tau):
    """Soft weight-clustering loss, summed over weights (paper-exact:
    L_wc = sum_i sum_j u_ij ||theta_i - mu_j||^2, unnormalized — the
    per-weight gradient must be O(1) regardless of model size for the
    clustering pull to engage at any P)."""
    d = pairwise_sq_dists(theta, mu)
    u = soft_assign(theta, mu, mask, tau)
    return jnp.sum(u * d)


def wc_loss_grads(theta, mu, mask, tau):
    """Closed-form gradients of `wc_loss` wrt (theta, mu).

    With s_i = sum_j u_ij d_ij (the per-weight soft loss) and
    g_ij = dL_i/dd_ij = u_ij * (1 - (d_ij - s_i)/tau):
        dtheta_i = 2 * sum_j g_ij (theta_i - mu_j)
        dmu_j    = -2 * sum_i g_ij (theta_i - mu_j)
    """
    d = pairwise_sq_dists(theta, mu)
    u = soft_assign(theta, mu, mask, tau)
    s = jnp.sum(u * d, axis=1, keepdims=True)
    g = u * (1.0 - (d - s) / tau)
    diff = theta[:, None] - mu[None, :]
    dtheta = 2.0 * jnp.sum(g * diff, axis=1)
    dmu = -2.0 * jnp.sum(g * diff, axis=0)
    return dtheta, dmu


def hard_assign(theta, mu, mask):
    """idx[i] = argmin over active centroids of d_ij."""
    d = pairwise_sq_dists(theta, mu) + (1.0 - mask)[None, :] * HARD_BIG
    return jnp.argmin(d, axis=1).astype(jnp.int32)


def snap(theta, mu, mask):
    """Quantize each weight to its nearest active centroid."""
    idx = hard_assign(theta, mu, mask)
    return mu[idx], idx


def cluster_stats(theta, mu, mask):
    """One Lloyd half-step: per-cluster sums and counts under hard assign."""
    idx = hard_assign(theta, mu, mask)
    one_hot = (idx[:, None] == jnp.arange(mu.shape[0])[None, :]).astype(
        jnp.float32
    )
    sums = jnp.sum(one_hot * theta[:, None], axis=0)
    counts = jnp.sum(one_hot, axis=0)
    return sums, counts
