"""Layer-1 Pallas kernels for the weight-clustering loss (paper Eq. 1/2).

The hot spot of FedCompress is the weight<->centroid interaction: every
local SGD step and every server distillation step evaluates

    L_wc(theta, mu, C) = sum_i sum_j u_ij * (theta_i - mu_j)^2,
    u_ij = softmax_j(-d_ij / tau)   (masked to the active C <= C_max)

over the *entire* flat parameter vector. Forward and backward are
written as separate Pallas kernels tied together with jax.custom_vjp
(interpret-mode pallas_call has no autodiff rule).

TPU mapping (DESIGN.md §Hardware-Adaptation): the parameter axis is
tiled into BLOCK-sized VMEM blocks (BlockSpec over axis 0); the full
centroid table (C_max <= 64 f32) rides along in every block. The d/u
tiles are BLOCK x C_max elementwise work for the VPU — deliberately not
MXU-shaped, since C_max is far below the 128x128 systolic tile.
Per-block VMEM working set at BLOCK=2048, C_max=32:
  weights 8 KiB + centroids 128 B + 3 tiles x 256 KiB ≈ 0.77 MiB,
inside a 1 MiB/core budget with double-buffering headroom at BLOCK=1024.

All artifacts are lowered with interpret=True: CPU PJRT cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the
rust runtime runs unmodified.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MASK_NEG = 1e9
DEFAULT_BLOCK = 2048


def _pad_to(x, multiple):
    p = x.shape[0]
    rem = (-p) % multiple
    if rem:
        x = jnp.pad(x, (0, rem))
    return x


def _valid_lane_mask(pid, block, p_valid):
    """1.0 for lanes holding real weights, 0.0 for tail padding."""
    lane = pid * block + jax.lax.iota(jnp.float32, block)
    return jnp.where(lane < p_valid, 1.0, 0.0)


# ---------------------------------------------------------------------------
# forward kernel: per-block soft-assignment loss, accumulated into a scalar
# ---------------------------------------------------------------------------


def _fwd_kernel(theta_ref, mu_ref, mask_ref, tau_ref, pvalid_ref, loss_ref):
    pid = pl.program_id(0)
    block = theta_ref.shape[0]

    theta = theta_ref[...]
    mu = mu_ref[...]
    mask = mask_ref[...]
    tau = tau_ref[0]
    p_valid = pvalid_ref[0]

    diff = theta[:, None] - mu[None, :]
    d = diff * diff
    logits = -d / tau - (1.0 - mask)[None, :] * MASK_NEG
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits)
    u = e / jnp.sum(e, axis=1, keepdims=True)

    per_weight = jnp.sum(u * d, axis=1)
    valid = _valid_lane_mask(pid, block, p_valid)
    partial = jnp.sum(per_weight * valid)

    @pl.when(pid == 0)
    def _init():
        loss_ref[0] = 0.0

    loss_ref[0] += partial


def _fwd_pallas(theta, mu, mask, tau, block):
    p = theta.shape[0]
    theta_p = _pad_to(theta, block)
    grid = theta_p.shape[0] // block
    tau_v = jnp.reshape(tau.astype(jnp.float32), (1,))
    pv = jnp.array([p], jnp.float32)
    loss = pl.pallas_call(
        _fwd_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(mu.shape, lambda i: (0,)),
            pl.BlockSpec(mask.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(theta_p, mu, mask, tau_v, pv)
    return loss[0]


# ---------------------------------------------------------------------------
# backward kernel: closed-form grads (see kernels/ref.py for the algebra)
# ---------------------------------------------------------------------------


def _bwd_kernel(
    theta_ref, mu_ref, mask_ref, tau_ref, pvalid_ref, dtheta_ref, dmu_ref
):
    pid = pl.program_id(0)
    block = theta_ref.shape[0]

    theta = theta_ref[...]
    mu = mu_ref[...]
    mask = mask_ref[...]
    tau = tau_ref[0]
    p_valid = pvalid_ref[0]

    diff = theta[:, None] - mu[None, :]
    d = diff * diff
    logits = -d / tau - (1.0 - mask)[None, :] * MASK_NEG
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits)
    u = e / jnp.sum(e, axis=1, keepdims=True)

    s = jnp.sum(u * d, axis=1, keepdims=True)
    g = u * (1.0 - (d - s) / tau)

    valid = _valid_lane_mask(pid, block, p_valid)
    gd = g * diff * valid[:, None]
    dtheta_ref[...] = 2.0 * jnp.sum(gd, axis=1)

    @pl.when(pid == 0)
    def _init():
        dmu_ref[...] = jnp.zeros_like(dmu_ref)

    dmu_ref[...] += -2.0 * jnp.sum(gd, axis=0)


def _bwd_pallas(theta, mu, mask, tau, block):
    p = theta.shape[0]
    theta_p = _pad_to(theta, block)
    grid = theta_p.shape[0] // block
    tau_v = jnp.reshape(tau.astype(jnp.float32), (1,))
    pv = jnp.array([p], jnp.float32)
    dtheta_p, dmu = pl.pallas_call(
        _bwd_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(mu.shape, lambda i: (0,)),
            pl.BlockSpec(mask.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(mu.shape, lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(theta_p.shape, jnp.float32),
            jax.ShapeDtypeStruct(mu.shape, jnp.float32),
        ],
        interpret=True,
    )(theta_p, mu, mask, tau_v, pv)
    return dtheta_p[:p], dmu


# ---------------------------------------------------------------------------
# public op: custom_vjp wiring
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def wc_loss(theta, mu, mask, tau, block=DEFAULT_BLOCK):
    """Soft weight-clustering loss over a flat parameter vector.

    Differentiable in `theta` and `mu` (closed-form Pallas backward);
    `mask` and `tau` are treated as constants of the optimization.
    """
    return _fwd_pallas(theta, mu, mask, jnp.asarray(tau), block)


def _wc_fwd(theta, mu, mask, tau, block):
    loss = _fwd_pallas(theta, mu, mask, jnp.asarray(tau), block)
    return loss, (theta, mu, mask, jnp.asarray(tau))


def _wc_bwd(block, res, ct):
    theta, mu, mask, tau = res
    dtheta, dmu = _bwd_pallas(theta, mu, mask, tau, block)
    return ct * dtheta, ct * dmu, jnp.zeros_like(mask), jnp.zeros_like(tau)


wc_loss.defvjp(_wc_fwd, _wc_bwd)
