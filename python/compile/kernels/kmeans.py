"""Layer-1 Pallas kernel: hard nearest-centroid assignment ("snap").

Used by the `snap` AOT entry point: quantize the flat parameter vector
to its nearest active centroid and emit the index stream the rust codec
bit-packs for the wire. Also emits per-cluster sums/counts so a Lloyd
refinement step can run without re-touching the weights (exercised by
tests and the server-side centroid refresh).

Same blocking story as wc_loss.py: parameter axis tiled to VMEM-sized
blocks, the centroid table broadcast to every block, accumulator
outputs revisited across the grid.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HARD_BIG = 1e30
DEFAULT_BLOCK = 2048


def _pad_to(x, multiple):
    rem = (-x.shape[0]) % multiple
    if rem:
        x = jnp.pad(x, (0, rem))
    return x


def _assign_kernel(
    theta_ref, mu_ref, mask_ref, pvalid_ref,
    snapped_ref, idx_ref, sums_ref, counts_ref,
):
    pid = pl.program_id(0)
    block = theta_ref.shape[0]

    theta = theta_ref[...]
    mu = mu_ref[...]
    mask = mask_ref[...]
    p_valid = pvalid_ref[0]

    diff = theta[:, None] - mu[None, :]
    d = diff * diff + (1.0 - mask)[None, :] * HARD_BIG
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    one_hot = (idx[:, None] == jax.lax.iota(jnp.int32, mu.shape[0])[None, :])
    one_hot = one_hot.astype(jnp.float32)

    snapped_ref[...] = jnp.sum(one_hot * mu[None, :], axis=1)
    idx_ref[...] = idx

    lane = pid * block + jax.lax.iota(jnp.float32, block)
    valid = jnp.where(lane < p_valid, 1.0, 0.0)

    @pl.when(pid == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    sums_ref[...] += jnp.sum(one_hot * (theta * valid)[:, None], axis=0)
    counts_ref[...] += jnp.sum(one_hot * valid[:, None], axis=0)


def snap(theta, mu, mask, block=DEFAULT_BLOCK):
    """(theta, mu, mask) -> (snapped, idx, sums, counts).

    snapped[i] = mu[argmin_j d_ij] over active centroids; sums/counts
    are the Lloyd statistics of the hard assignment (padding excluded).
    """
    p = theta.shape[0]
    theta_p = _pad_to(theta, block)
    grid = theta_p.shape[0] // block
    pv = jnp.array([p], jnp.float32)
    snapped_p, idx_p, sums, counts = pl.pallas_call(
        _assign_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(mu.shape, lambda i: (0,)),
            pl.BlockSpec(mask.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(mu.shape, lambda i: (0,)),
            pl.BlockSpec(mu.shape, lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(theta_p.shape, jnp.float32),
            jax.ShapeDtypeStruct(theta_p.shape, jnp.int32),
            jax.ShapeDtypeStruct(mu.shape, jnp.float32),
            jax.ShapeDtypeStruct(mu.shape, jnp.float32),
        ],
        interpret=True,
    )(theta_p, mu, mask, pv)
    return snapped_p[:p], idx_p[:p], sums, counts
