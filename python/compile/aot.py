"""AOT pipeline: lower every entry point of every dataset config to HLO
*text* and emit the manifest + init parameters + golden vectors the rust
runtime consumes. Run once by `make artifacts`; python never runs on the
training path afterwards.

Interchange is HLO text, NOT `.serialize()`: jax >= 0.5 emits protos
with 64-bit instruction ids that the image's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under --out (default ../artifacts):
  manifest.json                     — shapes, layouts, artifact index
  <ds>.<entry>.hlo.txt              — 5 datasets x 5 entry points
  init/<ds>.theta.bin               — seeded He-init flat params (f32 LE)
  golden/<ds>/<entry>.{in,out}N.bin — golden vectors for runtime tests
  golden/<ds>/goldens.json          — file index + scalar metadata
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

TAU = 0.05
BLOCK = 2048
GOLDEN_SEED = 1234


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def np_dtype_name(a):
    return {"float32": "f32", "int32": "i32"}[str(a.dtype)]


def write_bin(path, arr):
    np.asarray(arr).tofile(path)


def golden_inputs(entry, sig_args, cfg, layout, rng):
    """Deterministic concrete inputs matching an entry's signature."""
    theta = np.asarray(layout.init_flat(GOLDEN_SEED))
    mu = np.linspace(-0.5, 0.5, model.C_MAX, dtype=np.float32)
    mask = np.zeros(model.C_MAX, np.float32)
    mask[:16] = 1.0

    out = []
    for spec in sig_args:
        shape, dtype = spec.shape, spec.dtype
        if dtype == jnp.int32:
            out.append(
                rng.integers(0, cfg.num_classes, size=shape).astype(np.int32)
            )
        elif shape == (layout.total,):
            # theta-like; perturb per occurrence so teacher != student
            out.append(theta + 0.01 * len(out) * np.ones_like(theta))
        elif shape == (model.C_MAX,):
            # mu arrives before mask in every entry signature
            seen_cmax = sum(
                1 for a in out
                if np.shape(a) == (model.C_MAX,) and np.asarray(a).dtype == np.float32
            )
            out.append(mu if seen_cmax == 0 else mask)
        elif shape == ():
            out.append(np.float32(0.05))
        else:
            out.append(rng.normal(size=shape).astype(np.float32))
    return out


def build_dataset(cfg, out_dir):
    ep = model.build_entry_points(cfg, tau=TAU, block=BLOCK)
    layout = ep["layout"]
    rng = np.random.default_rng(GOLDEN_SEED)

    init_dir = os.path.join(out_dir, "init")
    gold_dir = os.path.join(out_dir, "golden", cfg.name)
    os.makedirs(init_dir, exist_ok=True)
    os.makedirs(gold_dir, exist_ok=True)

    write_bin(
        os.path.join(init_dir, f"{cfg.name}.theta.bin"), layout.init_flat(0)
    )

    artifacts = {}
    signatures = {}
    goldens = {}
    for name, (fn, args) in ep["entries"].items():
        hlo = to_hlo_text(fn, args)
        fname = f"{cfg.name}.{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        artifacts[name] = fname

        # golden vectors: run the jitted fn on deterministic inputs
        concrete = golden_inputs(name, args, cfg, layout, rng)
        results = jax.jit(fn)(*[jnp.asarray(a) for a in concrete])
        if not isinstance(results, tuple):
            results = (results,)

        in_files, out_files = [], []
        for i, a in enumerate(concrete):
            f = f"{name}.in{i}.bin"
            write_bin(os.path.join(gold_dir, f), a)
            in_files.append(
                {"file": f, "shape": list(np.shape(a)), "dtype": np_dtype_name(np.asarray(a))}
            )
        for i, a in enumerate(results):
            a = np.asarray(a)
            f = f"{name}.out{i}.bin"
            write_bin(os.path.join(gold_dir, f), a)
            out_files.append(
                {"file": f, "shape": list(a.shape), "dtype": np_dtype_name(a)}
            )
        goldens[name] = {"inputs": in_files, "outputs": out_files}

        signatures[name] = {
            "inputs": [
                {"shape": list(s.shape), "dtype": np_dtype_name(np.zeros(0, s.dtype))}
                for s in args
            ],
            "outputs": [o["shape"] for o in out_files],
        }
        print(f"  {cfg.name}.{name}: {len(hlo)} chars hlo")

    with open(os.path.join(gold_dir, "goldens.json"), "w") as f:
        json.dump(goldens, f, indent=1)

    return {
        "domain": cfg.domain,
        "num_classes": cfg.num_classes,
        "input_shape": list(cfg.input_shape),
        "emb_dim": cfg.emb_dim,
        "param_count": layout.total,
        "layers": layout.describe(),
        "artifacts": artifacts,
        "entry_signatures": signatures,
        "init_theta": f"init/{cfg.name}.theta.bin",
        "golden_dir": f"golden/{cfg.name}",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--datasets",
        default="",
        help="comma-separated subset (default: all five)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    wanted = set(filter(None, args.datasets.split(",")))
    manifest = {
        "c_max": model.C_MAX,
        "batch": model.BATCH,
        "eval_batch": model.EVAL_BATCH,
        "tau": TAU,
        "block": BLOCK,
        "golden_seed": GOLDEN_SEED,
        "datasets": {},
    }
    for cfg in model.DATASETS:
        if wanted and cfg.name not in wanted:
            continue
        print(f"[aot] building {cfg.name} ({cfg.domain})")
        manifest["datasets"][cfg.name] = build_dataset(cfg, args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written: {len(manifest['datasets'])} datasets")


if __name__ == "__main__":
    main()
