"""Layer-2: dataset configs, flat-parameter layout, and the five AOT
entry points the rust coordinator executes (train/distill/eval/embed/
snap). Build-time only — `aot.py` lowers these once to HLO text.

Interface contract with rust (runtime/artifacts.rs):
  * parameters are a single flat f32[P] vector, laid out by ParamLayout
    (declaration order, w-then-b per layer, C-order raveling);
  * centroids are f32[C_MAX] plus an activity mask f32[C_MAX], so one
    static HLO serves every dynamic cluster count C in [C_min, C_max];
  * scalars (lr, beta, tau, temp) are f32[] operands;
  * labels are int32[B]; inputs are NCHW f32.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import kmeans as kmeans_kernel
from .kernels import wc_loss as wc_kernel
from .nets import audio, vision, layers as L

C_MAX = 32
BATCH = 32
EVAL_BATCH = 64


@dataclasses.dataclass(frozen=True)
class DatasetConfig:
    """One of the paper's five dataset/model pairings (synthetic analogue)."""

    name: str
    domain: str  # "vision" | "audio"
    num_classes: int
    input_shape: tuple  # (C, H, W)
    emb_dim: int = 32
    width: int = 8


# Class counts / modality split mirror the paper's Table 1 datasets.
DATASETS = [
    DatasetConfig("cifar10", "vision", 10, (3, 16, 16)),
    DatasetConfig("cifar100", "vision", 100, (3, 16, 16)),
    DatasetConfig("pathmnist", "vision", 9, (3, 16, 16)),
    DatasetConfig("speechcommands", "audio", 12, (1, 32, 16)),
    DatasetConfig("voxforge", "audio", 6, (1, 32, 16)),
]


def net_for(cfg: DatasetConfig):
    mod = vision if cfg.domain == "vision" else audio
    specs = mod.specs(
        cfg.num_classes,
        in_ch=cfg.input_shape[0],
        emb_dim=cfg.emb_dim,
        width=cfg.width,
    )
    return specs, mod.forward


# ---------------------------------------------------------------------------
# flat parameter layout
# ---------------------------------------------------------------------------


class ParamLayout:
    """Deterministic flat layout: per spec, w then b, C-order ravel."""

    def __init__(self, specs):
        self.specs = specs
        self.entries = []  # (spec_idx, field, shape, offset, size)
        off = 0
        for i, s in enumerate(specs):
            for field in ("w", "b"):
                shape = s["shapes"][field]
                size = int(np.prod(shape))
                self.entries.append((i, field, shape, off, size))
                off += size
        self.total = off

    def flatten(self, params):
        parts = []
        for i, field, _, _, _ in self.entries:
            parts.append(jnp.ravel(params[i][field]))
        return jnp.concatenate(parts)

    def unflatten(self, flat):
        params = [dict() for _ in self.specs]
        for i, field, shape, off, size in self.entries:
            params[i][field] = jnp.reshape(
                jax.lax.dynamic_slice_in_dim(flat, off, size), shape
            )
        return params

    def init_flat(self, seed):
        key = jax.random.PRNGKey(seed)
        keys = jax.random.split(key, len(self.specs))
        params = [L.init_param(s, k) for s, k in zip(self.specs, keys)]
        return self.flatten(params)

    def describe(self):
        """Layer inventory for the manifest (drives rust models/ + edge/)."""
        out = []
        for i, field, shape, off, size in self.entries:
            s = self.specs[i]
            out.append(
                {
                    "layer": s["name"],
                    "kind": s["kind"],
                    "field": field,
                    "shape": list(shape),
                    "offset": off,
                    "size": size,
                    "stride": s.get("stride", 1),
                    "groups": s.get("groups", 1),
                }
            )
        return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def kld(teacher_logits, student_logits, temp):
    """lambda^2 * KL(softmax(t/l) || softmax(s/l)), batch mean (Eq. 2)."""
    pt = jax.nn.softmax(teacher_logits / temp)
    log_pt = jax.nn.log_softmax(teacher_logits / temp)
    log_ps = jax.nn.log_softmax(student_logits / temp)
    kl = jnp.sum(pt * (log_pt - log_ps), axis=1)
    return temp * temp * jnp.mean(kl)


# ---------------------------------------------------------------------------
# entry points (each is AOT-lowered per dataset config)
# ---------------------------------------------------------------------------


def build_entry_points(cfg: DatasetConfig, tau=0.05, block=2048):
    """Returns dict name -> (fn, example_args). All fns are jit-able."""
    specs, forward = net_for(cfg)
    layout = ParamLayout(specs)
    p_total = layout.total

    def apply_net(flat, x):
        params = layout.unflatten(flat)
        return forward(specs, params, x)

    # --- train_step: one SGD step of L_ce + beta * L_wc (paper Eq. 1) ---
    def train_step(theta, mu, mask, x, y, lr, beta):
        def loss_fn(th, m):
            logits, _ = apply_net(th, x)
            ce = cross_entropy(logits, y)
            wc = wc_kernel.wc_loss(th, m, mask, tau, block)
            return ce + beta * wc, ce

        (loss, ce), (d_theta, d_mu) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(theta, mu)
        # d_mu aggregates pull from all P weights (unnormalized L_wc);
        # dividing by P makes the centroid step a mean over members and
        # keeps one lr stable for both theta and mu at any model size.
        return (
            theta - lr * d_theta,
            mu - lr * beta * d_mu / p_total,
            loss,
            ce,
        )

    # --- distill_step: server-side self-compression (paper Eq. 2) ---
    def distill_step(theta_s, theta_t, mu, mask, x, lr, beta, temp):
        t_logits, _ = apply_net(theta_t, x)
        t_logits = jax.lax.stop_gradient(t_logits)

        def loss_fn(th, m):
            s_logits, _ = apply_net(th, x)
            kl = kld(t_logits, s_logits, temp)
            wc = wc_kernel.wc_loss(th, m, mask, tau, block)
            return kl + beta * wc, kl

        (loss, kl), (d_theta, d_mu) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(theta_s, mu)
        return (
            theta_s - lr * d_theta,
            mu - lr * beta * d_mu / p_total,  # see train_step
            loss,
            kl,
        )

    # --- eval_step: correct count + summed CE over one batch ---
    def eval_step(theta, x, y):
        logits, _ = apply_net(theta, x)
        pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
        correct = jnp.sum((pred == y).astype(jnp.float32))
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
        return correct, jnp.sum(nll)

    # --- embed: penultimate embeddings for the representation score ---
    def embed(theta, x):
        _, emb = apply_net(theta, x)
        return (emb,)

    # --- snap: hard quantization via the Pallas assign kernel ---
    def snap(theta, mu, mask):
        snapped, idx, sums, counts = kmeans_kernel.snap(theta, mu, mask, block)
        return snapped, idx, sums, counts

    c, h, w = cfg.input_shape
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    theta_s = sds((p_total,), f32)
    mu_s = sds((C_MAX,), f32)
    mask_s = sds((C_MAX,), f32)
    x_s = sds((BATCH, c, h, w), f32)
    y_s = sds((BATCH,), jnp.int32)
    xe_s = sds((EVAL_BATCH, c, h, w), f32)
    ye_s = sds((EVAL_BATCH,), jnp.int32)
    scalar = sds((), f32)

    return {
        "layout": layout,
        "specs": specs,
        "entries": {
            "train_step": (train_step, (theta_s, mu_s, mask_s, x_s, y_s, scalar, scalar)),
            "distill_step": (
                distill_step,
                (theta_s, theta_s, mu_s, mask_s, x_s, scalar, scalar, scalar),
            ),
            "eval_step": (eval_step, (theta_s, xe_s, ye_s)),
            "embed": (embed, (theta_s, xe_s)),
            "snap": (snap, (theta_s, mu_s, mask_s)),
        },
    }


@functools.lru_cache(maxsize=None)
def _built(name):
    cfg = next(c for c in DATASETS if c.name == name)
    return cfg, build_entry_points(cfg)
