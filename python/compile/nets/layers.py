"""Shared layer primitives for the build-time JAX nets.

Parameters are plain dicts of jnp arrays; the flat-vector layout the
rust coordinator sees is defined by `model.ParamLayout`, which walks
these specs in declaration order. Convolutions are bias-ful and
norm-free (no running statistics — FL aggregation of batch-norm state
is a known confounder the paper sidesteps by construction, and a
stateless net keeps the flat-parameter interface exact).
"""

import jax
import jax.numpy as jnp
import numpy as np


def he_scale(fan_in):
    return np.sqrt(2.0 / fan_in)


def conv_spec(name, cin, cout, k, stride=1, groups=1):
    """Spec for a KxK conv with bias. groups=cin gives a depthwise conv."""
    assert cin % groups == 0
    return {
        "name": name,
        "kind": "conv",
        "cin": cin,
        "cout": cout,
        "k": k,
        "stride": stride,
        "groups": groups,
        "shapes": {
            "w": (cout, cin // groups, k, k),
            "b": (cout,),
        },
        "fan_in": (cin // groups) * k * k,
    }


def dense_spec(name, din, dout):
    return {
        "name": name,
        "kind": "dense",
        "din": din,
        "dout": dout,
        "shapes": {"w": (din, dout), "b": (dout,)},
        "fan_in": din,
    }


def init_param(spec, key):
    """He-normal weights, zero bias."""
    kw, _ = jax.random.split(key)
    w = (
        jax.random.normal(kw, spec["shapes"]["w"], jnp.float32)
        * he_scale(spec["fan_in"])
    )
    b = jnp.zeros(spec["shapes"]["b"], jnp.float32)
    return {"w": w, "b": b}


def apply_conv(spec, p, x):
    """x: f32[B, C, H, W] (NCHW)."""
    s = spec["stride"]
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(s, s),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=spec["groups"],
    )
    return y + p["b"][None, :, None, None]


def apply_dense(spec, p, x):
    return x @ p["w"] + p["b"]


def relu(x):
    return jnp.maximum(x, 0.0)


def global_avg_pool(x):
    """NCHW -> NC."""
    return jnp.mean(x, axis=(2, 3))
