"""Layer-2 network definitions (build-time only; never on the request path)."""

from . import audio, layers, vision  # noqa: F401
