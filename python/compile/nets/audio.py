"""MobileNetLite — the audio backbone (paper: MobileNet, scaled).

Depthwise-separable conv stacks (dw3x3 + pw1x1), exactly MobileNet's
building block, over 1-channel spectrogram inputs; GAP, embedding FC
(penultimate, feeds the representation-quality score), classifier head.
~4k parameters at 12 classes.
"""

from . import layers as L


def specs(num_classes, in_ch=1, emb_dim=32, width=8):
    w1, w2, w3 = width, width * 2, width * 4
    return [
        L.conv_spec("stem", in_ch, w1, 3),
        # dw-separable block 1 (stride 2)
        L.conv_spec("b1.dw", w1, w1, 3, stride=2, groups=w1),
        L.conv_spec("b1.pw", w1, w2, 1),
        # dw-separable block 2 (stride 2)
        L.conv_spec("b2.dw", w2, w2, 3, stride=2, groups=w2),
        L.conv_spec("b2.pw", w2, w3, 1),
        # dw-separable block 3 (stride 1)
        L.conv_spec("b3.dw", w3, w3, 3, groups=w3),
        L.conv_spec("b3.pw", w3, w3, 1),
        # head
        L.dense_spec("fc_embed", w3, emb_dim),
        L.dense_spec("fc_out", emb_dim, num_classes),
    ]


def forward(specs_list, params, x):
    """x: f32[B, 1, T, F] -> (logits, embeddings)."""
    by_name = {s["name"]: (s, p) for s, p in zip(specs_list, params)}

    def conv(name, h):
        s, p = by_name[name]
        return L.apply_conv(s, p, h)

    h = L.relu(conv("stem", x))
    for blk in ("b1", "b2", "b3"):
        h = L.relu(conv(f"{blk}.dw", h))
        h = L.relu(conv(f"{blk}.pw", h))

    h = L.global_avg_pool(h)
    s, p = by_name["fc_embed"]
    emb = L.relu(L.apply_dense(s, p, h))
    s, p = by_name["fc_out"]
    logits = L.apply_dense(s, p, emb)
    return logits, emb
