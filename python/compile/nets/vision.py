"""ResNetLite — the vision backbone (paper: ResNet-20, scaled to testbed).

Structure-faithful scale-down of ResNet-20: conv stem, two residual
stages with stride-2 downsampling and 1x1 projection skips, global
average pooling, an embedding FC (whose post-ReLU activations are the
penultimate embeddings the representation-quality score consumes), and
a linear classifier head. ~20k parameters at 10 classes.
"""

from . import layers as L


def specs(num_classes, in_ch=3, emb_dim=32, width=8):
    w1, w2, w3 = width, width * 2, width * 4
    return [
        L.conv_spec("stem", in_ch, w1, 3),
        # stage 1 (stride 2)
        L.conv_spec("s1.conv1", w1, w2, 3, stride=2),
        L.conv_spec("s1.conv2", w2, w2, 3),
        L.conv_spec("s1.skip", w1, w2, 1, stride=2),
        # stage 2 (stride 2)
        L.conv_spec("s2.conv1", w2, w3, 3, stride=2),
        L.conv_spec("s2.conv2", w3, w3, 3),
        L.conv_spec("s2.skip", w2, w3, 1, stride=2),
        # head
        L.dense_spec("fc_embed", w3, emb_dim),
        L.dense_spec("fc_out", emb_dim, num_classes),
    ]


def forward(specs_list, params, x):
    """x: f32[B, C, H, W] -> (logits, embeddings)."""
    by_name = {s["name"]: (s, p) for s, p in zip(specs_list, params)}

    def conv(name, h):
        s, p = by_name[name]
        return L.apply_conv(s, p, h)

    h = L.relu(conv("stem", x))

    # stage 1
    r = conv("s1.skip", h)
    h = L.relu(conv("s1.conv1", h))
    h = conv("s1.conv2", h)
    h = L.relu(h + r)

    # stage 2
    r = conv("s2.skip", h)
    h = L.relu(conv("s2.conv1", h))
    h = conv("s2.conv2", h)
    h = L.relu(h + r)

    h = L.global_avg_pool(h)
    s, p = by_name["fc_embed"]
    emb = L.relu(L.apply_dense(s, p, h))
    s, p = by_name["fc_out"]
    logits = L.apply_dense(s, p, emb)
    return logits, emb
