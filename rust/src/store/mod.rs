//! The run store: persistent, content-addressed experiment records.
//!
//! Every federated run this repo executes used to evaporate on process
//! exit — `RunResult`, events, and the comm ledger lived only in the
//! printing process. The store turns each run into a durable
//! [`RunRecord`] addressed by a content key:
//!
//! ```text
//! key = FNV-1a64( u16 len | strategy name | config_image(cfg) )
//! ```
//!
//! where `config_image` is the *bit-exact* `FedConfig` serialization
//! from [`crate::net::proto`] (the same bytes the TCP handshake ships
//! to workers, seed included). Two runs share a key iff they are the
//! same experiment — same strategy, same config down to the float
//! bits — which is exactly the determinism contract the transport
//! layer already enforces, so a key is a *reproducibility address*:
//! the sweep orchestrator skips any job whose key already has a
//! completed record (resume-by-cache).
//!
//! Layout:
//!
//! * [`record`] — [`RunRecord`]: per-round `RoundMetrics`, the event
//!   JSONL, the comm ledger (ideal + framed bytes), and final scores,
//!   with explicit little-endian serialization and bit-exact
//!   [`record::diff_records`] comparison.
//! * [`index`] — [`RunStore`]: an append-only record file
//!   (`runs.fcr`) with a checksum-verifying scan that rebuilds the
//!   in-memory index on every open, plus a derived `index.json`
//!   sidecar for external tooling. Corrupt or truncated input
//!   surfaces as a typed [`StoreError`] — never a panic, never a hang
//!   (same discipline as `net::frame`).
//! * [`export`] — reporting: the `runs export-bench` summary
//!   (`BENCH_sweep.json`) and the `runs compare` table rows.

pub mod export;
pub mod index;
pub mod record;

pub use index::{RunMeta, RunStore, FORMAT_VERSION};
pub use record::{diff_records, key_hex, parse_key_hex, run_key, RecordDiff, RunRecord};

use std::fmt;

/// Typed store failure. Every malformed, truncated, or corrupt byte
/// sequence the record codecs can see maps to one of these — the
/// decoders never panic.
#[derive(Debug)]
pub enum StoreError {
    /// Underlying filesystem failure.
    Io(std::io::Error),
    /// File or record does not start with the expected magic.
    BadMagic { what: &'static str, got: u32 },
    /// Store file written by an unknown format version.
    UnsupportedVersion { got: u32 },
    /// A length field exceeds the sanity cap (refuse to allocate).
    Oversized { len: u64, max: u64 },
    /// Record body checksum does not match the stored FNV-1a.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// File ended mid-structure.
    Truncated { what: &'static str },
    /// Structurally invalid record contents.
    Malformed { what: String },
    /// A record's stored key does not match its recomputed content
    /// key — the record was tampered with or the key algorithm drifted.
    KeyMismatch { stored: u64, computed: u64 },
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "run store i/o error: {e}"),
            StoreError::BadMagic { what, got } => {
                write!(f, "bad {what} magic 0x{got:08x} (not a run store?)")
            }
            StoreError::UnsupportedVersion { got } => {
                write!(f, "unsupported run store format version {got}")
            }
            StoreError::Oversized { len, max } => {
                write!(f, "record length {len} exceeds the {max}-byte cap")
            }
            StoreError::ChecksumMismatch { stored, computed } => write!(
                f,
                "record checksum mismatch: stored 0x{stored:016x}, computed 0x{computed:016x}"
            ),
            StoreError::Truncated { what } => write!(f, "truncated run store: {what}"),
            StoreError::Malformed { what } => write!(f, "malformed record: {what}"),
            StoreError::KeyMismatch { stored, computed } => write!(
                f,
                "record key mismatch: stored 0x{stored:016x}, content hashes to 0x{computed:016x}"
            ),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> StoreError {
        StoreError::Io(e)
    }
}
