//! Store reporting: the `runs list` / `runs compare` / `runs show`
//! table builders (one row vocabulary shared by the terminal printer
//! and the CSV writer) and the `runs export-bench` summary that feeds
//! the repo's machine-readable perf trajectory (`BENCH_sweep.json`).

use std::path::Path;

use crate::bench::schema::{BenchDoc, BenchError, BenchRow};
use crate::util::json::Json;

use super::index::{RunMeta, RunStore};
use super::record::{key_hex, RunRecord};
use super::StoreError;

/// `runs list` columns.
pub const LIST_HEADER: [&str; 11] = [
    "key",
    "strategy",
    "dataset",
    "fleet",
    "codec",
    "seed",
    "rounds",
    "final_acc",
    "comm_mb",
    "framed_mb",
    "created_unix",
];

pub fn list_rows(metas: &[&RunMeta]) -> Vec<Vec<String>> {
    metas
        .iter()
        .map(|m| {
            vec![
                key_hex(m.key),
                m.strategy.clone(),
                m.dataset.clone(),
                m.fleet.clone(),
                m.codec.clone(),
                m.seed.to_string(),
                m.rounds.to_string(),
                format!("{:.4}", m.final_accuracy),
                format!("{:.3}", m.total_bytes as f64 / 1e6),
                format!("{:.3}", m.total_framed_bytes as f64 / 1e6),
                m.created_unix.to_string(),
            ]
        })
        .collect()
}

/// `runs compare` columns — one row per record, grouped for paired
/// reading (strategy / dataset / fleet / seed sort).
pub const COMPARE_HEADER: [&str; 11] = [
    "strategy",
    "dataset",
    "fleet",
    "codec",
    "seed",
    "final_acc",
    "mcr",
    "comm_mb",
    "sim_s",
    "dropped",
    "key",
];

pub fn compare_rows(metas: &[&RunMeta]) -> Vec<Vec<String>> {
    let mut sorted: Vec<&RunMeta> = metas.to_vec();
    sorted.sort_by(|a, b| {
        (&a.strategy, &a.dataset, &a.fleet, &a.codec, a.seed)
            .cmp(&(&b.strategy, &b.dataset, &b.fleet, &b.codec, b.seed))
    });
    sorted
        .iter()
        .map(|m| {
            vec![
                m.strategy.clone(),
                m.dataset.clone(),
                m.fleet.clone(),
                m.codec.clone(),
                m.seed.to_string(),
                format!("{:.4}", m.final_accuracy),
                format!("{:.2}", m.mcr),
                format!("{:.3}", m.total_bytes as f64 / 1e6),
                format!("{:.1}", m.total_sim_ms / 1e3),
                m.dropped.to_string(),
                key_hex(m.key),
            ]
        })
        .collect()
}

/// `runs show` per-round columns (a superset of the training log
/// line, machine-readable).
pub const ROUNDS_HEADER: [&str; 11] = [
    "round",
    "accuracy",
    "test_loss",
    "score",
    "client_mean_ce",
    "clusters",
    "up_bytes",
    "down_bytes",
    "sim_ms",
    "stragglers",
    "dropped",
];

pub fn rounds_rows(rec: &RunRecord) -> Vec<Vec<String>> {
    rec.rounds
        .iter()
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{:.6}", r.accuracy),
                format!("{:.6}", r.test_loss),
                format!("{:.6}", r.score),
                format!("{:.6}", r.client_mean_ce),
                r.clusters.to_string(),
                r.up_bytes.to_string(),
                r.down_bytes.to_string(),
                format!("{:.3}", r.round_sim_ms),
                r.stragglers.to_string(),
                r.dropped.to_string(),
            ]
        })
        .collect()
}

/// The `BENCH_sweep.json` document as a [`BenchDoc`] (shared format-2
/// envelope with the headless bench runner): every (latest) record
/// becomes one row (`suite` = strategy, `median_ns` = total sim time,
/// `bytes` = total uplink payload, so MiB/s derives the simulated
/// communication rate), and the pre-format-2 `records` / `runs` /
/// `by_strategy` keys ride along in the extra map for existing
/// consumers.
pub fn bench_doc(store: &RunStore) -> BenchDoc {
    let latest = store.latest();
    let mut doc = BenchDoc::new("sweep", false);
    for m in &latest {
        doc.rows.push(BenchRow {
            suite: m.strategy.clone(),
            name: format!("{}/{}/{}/s{}", m.dataset, m.fleet, m.codec, m.seed),
            median_ns: m.total_sim_ms * 1e6,
            p10_ns: m.total_sim_ms * 1e6,
            p90_ns: m.total_sim_ms * 1e6,
            iters: m.rounds,
            bytes: Some(m.total_bytes),
        });
    }
    doc.rows
        .sort_by(|a, b| (&a.suite, &a.name).cmp(&(&b.suite, &b.name)));
    let legacy = legacy_summary(&latest);
    doc.extra
        .insert("records".to_string(), Json::from(latest.len()));
    for key in ["runs", "by_strategy"] {
        if let Some(v) = legacy.opt(key) {
            doc.extra.insert(key.to_string(), v.clone());
        }
    }
    doc
}

/// Full rendered `BENCH_sweep.json` (envelope + legacy keys merged).
pub fn bench_summary(store: &RunStore) -> Json {
    bench_doc(store).to_json()
}

/// The pre-format-2 summary body (`runs` array + per-strategy
/// aggregates), kept verbatim under the format-2 envelope.
fn legacy_summary(latest: &[&RunMeta]) -> Json {
    let runs: Vec<Json> = latest
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("key", Json::str(&key_hex(m.key))),
                ("strategy", Json::str(&m.strategy)),
                ("dataset", Json::str(&m.dataset)),
                ("fleet", Json::str(&m.fleet)),
                ("codec", Json::str(&m.codec)),
                ("seed", Json::str(&m.seed.to_string())),
                ("rounds", Json::from(m.rounds)),
                ("final_accuracy", Json::num(m.final_accuracy)),
                ("total_bytes", Json::from(m.total_bytes)),
                ("total_framed_bytes", Json::from(m.total_framed_bytes)),
                ("mcr", Json::num(m.mcr)),
                ("total_sim_ms", Json::num(m.total_sim_ms)),
                ("total_wall_ms", Json::num(m.total_wall_ms)),
                ("dropped", Json::from(m.dropped)),
                ("stragglers", Json::from(m.stragglers)),
            ])
        })
        .collect();

    let mut strategies: Vec<&str> = latest.iter().map(|m| m.strategy.as_str()).collect();
    strategies.sort_unstable();
    strategies.dedup();
    let by_strategy: Vec<(&str, Json)> = strategies
        .iter()
        .map(|&name| {
            let group: Vec<&RunMeta> =
                latest.iter().copied().filter(|m| m.strategy == name).collect();
            let n = group.len() as f64;
            let mean = |f: &dyn Fn(&RunMeta) -> f64| {
                group.iter().map(|m| f(m)).sum::<f64>() / n
            };
            (
                name,
                Json::obj(vec![
                    ("runs", Json::from(group.len())),
                    (
                        "mean_final_accuracy",
                        Json::num(mean(&|m: &RunMeta| m.final_accuracy)),
                    ),
                    ("mean_mcr", Json::num(mean(&|m: &RunMeta| m.mcr))),
                    (
                        "total_bytes",
                        Json::from(group.iter().map(|m| m.total_bytes).sum::<usize>()),
                    ),
                    (
                        "mean_total_sim_ms",
                        Json::num(mean(&|m: &RunMeta| m.total_sim_ms)),
                    ),
                ]),
            )
        })
        .collect();

    Json::obj(vec![
        ("runs", Json::Arr(runs)),
        ("by_strategy", Json::obj(by_strategy)),
    ])
}

/// Write the bench summary to `path` (`runs export-bench`) through the
/// shared [`BenchDoc`] writer.
pub fn write_bench_json(store: &RunStore, path: &Path) -> Result<(), StoreError> {
    match bench_doc(store).write(path) {
        Ok(()) => Ok(()),
        Err(BenchError::Io(_, e)) => Err(StoreError::Io(e)),
        Err(e) => Err(StoreError::Malformed { what: e.to_string() }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::record::tests::demo_record;

    #[test]
    fn bench_summary_counts_and_groups() {
        let dir = std::env::temp_dir().join("fedcompress_store_unit/export");
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = RunStore::open(&dir).unwrap();
        store.append(&demo_record(1, "fedavg")).unwrap();
        store.append(&demo_record(2, "fedavg")).unwrap();
        store.append(&demo_record(1, "fedcompress")).unwrap();
        let doc = bench_summary(&store);
        // format-2 envelope from the shared bench schema...
        assert_eq!(doc.get("format").unwrap().as_usize().unwrap(), 2);
        assert_eq!(doc.get("rows").unwrap().as_arr().unwrap().len(), 3);
        // ...with the legacy summary keys still present for consumers
        assert_eq!(doc.get("records").unwrap().as_usize().unwrap(), 3);
        assert_eq!(doc.get("runs").unwrap().as_arr().unwrap().len(), 3);
        let by = doc.get("by_strategy").unwrap();
        assert_eq!(by.get("fedavg").unwrap().get("runs").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            by.get("fedcompress").unwrap().get("runs").unwrap().as_usize().unwrap(),
            1
        );
        // document round-trips through the JSON substrate
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).unwrap(), doc);

        let out = dir.join("BENCH_sweep.json");
        write_bench_json(&store, &out).unwrap();
        let parsed = Json::parse(std::fs::read_to_string(&out).unwrap().trim()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "sweep");
    }

    #[test]
    fn table_builders_shape() {
        let dir = std::env::temp_dir().join("fedcompress_store_unit/tables");
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = RunStore::open(&dir).unwrap();
        let rec = demo_record(3, "topk");
        store.append(&rec).unwrap();
        let latest = store.latest();
        let rows = list_rows(&latest);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].len(), LIST_HEADER.len());
        assert_eq!(rows[0][0], key_hex(rec.key));
        let rows = compare_rows(&latest);
        assert_eq!(rows[0].len(), COMPARE_HEADER.len());
        let rows = rounds_rows(&rec);
        assert_eq!(rows.len(), rec.rounds.len());
        assert_eq!(rows[0].len(), ROUNDS_HEADER.len());
    }
}
