//! [`RunStore`]: the append-only record file and its rebuildable
//! index.
//!
//! On-disk layout (`<dir>/runs.fcr`, little-endian):
//!
//! ```text
//! file header: u32 magic "FCST" | u32 format version
//! entry*:      u32 magic "FCRE" | u32 body_len | body |
//!              u64 fnv1a64(body)
//! ```
//!
//! The in-memory index (key -> entry offset + summary meta) is
//! rebuilt on every `open` by a full checksum-verifying scan — the
//! file is the single source of truth, so a truncated or bit-flipped
//! store surfaces a typed [`StoreError`] the moment it is opened,
//! never a panic and never stale listings. A derived `index.json`
//! sidecar is written for external tooling (dashboards, `jq`); it is
//! never read back, so deleting or corrupting it costs nothing.
//!
//! Appends go through one writer (`&mut self`); re-running an
//! experiment appends a fresh record and the index resolves a key to
//! its *latest* entry. The store is single-process: concurrent
//! appends from two processes are not defended against.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::util::hash::fnv1a64;
use crate::util::json::Json;

use super::record::{key_hex, parse_key_hex, RunRecord};
use super::StoreError;

const FILE_MAGIC: u32 = u32::from_le_bytes(*b"FCST");
const ENTRY_MAGIC: u32 = u32::from_le_bytes(*b"FCRE");
/// Store format version. Bump when record bodies change shape.
/// v2: the embedded config image gained the codec pipeline spec
/// (pre-codec stores would misparse, not error, without the bump).
pub const FORMAT_VERSION: u32 = 2;
const FILE_HEADER_LEN: u64 = 8;
/// Per-entry framing: magic(4) + body_len(4) + checksum(8).
const ENTRY_OVERHEAD: usize = 16;
/// Refuse record bodies above this size (a corrupt length prefix must
/// not become a multi-gigabyte allocation).
const MAX_BODY: u32 = 256 << 20;

/// Summary of one stored record — everything listings, comparisons,
/// and bench exports need without re-reading the file.
#[derive(Clone, Debug)]
pub struct RunMeta {
    pub key: u64,
    pub strategy: String,
    pub dataset: String,
    pub fleet: String,
    /// codec pipeline override the run executed under ("-" = the
    /// strategy's declared default)
    pub codec: String,
    pub seed: u64,
    pub rounds: usize,
    pub final_accuracy: f64,
    pub total_bytes: usize,
    pub total_framed_bytes: usize,
    pub mcr: f64,
    pub total_sim_ms: f64,
    pub total_wall_ms: f64,
    pub dropped: usize,
    pub stragglers: usize,
    pub created_unix: u64,
    /// byte offset of the entry (its magic) in `runs.fcr`
    pub offset: u64,
    /// whole entry length including framing
    pub entry_len: usize,
}

impl RunMeta {
    fn of(rec: &RunRecord, offset: u64, entry_len: usize) -> Result<RunMeta, StoreError> {
        let cfg = rec.cfg()?;
        Ok(RunMeta {
            key: rec.key,
            strategy: rec.strategy.clone(),
            dataset: cfg.dataset.clone(),
            fleet: cfg.fleet.preset.name().to_string(),
            codec: if cfg.codec.is_empty() {
                "-".to_string()
            } else {
                cfg.codec.clone()
            },
            seed: cfg.seed,
            rounds: rec.rounds.len(),
            final_accuracy: rec.final_accuracy,
            total_bytes: rec.total_bytes(),
            total_framed_bytes: rec.total_framed_bytes(),
            mcr: rec.mcr(),
            total_sim_ms: rec.total_sim_ms(),
            total_wall_ms: rec.total_wall_ms(),
            dropped: rec.total_dropped(),
            stragglers: rec.total_stragglers(),
            created_unix: rec.created_unix,
            offset,
            entry_len,
        })
    }
}

pub struct RunStore {
    dir: PathBuf,
    records_path: PathBuf,
    file_len: u64,
    /// every entry, file order (re-runs of a key appear once each)
    metas: Vec<RunMeta>,
    /// key -> index into `metas` of the latest entry for that key
    by_key: BTreeMap<u64, usize>,
}

impl RunStore {
    /// Open (or create) the store under `dir`, rebuilding the index by
    /// a full checksum-verifying scan of the record file.
    pub fn open(dir: &Path) -> Result<RunStore, StoreError> {
        std::fs::create_dir_all(dir)?;
        let records_path = dir.join("runs.fcr");
        if !records_path.exists() {
            let mut header = Vec::with_capacity(FILE_HEADER_LEN as usize);
            header.extend_from_slice(&FILE_MAGIC.to_le_bytes());
            header.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
            std::fs::write(&records_path, header)?;
        }
        let bytes = std::fs::read(&records_path)?;
        let mut store = RunStore {
            dir: dir.to_path_buf(),
            records_path,
            file_len: bytes.len() as u64,
            metas: Vec::new(),
            by_key: BTreeMap::new(),
        };
        store.scan(&bytes)?;
        store.write_sidecar()?;
        Ok(store)
    }

    fn scan(&mut self, bytes: &[u8]) -> Result<(), StoreError> {
        if bytes.len() < FILE_HEADER_LEN as usize {
            return Err(StoreError::Truncated {
                what: "store file header",
            });
        }
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        if magic != FILE_MAGIC {
            return Err(StoreError::BadMagic {
                what: "store file",
                got: magic,
            });
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(StoreError::UnsupportedVersion { got: version });
        }
        let mut off = FILE_HEADER_LEN as usize;
        while off < bytes.len() {
            let (rec, entry_len) = decode_entry(&bytes[off..])?;
            let meta = RunMeta::of(&rec, off as u64, entry_len)?;
            self.by_key.insert(meta.key, self.metas.len());
            self.metas.push(meta);
            off += entry_len;
        }
        Ok(())
    }

    /// Append a record; the in-memory index updates in the same call.
    /// The `index.json` sidecar is *not* rewritten here — it is O(all
    /// entries) and purely derived, so per-append refresh would turn
    /// an N-job sweep into O(N²) serialization inside the store lock.
    /// Call [`RunStore::flush_sidecar`] once after a batch (the sweep
    /// orchestrator and the store-backed drivers do); a crash before
    /// that costs nothing, the next open rescans and rewrites it.
    pub fn append(&mut self, rec: &RunRecord) -> Result<(), StoreError> {
        let entry = encode_entry(rec);
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.records_path)?;
        let offset = f.seek(SeekFrom::End(0))?;
        f.write_all(&entry)?;
        f.flush()?;
        let meta = RunMeta::of(rec, offset, entry.len())?;
        self.file_len = offset + entry.len() as u64;
        self.by_key.insert(meta.key, self.metas.len());
        self.metas.push(meta);
        Ok(())
    }

    /// Rewrite the derived `index.json` sidecar to match the current
    /// index (cheap relative to a batch of appends; see `append`).
    pub fn flush_sidecar(&self) -> Result<(), StoreError> {
        self.write_sidecar()
    }

    /// True when a completed record exists for `key` (the sweep
    /// orchestrator's cache probe).
    pub fn contains(&self, key: u64) -> bool {
        self.by_key.contains_key(&key)
    }

    /// Load the latest record for `key`, re-verifying the entry
    /// checksum on the way in.
    pub fn get(&self, key: u64) -> Result<Option<RunRecord>, StoreError> {
        let Some(&idx) = self.by_key.get(&key) else {
            return Ok(None);
        };
        let meta = &self.metas[idx];
        let mut f = std::fs::File::open(&self.records_path)?;
        f.seek(SeekFrom::Start(meta.offset))?;
        let mut entry = vec![0u8; meta.entry_len];
        f.read_exact(&mut entry).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                StoreError::Truncated {
                    what: "record entry (file shrank since open)",
                }
            } else {
                StoreError::Io(e)
            }
        })?;
        let (rec, _) = decode_entry(&entry)?;
        Ok(Some(rec))
    }

    /// Resolve a CLI key argument: a full 16-hex key, or a unique hex
    /// prefix of a stored key.
    pub fn resolve(&self, hex: &str) -> Result<u64, StoreError> {
        let t = hex.trim();
        if t.len() == 16 {
            if let Ok(k) = parse_key_hex(t) {
                if self.contains(k) {
                    return Ok(k);
                }
                return Err(StoreError::Malformed {
                    what: format!("no record with key {t}"),
                });
            }
        }
        let matches: Vec<u64> = self
            .by_key
            .keys()
            .copied()
            .filter(|k| key_hex(*k).starts_with(&t.to_ascii_lowercase()))
            .collect();
        match matches.as_slice() {
            [k] => Ok(*k),
            [] => Err(StoreError::Malformed {
                what: format!("no record with key prefix '{t}'"),
            }),
            many => Err(StoreError::Malformed {
                what: format!("key prefix '{t}' is ambiguous ({} matches)", many.len()),
            }),
        }
    }

    /// Every stored entry, file order (including superseded re-runs).
    pub fn metas(&self) -> &[RunMeta] {
        &self.metas
    }

    /// The latest entry per key, file order.
    pub fn latest(&self) -> Vec<&RunMeta> {
        self.metas
            .iter()
            .enumerate()
            .filter(|(i, m)| self.by_key.get(&m.key) == Some(i))
            .map(|(_, m)| m)
            .collect()
    }

    /// Distinct keys with a completed record.
    pub fn keys(&self) -> Vec<u64> {
        self.by_key.keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Derived sidecar for external tooling; never read back.
    fn write_sidecar(&self) -> Result<(), StoreError> {
        let entries: Vec<Json> = self
            .metas
            .iter()
            .map(|m| {
                // store-relative pointer to the run's replayable event
                // stream (`runs tail`); `..._present` says whether the
                // tee exists on disk at flush time
                let stream = format!("events/{}.jsonl", key_hex(m.key));
                let present = self.dir.join(&stream).exists();
                Json::obj(vec![
                    ("key", Json::str(&key_hex(m.key))),
                    ("strategy", Json::str(&m.strategy)),
                    ("dataset", Json::str(&m.dataset)),
                    ("fleet", Json::str(&m.fleet)),
                    ("codec", Json::str(&m.codec)),
                    ("seed", Json::str(&m.seed.to_string())),
                    ("rounds", Json::from(m.rounds)),
                    ("final_accuracy", Json::num(m.final_accuracy)),
                    ("total_bytes", Json::from(m.total_bytes)),
                    ("created_unix", Json::from(m.created_unix as usize)),
                    ("offset", Json::from(m.offset as usize)),
                    ("entry_len", Json::from(m.entry_len)),
                    ("event_stream", Json::str(&stream)),
                    ("event_stream_present", Json::Bool(present)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("format", Json::from(FORMAT_VERSION as usize)),
            ("file_len", Json::from(self.file_len as usize)),
            ("records", Json::Arr(entries)),
        ]);
        std::fs::write(self.dir.join("index.json"), doc.to_string())?;
        Ok(())
    }
}

fn encode_entry(rec: &RunRecord) -> Vec<u8> {
    let body = rec.to_body_bytes();
    assert!(
        body.len() as u64 <= MAX_BODY as u64,
        "record body over the {MAX_BODY}-byte cap"
    );
    let mut out = Vec::with_capacity(ENTRY_OVERHEAD + body.len());
    out.extend_from_slice(&ENTRY_MAGIC.to_le_bytes());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out.extend_from_slice(&fnv1a64(&body).to_le_bytes());
    out
}

/// Decode one entry from the head of `bytes`; returns the record and
/// the entry's total length.
fn decode_entry(bytes: &[u8]) -> Result<(RunRecord, usize), StoreError> {
    if bytes.len() < 8 {
        return Err(StoreError::Truncated {
            what: "entry header",
        });
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != ENTRY_MAGIC {
        return Err(StoreError::BadMagic {
            what: "record entry",
            got: magic,
        });
    }
    let body_len = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if body_len > MAX_BODY {
        return Err(StoreError::Oversized {
            len: body_len as u64,
            max: MAX_BODY as u64,
        });
    }
    let entry_len = ENTRY_OVERHEAD + body_len as usize;
    if bytes.len() < entry_len {
        return Err(StoreError::Truncated {
            what: "record body",
        });
    }
    let body = &bytes[8..8 + body_len as usize];
    let stored = u64::from_le_bytes(
        bytes[8 + body_len as usize..entry_len].try_into().unwrap(),
    );
    let computed = fnv1a64(body);
    if stored != computed {
        return Err(StoreError::ChecksumMismatch { stored, computed });
    }
    let rec = RunRecord::from_body_bytes(body)?;
    Ok((rec, entry_len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::record::tests::demo_record;

    fn tmp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fedcompress_store_unit").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn open_append_get_list() {
        let dir = tmp_store("basic");
        let mut store = RunStore::open(&dir).unwrap();
        assert!(store.is_empty());
        let a = demo_record(1, "fedavg");
        let b = demo_record(2, "fedcompress");
        store.append(&a).unwrap();
        store.append(&b).unwrap();
        assert_eq!(store.len(), 2);
        assert!(store.contains(a.key) && store.contains(b.key));
        let back = store.get(a.key).unwrap().unwrap();
        assert!(crate::store::diff_records(&a, &back).is_identical());
        assert!(store.get(0xDEAD_BEEF).unwrap().is_none());

        // a fresh open rebuilds the identical index from the file alone
        let again = RunStore::open(&dir).unwrap();
        assert_eq!(again.len(), 2);
        assert_eq!(again.keys(), store.keys());
        let back = again.get(b.key).unwrap().unwrap();
        assert!(crate::store::diff_records(&b, &back).is_identical());
        // sidecar exists and is derived
        assert!(dir.join("index.json").exists());
        std::fs::remove_file(dir.join("index.json")).unwrap();
        assert_eq!(RunStore::open(&dir).unwrap().len(), 2);
    }

    #[test]
    fn rerun_supersedes_but_keeps_history() {
        let dir = tmp_store("rerun");
        let mut store = RunStore::open(&dir).unwrap();
        let a1 = demo_record(1, "fedavg");
        let mut a2 = a1.clone();
        a2.created_unix += 60;
        store.append(&a1).unwrap();
        store.append(&a2).unwrap();
        assert_eq!(store.len(), 1, "one key");
        assert_eq!(store.metas().len(), 2, "two entries");
        assert_eq!(store.latest().len(), 1);
        let got = store.get(a1.key).unwrap().unwrap();
        assert_eq!(got.created_unix, a2.created_unix, "latest wins");
    }

    #[test]
    fn prefix_resolution() {
        let dir = tmp_store("prefix");
        let mut store = RunStore::open(&dir).unwrap();
        let a = demo_record(1, "fedavg");
        store.append(&a).unwrap();
        let hex = key_hex(a.key);
        assert_eq!(store.resolve(&hex).unwrap(), a.key);
        assert_eq!(store.resolve(&hex[..6]).unwrap(), a.key);
        assert!(store.resolve("zz").is_err(), "no such prefix");
        let b = demo_record(2, "fedavg");
        store.append(&b).unwrap();
        // the empty prefix now matches both keys -> ambiguous
        let err = store.resolve("").unwrap_err().to_string();
        assert!(err.contains("ambiguous"), "{err}");
    }
}
