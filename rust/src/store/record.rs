//! [`RunRecord`]: one persisted federated run, and its content key.
//!
//! Record body layout (little-endian; the store file wraps each body
//! in a `magic | len | body | fnv1a64` entry, see [`super::index`]):
//!
//! ```text
//! u64 key | u64 created_unix | u16 strat_len | strategy |
//! u32 cfg_len | config_image | u32 n_rounds |
//! n_rounds x RoundMetrics (80 B fixed, coordinator::metrics) |
//! f64 final_accuracy | u64 final_model_bytes | u64 dense_model_bytes |
//! u32 n_transfers | n_transfers x (u32 round | u8 dir | u64 bytes |
//! u64 framed) | u32 events_len | events JSONL (utf-8)
//! ```
//!
//! The model weights are deliberately *not* stored — records are the
//! paper-facing measurements (metrics, events, ledger), small enough
//! to accumulate thousands per store; the deliverable model belongs to
//! `Checkpoint`.

use crate::compression::accounting::{CommLedger, Direction};
use crate::config::FedConfig;
use crate::coordinator::events::{EventLog, ParsedLog};
use crate::coordinator::metrics::{self, RoundMetrics, RunResult};
use crate::net::proto::{config_image, parse_config_image};
use crate::util::hash::Fnv1a;

use super::StoreError;

/// Content key of a run: FNV-1a64 over the strategy name (length-
/// prefixed) followed by the bit-exact config image. Everything that
/// can change a run's outcome — dataset, seed, fleet, every float knob
/// — lives in the image, so equal keys mean "the same experiment".
pub fn run_key(strategy: &str, cfg: &FedConfig) -> u64 {
    let mut h = Fnv1a::new();
    h.update(&(strategy.len() as u16).to_le_bytes());
    h.update(strategy.as_bytes());
    h.update(&config_image(cfg));
    h.finish()
}

/// Render a key the way the CLI prints and parses it (16 hex digits).
pub fn key_hex(key: u64) -> String {
    format!("{key:016x}")
}

/// Parse a `runs show --key` style hex key.
pub fn parse_key_hex(s: &str) -> Result<u64, StoreError> {
    u64::from_str_radix(s.trim(), 16).map_err(|_| StoreError::Malformed {
        what: format!("'{s}' is not a hex record key"),
    })
}

#[derive(Clone, Debug)]
pub struct RunRecord {
    /// content key (`run_key(strategy, cfg)`), verified on decode
    pub key: u64,
    /// unix seconds the record was created (informational; excluded
    /// from `diff_records`)
    pub created_unix: u64,
    /// canonical strategy name
    pub strategy: String,
    /// bit-exact `FedConfig` image (`net::proto::config_image`)
    pub cfg_image: Vec<u8>,
    pub rounds: Vec<RoundMetrics>,
    pub final_accuracy: f64,
    /// wire bytes of the final deliverable model
    pub final_model_bytes: usize,
    /// dense f32 bytes of the same model
    pub dense_model_bytes: usize,
    pub ledger: CommLedger,
    /// the run's event log as JSON lines (stored verbatim)
    pub events_jsonl: String,
}

/// Caps a decoder enforces before allocating (a corrupt length field
/// must not become a multi-gigabyte allocation).
const MAX_ROUNDS: u32 = 1_000_000;
const MAX_TRANSFERS: u32 = 64_000_000;
const MAX_CFG_BYTES: u32 = 64 << 10;

impl RunRecord {
    /// Convert a finished run into its persistent record. `cfg` must
    /// be the config the run executed under.
    pub fn from_result(cfg: &FedConfig, result: &RunResult) -> RunRecord {
        // created_unix is an environment field, excluded from content
        // keys and diffs; the read goes through the sanctioned timer
        let created_unix = crate::util::timer::unix_now_s();
        RunRecord {
            key: run_key(result.strategy, cfg),
            created_unix,
            strategy: result.strategy.to_string(),
            cfg_image: config_image(cfg),
            rounds: result.rounds.clone(),
            final_accuracy: result.final_accuracy,
            final_model_bytes: result.final_model_bytes,
            dense_model_bytes: result.dense_model_bytes,
            ledger: result.ledger.clone(),
            events_jsonl: result.events.to_jsonl(),
        }
    }

    /// Rebuild the exact `FedConfig` the run executed under.
    pub fn cfg(&self) -> Result<FedConfig, StoreError> {
        parse_config_image(&self.cfg_image).map_err(|e| StoreError::Malformed {
            what: format!("config image: {e}"),
        })
    }

    /// Codec pipeline spec the run executed under — recorded in the
    /// body as part of the bit-exact config image (so it participates
    /// in the content key). Empty = the strategy's declared default.
    pub fn codec_spec(&self) -> Result<String, StoreError> {
        Ok(self.cfg()?.codec)
    }

    /// Parse the stored event log back into typed events. Tolerant:
    /// unreadable lines are collected as per-line errors in the
    /// returned [`ParsedLog`], never a failure — a damaged log still
    /// replays as far as it goes.
    pub fn events(&self) -> ParsedLog {
        EventLog::from_jsonl(&self.events_jsonl)
    }

    /// Model compression ratio versus dense f32 storage.
    pub fn mcr(&self) -> f64 {
        self.dense_model_bytes as f64 / self.final_model_bytes.max(1) as f64
    }

    pub fn total_bytes(&self) -> usize {
        self.ledger.total_bytes()
    }

    pub fn total_framed_bytes(&self) -> usize {
        self.ledger.total_framed_bytes()
    }

    pub fn total_sim_ms(&self) -> f64 {
        metrics::total_sim_ms(&self.rounds)
    }

    /// Real coordinator wall-clock summed over rounds, ms.
    pub fn total_wall_ms(&self) -> f64 {
        self.rounds.iter().map(|r| r.wall_ms).sum()
    }

    pub fn time_to_accuracy(&self, target: f64) -> Option<(usize, f64)> {
        metrics::time_to_accuracy(&self.rounds, target)
    }

    /// Active cluster count of the last trained round (the deployed C
    /// a `table2 --from-run` evaluation uses).
    pub fn final_clusters(&self) -> Option<usize> {
        self.rounds.last().map(|r| r.clusters)
    }

    pub fn total_dropped(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped).sum()
    }

    pub fn total_stragglers(&self) -> usize {
        self.rounds.iter().map(|r| r.stragglers).sum()
    }

    pub fn accuracy_trace(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.accuracy).collect()
    }

    pub fn score_trace(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.score).collect()
    }

    // --- serialization ------------------------------------------------

    /// Serialize the record body (store entry framing not included).
    pub fn to_body_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            64 + self.cfg_image.len()
                + self.rounds.len() * metrics::ROUND_METRICS_BYTES
                + self.ledger.transfer_count() * 21
                + self.events_jsonl.len(),
        );
        out.extend_from_slice(&self.key.to_le_bytes());
        out.extend_from_slice(&self.created_unix.to_le_bytes());
        out.extend_from_slice(&(self.strategy.len() as u16).to_le_bytes());
        out.extend_from_slice(self.strategy.as_bytes());
        out.extend_from_slice(&(self.cfg_image.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.cfg_image);
        out.extend_from_slice(&(self.rounds.len() as u32).to_le_bytes());
        for r in &self.rounds {
            out.extend_from_slice(&r.to_le_bytes());
        }
        out.extend_from_slice(&self.final_accuracy.to_le_bytes());
        out.extend_from_slice(&(self.final_model_bytes as u64).to_le_bytes());
        out.extend_from_slice(&(self.dense_model_bytes as u64).to_le_bytes());
        out.extend_from_slice(&(self.ledger.transfer_count() as u32).to_le_bytes());
        for t in self.ledger.transfers() {
            out.extend_from_slice(&(t.round as u32).to_le_bytes());
            out.push(match t.direction {
                Direction::Down => 0,
                Direction::Up => 1,
            });
            out.extend_from_slice(&(t.bytes as u64).to_le_bytes());
            out.extend_from_slice(&(t.framed_bytes as u64).to_le_bytes());
        }
        out.extend_from_slice(&(self.events_jsonl.len() as u32).to_le_bytes());
        out.extend_from_slice(self.events_jsonl.as_bytes());
        out
    }

    /// Decode a record body. Every structural defect is a typed
    /// [`StoreError`]; the stored key is re-verified against the
    /// record's own content (strategy + config image).
    pub fn from_body_bytes(body: &[u8]) -> Result<RunRecord, StoreError> {
        let mut c = Cur { b: body, i: 0 };
        let key = c.u64("record key")?;
        let created_unix = c.u64("created timestamp")?;
        let strategy = c.str16("strategy name")?;
        let cfg_len = c.u32("config image length")?;
        if cfg_len > MAX_CFG_BYTES {
            return Err(StoreError::Oversized {
                len: cfg_len as u64,
                max: MAX_CFG_BYTES as u64,
            });
        }
        let cfg_image = c.take(cfg_len as usize, "config image")?.to_vec();
        // the image must parse — a record whose config cannot be
        // rebuilt is not a usable experiment address
        let cfg = parse_config_image(&cfg_image).map_err(|e| StoreError::Malformed {
            what: format!("config image: {e}"),
        })?;
        let n_rounds = c.u32("round count")?;
        if n_rounds > MAX_ROUNDS {
            return Err(StoreError::Oversized {
                len: n_rounds as u64,
                max: MAX_ROUNDS as u64,
            });
        }
        let mut rounds = Vec::with_capacity(n_rounds as usize);
        for _ in 0..n_rounds {
            let img: &[u8; metrics::ROUND_METRICS_BYTES] = c
                .take(metrics::ROUND_METRICS_BYTES, "round metrics")?
                .try_into()
                .expect("fixed-size take");
            rounds.push(RoundMetrics::from_le_bytes(img));
        }
        let final_accuracy = c.f64("final accuracy")?;
        let final_model_bytes = c.u64("final model bytes")? as usize;
        let dense_model_bytes = c.u64("dense model bytes")? as usize;
        let n_transfers = c.u32("transfer count")?;
        if n_transfers > MAX_TRANSFERS {
            return Err(StoreError::Oversized {
                len: n_transfers as u64,
                max: MAX_TRANSFERS as u64,
            });
        }
        let mut ledger = CommLedger::new();
        for _ in 0..n_transfers {
            let round = c.u32("transfer round")? as usize;
            let direction = match c.u8("transfer direction")? {
                0 => Direction::Down,
                1 => Direction::Up,
                d => {
                    return Err(StoreError::Malformed {
                        what: format!("unknown transfer direction tag {d}"),
                    })
                }
            };
            let bytes = c.u64("transfer bytes")? as usize;
            let framed = c.u64("transfer framed bytes")? as usize;
            if framed < bytes {
                return Err(StoreError::Malformed {
                    what: format!("transfer framed bytes {framed} undercut payload {bytes}"),
                });
            }
            ledger.record(round, direction, bytes, framed);
        }
        let events_len = c.u32("event log length")?;
        let events_bytes = c.take(events_len as usize, "event log")?;
        let events_jsonl =
            String::from_utf8(events_bytes.to_vec()).map_err(|_| StoreError::Malformed {
                what: "event log is not utf-8".to_string(),
            })?;
        if !c.done() {
            return Err(StoreError::Malformed {
                what: format!("{} bytes of trailing garbage after record", c.remaining()),
            });
        }
        let computed = run_key(&strategy, &cfg);
        if computed != key {
            return Err(StoreError::KeyMismatch {
                stored: key,
                computed,
            });
        }
        Ok(RunRecord {
            key,
            created_unix,
            strategy,
            cfg_image,
            rounds,
            final_accuracy,
            final_model_bytes,
            dense_model_bytes,
            ledger,
            events_jsonl,
        })
    }
}

/// Result of a bit-exact record comparison: the (possibly empty) list
/// of drifting fields.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecordDiff {
    pub fields: Vec<String>,
}

impl RecordDiff {
    pub fn is_identical(&self) -> bool {
        self.fields.is_empty()
    }
}

/// Compare two records for bit-exact *experimental* equality. Every
/// metric, ledger entry, and event byte participates; float fields are
/// compared by bit pattern, so `-0.0 != 0.0` and NaN payloads count.
///
/// Deliberately excluded: `created_unix` and per-round `wall_ms` —
/// both measure the *environment* the run happened in (when, and how
/// fast this host was), not the experiment itself. Two faithful
/// re-executions of the same key differ only in those two fields.
pub fn diff_records(a: &RunRecord, b: &RunRecord) -> RecordDiff {
    let mut d = RecordDiff::default();
    let mut push = |what: String| d.fields.push(what);
    if a.strategy != b.strategy {
        push(format!("strategy ({} vs {})", a.strategy, b.strategy));
    }
    if a.cfg_image != b.cfg_image {
        push("cfg_image".to_string());
    }
    if a.rounds.len() != b.rounds.len() {
        push(format!("rounds.len ({} vs {})", a.rounds.len(), b.rounds.len()));
    }
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        // compare via the byte image with wall_ms blanked (bytes
        // 56..64: round 4 + four f64 metrics 32 + clusters 4 +
        // up/down u64s 16 precede it) — field layout lives in
        // `RoundMetrics::to_le_bytes`, not twice
        let mut ia = ra.to_le_bytes();
        let mut ib = rb.to_le_bytes();
        ia[56..64].fill(0);
        ib[56..64].fill(0);
        if ia != ib {
            push(format!("rounds[{i}]"));
        }
    }
    if a.final_accuracy.to_bits() != b.final_accuracy.to_bits() {
        push(format!(
            "final_accuracy ({} vs {})",
            a.final_accuracy, b.final_accuracy
        ));
    }
    if a.final_model_bytes != b.final_model_bytes {
        push("final_model_bytes".to_string());
    }
    if a.dense_model_bytes != b.dense_model_bytes {
        push("dense_model_bytes".to_string());
    }
    if a.ledger.transfer_count() != b.ledger.transfer_count() {
        push(format!(
            "ledger.len ({} vs {})",
            a.ledger.transfer_count(),
            b.ledger.transfer_count()
        ));
    }
    for (i, (ta, tb)) in a
        .ledger
        .transfers()
        .iter()
        .zip(b.ledger.transfers())
        .enumerate()
    {
        if ta.round != tb.round
            || ta.direction != tb.direction
            || ta.bytes != tb.bytes
            || ta.framed_bytes != tb.framed_bytes
        {
            push(format!("ledger[{i}]"));
        }
    }
    if a.events_jsonl != b.events_jsonl {
        push("events_jsonl".to_string());
    }
    d
}

// --- cursor reader with typed truncation errors ----------------------------

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], StoreError> {
        if self.i + n > self.b.len() {
            return Err(StoreError::Truncated { what });
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self, what: &'static str) -> Result<u8, StoreError> {
        Ok(self.take(1, what)?[0])
    }
    fn u16(&mut self, what: &'static str) -> Result<u16, StoreError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }
    fn u32(&mut self, what: &'static str) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }
    fn u64(&mut self, what: &'static str) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
    fn f64(&mut self, what: &'static str) -> Result<f64, StoreError> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
    fn str16(&mut self, what: &'static str) -> Result<String, StoreError> {
        let n = self.u16(what)? as usize;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| StoreError::Malformed {
            what: format!("{what}: not utf-8"),
        })
    }
    fn done(&self) -> bool {
        self.i == self.b.len()
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::coordinator::events::{Event, EventLog};

    /// A fully populated record with awkward floats (no engine
    /// needed — RunRecord is a plain measurement container).
    pub(crate) fn demo_record(seed: u64, strategy: &'static str) -> RunRecord {
        let mut cfg = FedConfig::quick("cifar10");
        cfg.seed = seed;
        let mut ledger = CommLedger::new();
        let mut events = EventLog::new();
        let mut rounds = Vec::new();
        for r in 0..4usize {
            ledger.record(r, Direction::Down, 1000 + r, 1024 + r);
            ledger.record(r, Direction::Up, 250 + r, 290 + r);
            events.push(Event::RoundStart {
                round: r,
                clusters: 16,
            });
            events.push(Event::Evaluated {
                round: r,
                accuracy: 0.5 + 0.1 * r as f64,
                loss: 1.25e-3,
            });
            rounds.push(RoundMetrics {
                round: r,
                accuracy: 0.5 + 0.1 * r as f64,
                test_loss: 0.7182818284590452,
                score: 4.062499999999999,
                client_mean_ce: 2.1,
                clusters: 16 + r,
                up_bytes: 250 + r,
                down_bytes: 1000 + r,
                wall_ms: 17.25 + r as f64,
                round_sim_ms: 1500.0,
                stragglers: r % 2,
                dropped: 0,
            });
        }
        let result = RunResult {
            strategy,
            dataset: cfg.dataset.clone(),
            rounds,
            final_theta: vec![],
            final_accuracy: 0.8049999999999999,
            final_model_bytes: 5_120,
            dense_model_bytes: 81_920,
            ledger,
            events,
            final_centroids: crate::clustering::CentroidState {
                mu: vec![0.0; 4],
                mask: vec![1.0; 4],
                c_max: 4,
                active: 4,
            },
        };
        RunRecord::from_result(&cfg, &result)
    }

    #[test]
    fn body_round_trips_bit_exactly() {
        let rec = demo_record(7, "fedcompress");
        let body = rec.to_body_bytes();
        let back = RunRecord::from_body_bytes(&body).unwrap();
        assert_eq!(back.to_body_bytes(), body);
        assert!(diff_records(&rec, &back).is_identical());
        assert_eq!(back.key, rec.key);
        assert_eq!(back.strategy, "fedcompress");
        assert_eq!(back.rounds.len(), 4);
        assert_eq!(back.ledger.transfer_count(), 8);
        assert_eq!(back.cfg().unwrap().seed, 7);
        let parsed = back.events();
        assert!(parsed.is_clean());
        assert_eq!(parsed.log.len(), 8);
        assert_eq!(back.final_clusters(), Some(19));
    }

    /// The codec spec is part of the recorded body (via the config
    /// image) and of the content key: two runs differing only in their
    /// pipeline are different experiments.
    #[test]
    fn codec_spec_is_recorded_and_keyed() {
        let base = demo_record(7, "fedavg");
        assert_eq!(base.codec_spec().unwrap(), "");
        let mut cfg = base.cfg().unwrap();
        cfg.codec = "topk(keep=0.2)|kmeans(c=8,iters=25)|huffman".to_string();
        let mut rec = base.clone();
        rec.cfg_image = config_image(&cfg);
        rec.key = run_key(&rec.strategy, &cfg);
        assert_ne!(rec.key, base.key, "codec must change the key");
        let back = RunRecord::from_body_bytes(&rec.to_body_bytes()).unwrap();
        assert_eq!(
            back.codec_spec().unwrap(),
            "topk(keep=0.2)|kmeans(c=8,iters=25)|huffman"
        );
    }

    #[test]
    fn key_separates_experiments() {
        let a = demo_record(7, "fedcompress");
        let b = demo_record(8, "fedcompress");
        let c = demo_record(7, "fedavg");
        assert_ne!(a.key, b.key, "seed must change the key");
        assert_ne!(a.key, c.key, "strategy must change the key");
        // and the key is a pure function of (strategy, cfg)
        assert_eq!(a.key, demo_record(7, "fedcompress").key);
        let cfg = a.cfg().unwrap();
        assert_eq!(a.key, run_key("fedcompress", &cfg));
    }

    #[test]
    fn diff_ignores_environment_fields_only() {
        let a = demo_record(7, "fedcompress");
        let mut b = a.clone();
        b.created_unix += 1000;
        for r in &mut b.rounds {
            r.wall_ms *= 3.0; // a slower host, same experiment
        }
        assert!(diff_records(&a, &b).is_identical());

        let mut c = a.clone();
        c.rounds[2].accuracy += 1e-15;
        let d = diff_records(&a, &c);
        assert_eq!(d.fields, vec!["rounds[2]".to_string()]);

        // bit-pattern comparison: -0.0 and +0.0 are different records
        let mut e = a.clone();
        e.final_accuracy = -0.0;
        let mut f = a.clone();
        f.final_accuracy = 0.0;
        assert!(!diff_records(&e, &f).is_identical());
    }

    #[test]
    fn tampered_key_is_rejected() {
        let rec = demo_record(7, "fedcompress");
        let mut body = rec.to_body_bytes();
        body[0] ^= 1; // flip a key bit; content untouched
        match RunRecord::from_body_bytes(&body) {
            Err(StoreError::KeyMismatch { .. }) => {}
            other => panic!("expected KeyMismatch, got {other:?}"),
        }
    }

    #[test]
    fn key_hex_round_trips() {
        let k = 0x0123_4567_89ab_cdefu64;
        assert_eq!(parse_key_hex(&key_hex(k)).unwrap(), k);
        assert_eq!(parse_key_hex(" 00ff00ff00ff00ff ").unwrap(), 0x00ff00ff00ff00ff);
        assert!(parse_key_hex("not-hex").is_err());
    }
}
