//! Bit-level I/O: the wire substrate for the clustered-weight codec
//! (ceil(log2 C) bits per index) and the Huffman coder (FedZip).
//! LSB-first within each byte; writer and reader are exact inverses.

/// Append-only bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// bits used in the last byte (0 => last byte full / empty buf)
    used: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n <= 32), LSB first.
    pub fn write(&mut self, v: u32, n: u32) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || v < (1u64 << n) as u32);
        let mut v = v as u64;
        let mut n = n;
        while n > 0 {
            if self.used == 0 {
                self.buf.push(0);
                self.used = 0;
            }
            let free = 8 - self.used;
            let take = free.min(n);
            let last = self.buf.last_mut().unwrap();
            *last |= ((v & ((1u64 << take) - 1)) as u8) << self.used;
            // used == 0 again <=> the byte is full; the next iteration
            // (or the next call) pushes a fresh byte at the loop top.
            self.used = (self.used + take) % 8;
            v >>= take;
            n -= take;
        }
    }

    /// Write a single bit.
    pub fn write_bit(&mut self, b: bool) {
        self.write(b as u32, 1);
    }

    pub fn bit_len(&self) -> usize {
        if self.buf.is_empty() {
            0
        } else {
            (self.buf.len() - 1) * 8 + if self.used == 0 { 8 } else { self.used as usize }
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Sequential bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // absolute bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `n` bits (n <= 32), LSB first. Returns None past the end.
    pub fn read(&mut self, n: u32) -> Option<u32> {
        if self.pos + n as usize > self.buf.len() * 8 {
            return None;
        }
        let mut v: u64 = 0;
        let mut got = 0;
        while got < n {
            let byte = self.buf[self.pos / 8];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(n - got);
            let bits = ((byte >> off) as u64) & ((1u64 << take) - 1);
            v |= bits << got;
            got += take;
            self.pos += take as usize;
        }
        Some(v as u32)
    }

    pub fn read_bit(&mut self) -> Option<bool> {
        self.read(1).map(|b| b != 0)
    }

    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), Some(b));
        }
    }

    #[test]
    fn mixed_widths_roundtrip() {
        let mut w = BitWriter::new();
        let vals: Vec<(u32, u32)> = vec![
            (5, 3),
            (0, 1),
            (1023, 10),
            (0xdeadbeef, 32),
            (7, 7),
            (1, 1),
            (65535, 16),
        ];
        for &(v, n) in &vals {
            w.write(v, n);
        }
        let total_bits: u32 = vals.iter().map(|&(_, n)| n).sum();
        assert_eq!(w.bit_len(), total_bits as usize);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(r.read(n), Some(v), "width {n}");
        }
    }

    #[test]
    fn random_roundtrip_property() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let mut w = BitWriter::new();
            let mut vals = Vec::new();
            for _ in 0..200 {
                let n = 1 + rng.below(32) as u32;
                let v = if n == 32 {
                    rng.next_u64() as u32
                } else {
                    (rng.next_u64() as u32) & ((1u32 << n) - 1)
                };
                w.write(v, n);
                vals.push((v, n));
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (v, n) in vals {
                assert_eq!(r.read(n), Some(v));
            }
        }
    }

    #[test]
    fn read_past_end_is_none() {
        let mut w = BitWriter::new();
        w.write(3, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(2), Some(3));
        assert_eq!(r.read(8), None); // only 6 padding bits remain
    }

    #[test]
    fn byte_len_is_minimal() {
        let mut w = BitWriter::new();
        w.write(0x1ff, 9);
        assert_eq!(w.as_bytes().len(), 2);
    }
}
