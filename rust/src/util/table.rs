//! Shared fixed-width table renderer.
//!
//! Every human-facing table in the CLI — `runs list`, `runs compare`,
//! `exp/fleet`, and the live `runs tail` / `sweep --watch` views — goes
//! through this one renderer so batch and live output stay visually
//! consistent. Columns are sized to their widest cell, separated by two
//! spaces, and aligned per column; trailing whitespace is trimmed so the
//! output is stable under diffing and greps.

/// Per-column alignment. Columns beyond the provided alignment slice
/// default to [`Align::Right`], which suits numeric data.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Align {
    Left,
    Right,
}

fn width_of(header: &[&str], rows: &[Vec<String>], col: usize) -> usize {
    let mut w = header.get(col).map(|h| h.len()).unwrap_or(0);
    for row in rows {
        if let Some(cell) = row.get(col) {
            w = w.max(cell.len());
        }
    }
    w
}

fn render_line(cells: &[String], widths: &[usize], aligns: &[Align]) -> String {
    let mut line = String::new();
    for (i, w) in widths.iter().enumerate() {
        if i > 0 {
            line.push_str("  ");
        }
        let cell = cells.get(i).map(String::as_str).unwrap_or("");
        match aligns.get(i).copied().unwrap_or(Align::Right) {
            Align::Left => {
                line.push_str(cell);
                for _ in cell.len()..*w {
                    line.push(' ');
                }
            }
            Align::Right => {
                for _ in cell.len()..*w {
                    line.push(' ');
                }
                line.push_str(cell);
            }
        }
    }
    while line.ends_with(' ') {
        line.pop();
    }
    line
}

/// Render `header` + `rows` as an aligned table. Returns the table as a
/// string with one trailing `\n` per line (including the last).
pub fn render(header: &[&str], rows: &[Vec<String>], aligns: &[Align]) -> String {
    let cols = rows
        .iter()
        .map(Vec::len)
        .chain(std::iter::once(header.len()))
        .max()
        .unwrap_or(0);
    let widths: Vec<usize> = (0..cols).map(|c| width_of(header, rows, c)).collect();
    let mut out = String::new();
    let head: Vec<String> = header.iter().map(|h| (*h).to_string()).collect();
    out.push_str(&render_line(&head, &widths, aligns));
    out.push('\n');
    for row in rows {
        out.push_str(&render_line(row, &widths, aligns));
        out.push('\n');
    }
    out
}

/// Right-align every column — the historical `print_aligned` behaviour
/// used by `runs list` / `runs compare`.
pub fn render_right(header: &[&str], rows: &[Vec<String>]) -> String {
    render(header, rows, &[])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn right_alignment_and_widths() {
        let t = render_right(
            &["a", "long"],
            &[
                vec!["1".into(), "2".into()],
                vec!["300".into(), "4".into()],
            ],
        );
        assert_eq!(t, "  a  long\n  1     2\n300     4\n");
    }

    #[test]
    fn left_columns_pad_right_and_trim_trailing() {
        let t = render(
            &["name", "n"],
            &[vec!["ab".into(), "1".into()], vec!["long".into(), "22".into()]],
            &[Align::Left],
        );
        assert_eq!(t, "name   n\nab     1\nlong  22\n");
    }

    #[test]
    fn ragged_rows_do_not_panic() {
        let t = render(&["a"], &[vec![], vec!["1".into(), "2".into()]], &[]);
        assert!(t.contains('2'));
    }
}
