//! Deterministic PRNG: xoshiro256** (Blackman & Vigna), plus the
//! sampling helpers the data generators and partitioners need.
//!
//! Every stochastic component in the system takes an explicit seed so
//! experiment runs are bit-reproducible (the paper fixes seeds and
//! averages two trials; we do the same).

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
        ];
        Rng { s }
    }

    /// Derive an independent stream (client id, dataset id, ...).
    pub fn fork(&self, stream: u64) -> Self {
        // mix the stream id through splitmix so forks decorrelate
        let mut x = self.s[0] ^ stream.wrapping_mul(0x9e3779b97f4a7c15);
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x) ^ self.s[1],
            splitmix64(&mut x) ^ self.s[2],
            splitmix64(&mut x) ^ self.s[3],
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's rejection-free-enough mapping; bias < 2^-32 for our n
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn normal(&mut self) -> f32 {
        // do not cache across calls: keeps `fork` semantics simple
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape > 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum::<f64>().max(1e-300);
        for v in &mut g {
            *v /= s;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    /// Sample from a discrete distribution given (unnormalized) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_decorrelates() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentration() {
        let mut r = Rng::new(9);
        let p = r.dirichlet(0.5, 10);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x >= 0.0));
        // high alpha -> near-uniform
        let q = r.dirichlet(1000.0, 10);
        for &x in &q {
            assert!((x - 0.1).abs() < 0.05);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(13);
        let picked = r.choose(20, 5);
        assert_eq!(picked.len(), 5);
        for w in picked.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "{frac}");
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(19);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.gamma(2.5)).sum::<f64>() / n as f64;
        assert!((m - 2.5).abs() < 0.1, "{m}");
    }
}
