//! Tiny leveled logger writing to stderr; level set via `FEDCOMPRESS_LOG`
//! (error|warn|info|debug, default info). Keeps the hot path clean: all
//! macros compile to a level check + formatted write.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

pub fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("FEDCOMPRESS_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => 0,
                "warn" => 1,
                "info" => 2,
                "debug" => 3,
                _ => 2,
            };
            LEVEL.store(lvl, Ordering::Relaxed);
        }
    });
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) }
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) }
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) }
}
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
