//! Panic-free little-endian byte cursor — the shared substrate under
//! every wire/record decode path.
//!
//! Decoders that face adversarial bytes (`net/frame`, `net/proto`, the
//! codec terminal formats) must never panic on any input — the fedlint
//! rule `no-panic-decode` enforces that statically. This cursor is the
//! bounds-checked primitive they build on: every accessor returns
//! `Option`, `None` meaning the input ran out, and the caller maps
//! `None` onto its own typed truncation error (`ProtoError::Truncated`,
//! `CodecError::Truncated`, ...). All multi-byte reads are
//! little-endian, matching the wire format everywhere in this crate.

/// A forward-only reader over a byte slice. Never panics: out-of-range
/// reads (including position arithmetic that would overflow `usize`)
/// return `None` and leave the cursor where it was.
pub struct ByteCursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> ByteCursor<'a> {
    pub fn new(b: &'a [u8]) -> ByteCursor<'a> {
        ByteCursor { b, i: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.b.len().saturating_sub(self.i)
    }

    /// True once every byte has been consumed.
    pub fn done(&self) -> bool {
        self.remaining() == 0
    }

    /// Take the next `n` bytes, or `None` if fewer remain.
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.i.checked_add(n)?;
        let s = self.b.get(self.i..end)?;
        self.i = end;
        Some(s)
    }

    /// Take a fixed-width array off the front.
    pub fn array<const N: usize>(&mut self) -> Option<[u8; N]> {
        let s = self.take(N)?;
        <[u8; N]>::try_from(s).ok()
    }

    pub fn u8(&mut self) -> Option<u8> {
        self.array::<1>().map(|[b]| b)
    }

    pub fn u16(&mut self) -> Option<u16> {
        self.array().map(u16::from_le_bytes)
    }

    pub fn u32(&mut self) -> Option<u32> {
        self.array().map(u32::from_le_bytes)
    }

    pub fn u64(&mut self) -> Option<u64> {
        self.array().map(u64::from_le_bytes)
    }

    pub fn f32(&mut self) -> Option<f32> {
        self.array().map(f32::from_le_bytes)
    }

    pub fn f64(&mut self) -> Option<f64> {
        self.array().map(f64::from_le_bytes)
    }

    /// Everything left, consuming it (empty slice at the end).
    pub fn rest(&mut self) -> &'a [u8] {
        let s = self.b.get(self.i..).unwrap_or_default();
        self.i = self.b.len();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_every_width_in_order() {
        let mut buf = Vec::new();
        buf.push(7u8);
        buf.extend_from_slice(&0xBEEFu16.to_le_bytes());
        buf.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        buf.extend_from_slice(&0x0123_4567_89AB_CDEFu64.to_le_bytes());
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        buf.extend_from_slice(&(-0.25f64).to_le_bytes());
        buf.extend_from_slice(b"tail");

        let mut c = ByteCursor::new(&buf);
        assert_eq!(c.u8(), Some(7));
        assert_eq!(c.u16(), Some(0xBEEF));
        assert_eq!(c.u32(), Some(0xDEAD_BEEF));
        assert_eq!(c.u64(), Some(0x0123_4567_89AB_CDEF));
        assert_eq!(c.f32(), Some(1.5));
        assert_eq!(c.f64(), Some(-0.25));
        assert_eq!(c.rest(), b"tail");
        assert!(c.done());
        assert_eq!(c.rest(), b"");
    }

    #[test]
    fn truncation_returns_none_and_does_not_advance() {
        let mut c = ByteCursor::new(&[1, 2, 3]);
        assert_eq!(c.u32(), None);
        assert_eq!(c.remaining(), 3, "failed read must not consume");
        assert_eq!(c.u16(), Some(0x0201));
        assert_eq!(c.take(2), None);
        assert_eq!(c.take(1), Some(&[3u8][..]));
        assert!(c.done());
        assert_eq!(c.u8(), None);
    }

    #[test]
    fn huge_take_is_overflow_safe() {
        let mut c = ByteCursor::new(&[0; 8]);
        assert_eq!(c.u32(), Some(0));
        // i + usize::MAX would overflow; must be None, not a panic
        assert_eq!(c.take(usize::MAX), None);
        assert_eq!(c.remaining(), 4);
    }
}
