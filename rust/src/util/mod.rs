//! Foundation substrates built in-repo (the offline registry vendors
//! only the `xla` dependency tree — no serde/tokio/clap/etc.).

pub mod bitio;
pub mod csv;
pub mod cursor;
pub mod hash;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod suggest;
pub mod table;
pub mod timer;
pub mod threadpool;
