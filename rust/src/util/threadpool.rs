//! Fixed-size thread pool with a scoped parallel-map — the execution
//! substrate for simulated clients (tokio is not in the vendored set).
//!
//! `scope_map` runs a closure over a slice of work items on N worker
//! threads and returns results in input order; panics in workers are
//! propagated to the caller.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `f` over `0..n` on up to `workers` threads; results in index order.
///
/// `f` must be Sync (shared by reference across workers). This is a
/// scoped-parallelism helper rather than a persistent pool: client-round
/// granularity is coarse (each item runs many PJRT executions), so
/// thread spawn cost is noise, and scoping keeps lifetimes simple.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n);
    let next = Arc::new(Mutex::new(0usize));
    let (tx, rx) = mpsc::channel::<(usize, T)>();

    thread::scope(|s| {
        for _ in 0..workers {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let f = &f;
            s.spawn(move || loop {
                let i = {
                    let mut g = next.lock().unwrap();
                    if *g >= n {
                        return;
                    }
                    let i = *g;
                    *g += 1;
                    i
                };
                let out = f(i);
                if tx.send((i, out)).is_err() {
                    return;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            slots[i] = Some(v);
        }
        slots
            .into_iter()
            .map(|s| s.expect("worker panicked before producing a result"))
            .collect()
    })
}

/// Number of workers to use by default: physical parallelism, capped.
pub fn default_workers() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_items_run_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = parallel_map(500, 7, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
            1usize
        });
        assert_eq!(counter.load(Ordering::SeqCst), 500);
        assert_eq!(out.iter().sum::<usize>(), 500);
    }

    #[test]
    fn single_worker_degrades_to_sequential() {
        let out = parallel_map(10, 1, |i| i);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        parallel_map(8, 4, |i| {
            if i == 3 {
                panic!("boom");
            }
            i
        });
    }
}
