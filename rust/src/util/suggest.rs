//! Closest-name suggestion shared by the name registries
//! (`baselines::StrategyRegistry`, `codec::CodecRegistry`): plain
//! Levenshtein distance plus the "plausibly a typo" cutoff, extracted
//! so every `--foo list`-style surface reports unknown names the same
//! way instead of copy-pasting the edit-distance machinery.

/// Plain O(nm) Levenshtein edit distance (registry names are short).
pub fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest candidate by edit distance, if plausibly a typo of `name`
/// (distance <= half the query length, minimum 1). Ties resolve to the
/// earliest candidate, so registration order is the tiebreak.
pub fn closest<'a>(name: &str, candidates: impl Iterator<Item = &'a str>) -> Option<&'a str> {
    let mut best: Option<(usize, &'a str)> = None;
    for cand in candidates {
        let d = levenshtein(name, cand);
        let better = match best {
            None => true,
            Some((bd, _)) => d < bd,
        };
        if better {
            best = Some((d, cand));
        }
    }
    let (d, cand) = best?;
    (d <= (name.len() / 2).max(1)).then_some(cand)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("fedzip", "fedavg"), 3);
        assert_eq!(levenshtein("topk", "top-k"), 1);
    }

    #[test]
    fn closest_applies_the_typo_cutoff() {
        let names = ["dense", "topk", "kmeans", "huffman"];
        assert_eq!(closest("kmean", names.iter().copied()), Some("kmeans"));
        assert_eq!(closest("hufman", names.iter().copied()), Some("huffman"));
        // nothing plausibly close
        assert_eq!(closest("zstd", names.iter().copied()), None);
        // empty candidate set
        assert_eq!(closest("x", [].iter().copied()), None);
    }
}
