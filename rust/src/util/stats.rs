//! Streaming statistics + small analytic helpers shared by the metrics
//! pipeline, the dynamic-C controller and the experiment drivers.

/// Welford online mean/variance.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Fixed-window moving average (the paper's MA over window W for the
/// representation-quality score).
#[derive(Clone, Debug)]
pub struct MovingAverage {
    window: usize,
    buf: Vec<f64>,
}

impl MovingAverage {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        MovingAverage {
            window,
            buf: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.buf.push(x);
    }

    /// MA at the latest point (mean of up to `window` trailing values);
    /// None until at least one value has been pushed.
    pub fn current(&self) -> Option<f64> {
        self.at(self.buf.len().checked_sub(1)?)
    }

    /// MA ending at index i (inclusive).
    pub fn at(&self, i: usize) -> Option<f64> {
        if i >= self.buf.len() {
            return None;
        }
        let start = (i + 1).saturating_sub(self.window);
        let slice = &self.buf[start..=i];
        Some(slice.iter().sum::<f64>() / slice.len() as f64)
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Pearson correlation coefficient (used to reproduce Figure 2's
/// score<->accuracy correlation claim).
///
/// Degenerate inputs — fewer than two points, zero variance in either
/// series, or any non-finite sample — return 0.0 rather than letting a
/// NaN propagate into downstream tables and CSVs: "no measurable
/// correlation" is the honest report for all of them.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    if xs.iter().chain(ys).any(|v| !v.is_finite()) {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    let r = sxy / (sxx * syy).sqrt();
    if r.is_finite() {
        r
    } else {
        0.0
    }
}

/// Percentile of a sample (linear interpolation, p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    // total_cmp: NaN-bearing samples sort to the end instead of panicking
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_two_pass() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn moving_average_window() {
        let mut ma = MovingAverage::new(3);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            ma.push(x);
        }
        assert_eq!(ma.current(), Some(4.0)); // (3+4+5)/3
        assert_eq!(ma.at(0), Some(1.0));
        assert_eq!(ma.at(1), Some(1.5));
        assert_eq!(ma.at(2), Some(2.0));
        assert_eq!(ma.at(9), None);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0, 5.0, 5.0, 5.0]), 0.0);
    }

    /// Degenerate inputs must never leak NaN into figure/table output.
    #[test]
    fn pearson_degenerate_inputs_return_zero() {
        // zero variance on either side
        assert_eq!(pearson(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[1.0, 2.0, 3.0], &[3.0, 3.0, 3.0]), 0.0);
        // NaN / infinity in the samples
        assert_eq!(pearson(&[1.0, f64::NAN, 3.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[1.0, 2.0], &[f64::INFINITY, 0.0]), 0.0);
        // too few points
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
        assert_eq!(pearson(&[], &[]), 0.0);
        // and the guard never fires on healthy data
        assert!(pearson(&[1.0, 2.0, 4.0], &[1.0, 3.0, 2.0]).is_finite());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }
}
