//! Minimal-but-complete JSON: recursive-descent parser + writer.
//!
//! Substrate for the AOT manifest (`artifacts/manifest.json`), golden
//! indexes, experiment configs and result dumps — serde is not in the
//! vendored crate set. Supports the full JSON grammar (RFC 8259):
//! objects, arrays, strings with escapes/\uXXXX, numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.b[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate");
                                }
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // consume the full utf-8 sequence starting at c
                    let start = self.i - 1;
                    let len = utf8_len(c)?;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| anyhow!("utf-8: {e}"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        self.i += 4;
        u32::from_str_radix(s, 16).map_err(|e| anyhow!("hex: {e}"))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7f => Ok(1),
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => bail!("invalid utf-8 lead byte {first:#x}"),
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(e, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(e, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u00e9\\uD83D\\uDE00\"").unwrap(),
            Json::Str("é😀".into())
        );
        // raw multibyte passthrough
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"a\"b","t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "flag": false, "xs": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 7);
        assert!(!v.get("flag").unwrap().as_bool().unwrap());
        assert_eq!(v.get("xs").unwrap().usize_array().unwrap(), vec![1, 2]);
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
    }
}
