//! FNV-1a hashing — the content-address substrate shared by the
//! checkpoint checksum and the run store's record keys. 64-bit FNV-1a
//! is not cryptographic; it is a fast, stable fingerprint for
//! detecting corruption and addressing identical experiment configs.

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

/// Streaming FNV-1a, for hashing multi-part keys without
/// concatenation.
#[derive(Clone, Debug)]
pub struct Fnv1a {
    state: u64,
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a {
            state: 0xcbf29ce484222325,
        }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.state = h;
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let mut h = Fnv1a::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), fnv1a64(&data));
    }

    #[test]
    fn distinct_inputs_distinct_hashes() {
        assert_ne!(fnv1a64(b"fedavg"), fnv1a64(b"fedzip"));
        assert_ne!(fnv1a64(&[0, 1]), fnv1a64(&[1, 0]));
    }
}
