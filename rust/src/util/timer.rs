//! The sanctioned wall-clock boundary. Every monotonic or calendar
//! clock read in `src/` goes through this module — fedlint's
//! `no-wallclock-state` rule covers the whole source tree, so the two
//! `::now` calls below carry the only standing allows outside tests.
//!
//! Centralising the reads keeps the determinism contract reviewable:
//! timer values may feed *live-only* surfaces (phase-timing ops
//! events, bench rows, log lines) and the environment fields that
//! `diff_records` already excludes (`wall_ms`, `wall_s`,
//! `created_unix`). They must never reach canonical events, round
//! metrics content, records, or anything hashed into a run key. The
//! lint cannot check that flow transitively — the narrow waist plus
//! review does.

use std::time::Instant;

/// Monotonic clock read — the only `Instant::now` site in `src/`.
///
/// Callers that need an `Instant` value (e.g. the mux's per-connection
/// inactivity clock) take it from here; callers that just measure a
/// span should prefer [`Stopwatch`].
pub fn now() -> Instant {
    // fedlint:allow(no-wallclock-state) -- the sanctioned monotonic read; values are live-only by contract
    Instant::now()
}

/// Calendar clock read in whole seconds since the Unix epoch — the
/// only `SystemTime::now` site in `src/`. Feeds `created_unix`-style
/// environment fields only.
pub fn unix_now_s() -> u64 {
    // fedlint:allow(no-wallclock-state) -- the sanctioned calendar read; feeds excluded environment fields only
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Span measurement over the sanctioned monotonic clock. `start()`,
/// then read an elapsed view; `lap_ns()` additionally resets the
/// origin so consecutive laps tile a timeline into phases.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { t0: now() }
    }

    pub fn elapsed_ns(&self) -> u64 {
        let ns = self.t0.elapsed().as_nanos();
        u64::try_from(ns).unwrap_or(u64::MAX)
    }

    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Nanoseconds since start (or the previous lap), then restart.
    pub fn lap_ns(&mut self) -> u64 {
        let ns = self.elapsed_ns();
        self.t0 = now();
        ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_is_monotone() {
        let mut sw = Stopwatch::start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
        let lap = sw.lap_ns();
        assert!(lap >= b);
        // origin reset: the next reading restarts near zero
        assert!(sw.elapsed_ns() <= lap.max(1_000_000_000));
    }

    #[test]
    fn unix_now_is_after_2020() {
        assert!(unix_now_s() > 1_577_836_800);
    }
}
