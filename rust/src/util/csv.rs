//! Minimal CSV writer/reader (RFC 4180 quoting) for experiment series
//! (figure CSVs, result dumps). Reader handles quoted fields, embedded
//! commas/quotes/newlines.
//!
//! `render`/`write_file` are the one shared table writer every
//! experiment driver and the run store's `runs show --csv` path use —
//! there is exactly one place CSV gets emitted from.

use std::path::Path;

use anyhow::{bail, Context, Result};

pub fn escape_field(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

pub fn write_row(out: &mut String, fields: &[&str]) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&escape_field(f));
    }
    out.push('\n');
}

/// Render a header + data rows as one CSV document (RFC 4180 quoting).
pub fn render(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    write_row(&mut out, header);
    for row in rows {
        let fields: Vec<&str> = row.iter().map(|s| s.as_str()).collect();
        write_row(&mut out, &fields);
    }
    out
}

/// Write a header + data rows to a CSV file (parent directories are
/// created if missing).
pub fn write_file(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {parent:?}"))?;
        }
    }
    std::fs::write(path, render(header, rows)).with_context(|| format!("writing {path:?}"))
}

/// Parse CSV text into rows of fields.
pub fn parse(text: &str) -> Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    if !field.is_empty() {
                        bail!("quote inside unquoted field");
                    }
                    in_quotes = true;
                }
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                '\r' => {} // tolerate CRLF
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        bail!("unterminated quoted field");
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut s = String::new();
        write_row(&mut s, &["round", "score", "acc"]);
        write_row(&mut s, &["0", "4.5", "0.31"]);
        let rows = parse(&s).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["0", "4.5", "0.31"]);
    }

    #[test]
    fn quoting_round_trip() {
        let mut s = String::new();
        write_row(&mut s, &["a,b", "he said \"hi\"", "multi\nline"]);
        let rows = parse(&s).unwrap();
        assert_eq!(rows[0][0], "a,b");
        assert_eq!(rows[0][1], "he said \"hi\"");
        assert_eq!(rows[0][2], "multi\nline");
    }

    #[test]
    fn crlf_tolerated() {
        let rows = parse("a,b\r\nc,d\r\n").unwrap();
        assert_eq!(rows, vec![vec!["a", "b"], vec!["c", "d"]]);
    }

    #[test]
    fn malformed_rejected() {
        assert!(parse("a\"b,c\n").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_input() {
        assert!(parse("").unwrap().is_empty());
    }

    #[test]
    fn render_and_write_file_round_trip() {
        let rows = vec![
            vec!["0".to_string(), "4.5".to_string(), "plain".to_string()],
            vec!["1".to_string(), "2.25".to_string(), "quo\"ted,x".to_string()],
        ];
        let text = render(&["round", "score", "note"], &rows);
        let parsed = parse(&text).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0], vec!["round", "score", "note"]);
        assert_eq!(parsed[2][2], "quo\"ted,x");

        let dir = std::env::temp_dir().join("fedcompress_csv_test/nested");
        let path = dir.join("out.csv");
        let _ = std::fs::remove_dir_all(&dir);
        write_file(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let back = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, vec![vec!["a", "b"], vec!["1", "2"]]);
    }
}
