//! Fleet scenario table: every registered strategy under named fleet
//! presets, compared on rounds-to-accuracy and *simulated*
//! time-to-accuracy — the question the paper's bytes-only evaluation
//! cannot answer ("what does compression buy in round wall-clock when
//! clients sit on 5 Mbps uplinks and 10% of them drop?").
//!
//! All runs share one federated data environment (paired comparison,
//! seeds fixed); the accuracy target is derived post-hoc as 90% of the
//! best *final* accuracy over the whole table. Rows that never reach it
//! during training print "-" (possible when a strategy's finalize-time
//! fit beats every per-round accuracy, or under heavy faults).
//!
//! Like `table1`, the driver computes from [`RunRecord`]s: attach a
//! [`RunStore`] (`fleet --store <dir>`) and completed strategy x
//! preset runs load by content key instead of re-executing — the same
//! seed + preset always reproduces the identical table.

use anyhow::Result;

use crate::baselines::registry::StrategyRegistry;
use crate::config::FedConfig;
use crate::coordinator::server::build_data;
use crate::runtime::Engine;
use crate::sim::FleetPreset;
use crate::store::{run_key, RunStore};
use crate::sweep::{run_or_cached, verify_cached, CacheStats};
use crate::util::table::{self, Align};

#[derive(Clone, Debug, PartialEq)]
pub struct FleetRow {
    pub fleet: &'static str,
    pub strategy: &'static str,
    pub final_acc: f64,
    /// first round reaching the table's accuracy target (None = never)
    pub rounds_to_target: Option<usize>,
    /// cumulative simulated seconds to that round
    pub sim_s_to_target: Option<f64>,
    /// total simulated run time, seconds
    pub total_sim_s: f64,
    pub total_mb: f64,
    pub dropped: usize,
    pub stragglers: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct FleetTable {
    pub target_acc: f64,
    pub rows: Vec<FleetRow>,
}

/// Fraction of the table's best final accuracy used as the
/// time-to-accuracy target.
const TARGET_FRACTION: f64 = 0.9;

/// Run every registered strategy under each preset. `cfg.fleet.dropout`
/// and `cfg.fleet.deadline_s` apply to all presets; `cfg.fleet.preset`
/// is overridden per table row.
pub fn run(engine: &Engine, cfg: &FedConfig, presets: &[FleetPreset]) -> Result<FleetTable> {
    run_cached(engine, cfg, presets, None).map(|(t, _)| t)
}

/// Store-backed variant: every strategy x preset run is loaded from
/// `store` on a content-key hit and appended on a miss.
pub fn run_cached(
    engine: &Engine,
    cfg: &FedConfig,
    presets: &[FleetPreset],
    mut store: Option<&mut RunStore>,
) -> Result<(FleetTable, CacheStats)> {
    let reg = StrategyRegistry::builtin();
    let mut stats = CacheStats::default();

    // the full strategy x preset plan, each with its resolved config
    let mut plan: Vec<(FleetPreset, &'static str, FedConfig)> = Vec::new();
    for &preset in presets {
        let mut fleet_cfg = cfg.clone();
        fleet_cfg.fleet.preset = preset;
        for name in reg.names() {
            plan.push((preset, name, fleet_cfg.clone()));
        }
    }

    // cache-only fast path: a fully stored table never materializes
    // the dataset or touches the engine
    let all_cached = store
        .as_deref()
        .is_some_and(|s| plan.iter().all(|(_, n, c)| s.contains(run_key(n, c))));
    let mut runs = Vec::new();
    if all_cached {
        let store = store.as_deref_mut().expect("all_cached implies a store");
        for (preset, name, c) in &plan {
            let rec = store.get(run_key(name, c))?.expect("contains-checked");
            verify_cached(&rec, name, c)?;
            stats.note(true);
            runs.push((*preset, *name, rec));
        }
    } else {
        let data = build_data(engine, cfg)?;
        for (preset, name, c) in &plan {
            let (rec, hit) = run_or_cached(engine, c, name, &data, store.as_deref_mut())?;
            stats.note(hit);
            runs.push((*preset, *name, rec));
        }
        if let Some(store) = store.as_deref() {
            store.flush_sidecar()?;
        }
    }

    let best = runs
        .iter()
        .map(|(_, _, r)| r.final_accuracy)
        .fold(f64::MIN, f64::max);
    let target_acc = TARGET_FRACTION * best;

    let rows = runs
        .into_iter()
        .map(|(preset, name, r)| {
            let hit = r.time_to_accuracy(target_acc);
            FleetRow {
                fleet: preset.name(),
                strategy: name,
                final_acc: r.final_accuracy,
                rounds_to_target: hit.map(|(round, _)| round + 1),
                sim_s_to_target: hit.map(|(_, ms)| ms / 1e3),
                total_sim_s: r.total_sim_ms() / 1e3,
                total_mb: r.total_bytes() as f64 / 1e6,
                dropped: r.total_dropped(),
                stragglers: r.total_stragglers(),
            }
        })
        .collect();
    Ok((FleetTable { target_acc, rows }, stats))
}

pub fn print_table(t: &FleetTable) {
    let header = [
        "fleet",
        "strategy",
        "final_acc",
        "r@tgt",
        "sim_s@tgt",
        "sim_s_tot",
        "comm_MB",
        "drop",
        "strag",
    ];
    let aligns = [
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ];
    let rows: Vec<Vec<String>> = t
        .rows
        .iter()
        .map(|r| {
            let r_tgt = r
                .rounds_to_target
                .map(|n| n.to_string())
                .unwrap_or_else(|| "-".into());
            let s_tgt = r
                .sim_s_to_target
                .map(|s| format!("{s:.1}"))
                .unwrap_or_else(|| "-".into());
            vec![
                r.fleet.to_string(),
                r.strategy.to_string(),
                format!("{:.4}", r.final_acc),
                r_tgt,
                s_tgt,
                format!("{:.1}", r.total_sim_s),
                format!("{:.2}", r.total_mb),
                r.dropped.to_string(),
                r.stragglers.to_string(),
            ]
        })
        .collect();
    print!("{}", table::render(&header, &rows, &aligns));
    println!(
        "target accuracy: {:.4} ({:.0}% of best final)",
        t.target_acc,
        TARGET_FRACTION * 100.0
    );
}
