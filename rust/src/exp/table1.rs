//! Table 1: delta-accuracy / CCR / MCR for FedZip, FedCompress w/o SCS
//! and FedCompress versus the FedAvg baseline, per dataset.
//!
//! Prints the same row layout as the paper; CCR/MCR are n-fold
//! reductions vs FedAvg. All four strategies share one federated data
//! environment per dataset (paired comparison, seeds fixed).
//!
//! The driver computes from [`RunRecord`]s, not live `RunResult`s:
//! with a [`RunStore`] attached (`table1 --store <dir>`), previously
//! completed runs are loaded by content key instead of re-executed,
//! and fresh runs are persisted for the next invocation.

use anyhow::Result;
use std::path::Path;

use crate::compression::accounting::ccr;
use crate::config::FedConfig;
use crate::coordinator::server::build_data;
use crate::runtime::Engine;
use crate::store::{run_key, RunRecord, RunStore};
use crate::sweep::{run_or_cached, verify_cached, CacheStats};
use crate::util::csv;

/// The paper's four columns, in presentation order (FedAvg first: it is
/// the CCR/MCR denominator for the others).
pub const COLUMNS: [&str; 4] = ["fedavg", "fedzip", "fedcompress-noscs", "fedcompress"];

#[derive(Clone, Debug, PartialEq)]
pub struct Table1Row {
    pub dataset: String,
    pub fedavg_acc: f64,
    /// per strategy (FedZip, NoSCS, FedCompress): (delta_acc_pp, ccr, mcr)
    pub entries: Vec<(&'static str, f64, f64, f64)>,
}

pub fn run_dataset(engine: &Engine, cfg: &FedConfig) -> Result<Table1Row> {
    run_dataset_cached(engine, cfg, None).map(|(row, _)| row)
}

/// Store-backed variant: each of the four runs is loaded from `store`
/// when its content key already has a record, and appended when not.
pub fn run_dataset_cached(
    engine: &Engine,
    cfg: &FedConfig,
    mut store: Option<&mut RunStore>,
) -> Result<(Table1Row, CacheStats)> {
    let mut stats = CacheStats::default();
    let mut records: Vec<RunRecord> = Vec::with_capacity(COLUMNS.len());
    // cache-only fast path: when every strategy's record is stored,
    // the dataset is never materialized and the engine never touched
    let all_cached = store
        .as_deref()
        .is_some_and(|s| COLUMNS.iter().all(|st| s.contains(run_key(st, cfg))));
    if all_cached {
        let store = store.as_deref_mut().expect("all_cached implies a store");
        for strategy in COLUMNS {
            let rec = store.get(run_key(strategy, cfg))?.expect("contains-checked");
            verify_cached(&rec, strategy, cfg)?;
            stats.note(true);
            records.push(rec);
        }
    } else {
        let data = build_data(engine, cfg)?;
        for strategy in COLUMNS {
            let (rec, hit) = run_or_cached(engine, cfg, strategy, &data, store.as_deref_mut())?;
            stats.note(hit);
            records.push(rec);
        }
        if let Some(store) = store.as_deref() {
            store.flush_sidecar()?;
        }
    }
    let fedavg = &records[0];
    let entries = records[1..]
        .iter()
        .zip(&COLUMNS[1..])
        .map(|(r, &name)| {
            (
                name,
                (r.final_accuracy - fedavg.final_accuracy) * 100.0,
                ccr(&fedavg.ledger, &r.ledger),
                r.mcr(),
            )
        })
        .collect();
    let row = Table1Row {
        dataset: cfg.dataset.clone(),
        fedavg_acc: fedavg.final_accuracy * 100.0,
        entries,
    };
    Ok((row, stats))
}

pub fn print_header() {
    println!(
        "{:<16} {:>8} | {:>22} | {:>22} | {:>22}",
        "Dataset", "FedAvg", "FedZip", "FedCompress w/o SCS", "FedCompress"
    );
    println!(
        "{:<16} {:>8} | {:>7} {:>6} {:>6}  | {:>7} {:>6} {:>6}  | {:>7} {:>6} {:>6}",
        "", "Acc", "dAcc", "CCR", "MCR", "dAcc", "CCR", "MCR", "dAcc", "CCR", "MCR"
    );
}

pub fn print_row(row: &Table1Row) {
    print!("{:<16} {:>8.2} |", row.dataset, row.fedavg_acc);
    for (_, dacc, c, m) in &row.entries {
        print!(" {:>+7.2} {:>6.2} {:>6.2}  |", dacc, c, m);
    }
    println!();
}

/// Long-format CSV (one line per dataset x strategy) through the
/// shared `util::csv` writer.
pub fn write_csv(rows: &[Table1Row], path: &Path) -> Result<()> {
    let header = ["dataset", "fedavg", "strategy", "dacc_pp", "ccr", "mcr"];
    let mut out = Vec::new();
    for row in rows {
        for (name, dacc, c, m) in &row.entries {
            out.push(vec![
                row.dataset.clone(),
                format!("{:.4}", row.fedavg_acc),
                name.to_string(),
                format!("{dacc:.4}"),
                format!("{c:.4}"),
                format!("{m:.4}"),
            ]);
        }
    }
    csv::write_file(path, &header, &out)
}

/// Aggregate line the paper quotes ("average 4.5-fold CCR").
pub fn print_summary(rows: &[Table1Row]) {
    if rows.is_empty() {
        return;
    }
    let n = rows.len() as f64;
    for (i, name) in ["fedzip", "fedcompress-noscs", "fedcompress"]
        .iter()
        .enumerate()
    {
        let mean_ccr: f64 = rows.iter().map(|r| r.entries[i].2).sum::<f64>() / n;
        let mean_mcr: f64 = rows.iter().map(|r| r.entries[i].3).sum::<f64>() / n;
        let mean_dacc: f64 = rows.iter().map(|r| r.entries[i].1).sum::<f64>() / n;
        println!(
            "mean[{name}]: dAcc={mean_dacc:+.2}pp CCR={mean_ccr:.2} MCR={mean_mcr:.2}"
        );
    }
}
