//! Table 1: delta-accuracy / CCR / MCR for FedZip, FedCompress w/o SCS
//! and FedCompress versus the FedAvg baseline, per dataset.
//!
//! Prints the same row layout as the paper; CCR/MCR are n-fold
//! reductions vs FedAvg. All four strategies share one federated data
//! environment per dataset (paired comparison, seeds fixed).

use anyhow::Result;

use crate::compression::accounting::ccr;
use crate::config::FedConfig;
use crate::coordinator::server::{build_data, run_federated_with_data};
use crate::coordinator::RunResult;
use crate::runtime::Engine;

/// The paper's four columns, in presentation order (FedAvg first: it is
/// the CCR/MCR denominator for the others).
pub const COLUMNS: [&str; 4] = ["fedavg", "fedzip", "fedcompress-noscs", "fedcompress"];

#[derive(Clone, Debug)]
pub struct Table1Row {
    pub dataset: String,
    pub fedavg_acc: f64,
    /// per strategy (FedZip, NoSCS, FedCompress): (delta_acc_pp, ccr, mcr)
    pub entries: Vec<(&'static str, f64, f64, f64)>,
}

pub fn run_dataset(engine: &Engine, cfg: &FedConfig) -> Result<Table1Row> {
    let data = build_data(engine, cfg)?;
    let mut results: Vec<RunResult> = Vec::new();
    for strategy in COLUMNS {
        results.push(run_federated_with_data(engine, cfg, strategy, &data)?);
    }
    let fedavg = &results[0];
    let entries = results[1..]
        .iter()
        .map(|r| {
            (
                r.strategy,
                (r.final_accuracy - fedavg.final_accuracy) * 100.0,
                ccr(&fedavg.ledger, &r.ledger),
                r.mcr(),
            )
        })
        .collect();
    Ok(Table1Row {
        dataset: cfg.dataset.clone(),
        fedavg_acc: fedavg.final_accuracy * 100.0,
        entries,
    })
}

pub fn print_header() {
    println!(
        "{:<16} {:>8} | {:>22} | {:>22} | {:>22}",
        "Dataset", "FedAvg", "FedZip", "FedCompress w/o SCS", "FedCompress"
    );
    println!(
        "{:<16} {:>8} | {:>7} {:>6} {:>6}  | {:>7} {:>6} {:>6}  | {:>7} {:>6} {:>6}",
        "", "Acc", "dAcc", "CCR", "MCR", "dAcc", "CCR", "MCR", "dAcc", "CCR", "MCR"
    );
}

pub fn print_row(row: &Table1Row) {
    print!("{:<16} {:>8.2} |", row.dataset, row.fedavg_acc);
    for (_, dacc, c, m) in &row.entries {
        print!(" {:>+7.2} {:>6.2} {:>6.2}  |", dacc, c, m);
    }
    println!();
}

/// Aggregate line the paper quotes ("average 4.5-fold CCR").
pub fn print_summary(rows: &[Table1Row]) {
    if rows.is_empty() {
        return;
    }
    let n = rows.len() as f64;
    for (i, name) in ["fedzip", "fedcompress-noscs", "fedcompress"]
        .iter()
        .enumerate()
    {
        let mean_ccr: f64 = rows.iter().map(|r| r.entries[i].2).sum::<f64>() / n;
        let mean_mcr: f64 = rows.iter().map(|r| r.entries[i].3).sum::<f64>() / n;
        let mean_dacc: f64 = rows.iter().map(|r| r.entries[i].1).sum::<f64>() / n;
        println!(
            "mean[{name}]: dAcc={mean_dacc:+.2}pp CCR={mean_ccr:.2} MCR={mean_mcr:.2}"
        );
    }
}
