//! Figure 2: per-round mean representation-quality score vs mean
//! validation accuracy across clients during FedCompress training, plus
//! their Pearson correlation (the paper claims a strong positive one).

use anyhow::Result;

use crate::config::FedConfig;
use crate::coordinator::{run_federated, RunResult};
use crate::runtime::Engine;
use crate::util::csv;
use crate::util::stats::pearson;

pub struct Figure2Series {
    pub dataset: String,
    pub rounds: Vec<usize>,
    pub score: Vec<f64>,
    pub accuracy: Vec<f64>,
    pub correlation: f64,
}

pub fn run(engine: &Engine, cfg: &FedConfig) -> Result<Figure2Series> {
    let result: RunResult = run_federated(engine, cfg, "fedcompress")?;
    let score: Vec<f64> = result.rounds.iter().map(|r| r.score).collect();
    let accuracy: Vec<f64> = result.rounds.iter().map(|r| r.accuracy).collect();
    let correlation = pearson(&score, &accuracy);
    Ok(Figure2Series {
        dataset: cfg.dataset.clone(),
        rounds: (0..result.rounds.len()).collect(),
        score,
        accuracy,
        correlation,
    })
}

pub fn write_csv(series: &Figure2Series, path: &std::path::Path) -> Result<()> {
    let rows: Vec<Vec<String>> = (0..series.rounds.len())
        .map(|i| {
            vec![
                series.rounds[i].to_string(),
                format!("{:.6}", series.score[i]),
                format!("{:.6}", series.accuracy[i]),
            ]
        })
        .collect();
    csv::write_file(path, &["round", "score", "accuracy"], &rows)
}

pub fn print_series(s: &Figure2Series) {
    println!("figure2[{}]: Pearson r = {:.3}", s.dataset, s.correlation);
    println!("{:>5} {:>10} {:>10}", "round", "score E", "val acc");
    for i in 0..s.rounds.len() {
        println!("{:>5} {:>10.3} {:>10.4}", s.rounds[i], s.score[i], s.accuracy[i]);
    }
}
