//! Experiment drivers: one per paper table/figure (DESIGN.md §4), plus
//! the fleet scenario table (`fleet`, beyond the paper).
//!
//! Every driver runs under the config's codec pipeline override when
//! one is set (`--codec` / `--axis codec=`): run-store content keys
//! cover the spec, so cached rows never mix pipelines, and the CLI
//! prints the [`codec_banner`] so a table is never misread as the
//! strategies' declared defaults.

pub mod figure2;
pub mod fleet;
pub mod table1;
pub mod table2;

use crate::config::FedConfig;

/// One-line banner naming the active codec override, if any — printed
/// by the table drivers so compressed-variant tables are labeled.
pub fn codec_banner(cfg: &FedConfig) -> Option<String> {
    (!cfg.codec.is_empty()).then(|| format!("codec override: {}", cfg.codec))
}
