//! Experiment drivers: one per paper table/figure (DESIGN.md §4), plus
//! the fleet scenario table (`fleet`, beyond the paper).

pub mod figure2;
pub mod fleet;
pub mod table1;
pub mod table2;
