//! Experiment drivers: one per paper table/figure (DESIGN.md §4).

pub mod figure2;
pub mod table1;
pub mod table2;
