//! Table 2: inference-time acceleration of the clustered model over the
//! dense FedAvg model on three edge-device profiles, f32 and uint8.
//!
//! Evaluated on the paper's deployment-scale models (ResNet-20,
//! MobileNet — edge::paper_models), since the speedup mechanism is
//! weight-streaming relief, which only engages at deployment scale;
//! our 20k-param training testbed models fit edge caches even dense
//! (the model correctly predicts ~1.0x for them, see edge tests).

use anyhow::Result;
use std::path::Path;

use crate::edge::paper_models::{mobilenet, resnet20};
use crate::edge::{inference_latency, speedup, Precision, WeightFormat, EDGE_DEVICES};
use crate::util::csv;

#[derive(Clone, Debug)]
pub struct Table2Row {
    pub model: String,
    pub device: &'static str,
    pub f32_speedup: f64,
    pub u8_speedup: f64,
    pub dense_f32_us: f64,
    pub clustered_f32_us: f64,
}

/// `c` is the cluster count of the deployed model (the controller's
/// final value in a real run; Table 1 runs land at 16-32).
pub fn run(model: &str, c: usize) -> Result<Vec<Table2Row>> {
    let spec = match model {
        "resnet20" => resnet20(),
        "mobilenet" => mobilenet(),
        other => anyhow::bail!("unknown table2 model '{other}'"),
    };
    Ok(EDGE_DEVICES
        .iter()
        .map(|d| Table2Row {
            model: spec.name.clone(),
            device: d.name,
            f32_speedup: speedup(&spec, d, Precision::F32, c),
            u8_speedup: speedup(&spec, d, Precision::U8, c),
            dense_f32_us: inference_latency(&spec, d, Precision::F32, WeightFormat::Dense),
            clustered_f32_us: inference_latency(
                &spec,
                d,
                Precision::F32,
                WeightFormat::Clustered { c },
            ),
        })
        .collect())
}

/// CSV dump through the shared `util::csv` writer (same column
/// vocabulary as `print_rows`).
pub fn write_csv(rows: &[Table2Row], path: &Path) -> Result<()> {
    let header = [
        "model",
        "device",
        "f32_speedup",
        "u8_speedup",
        "dense_us",
        "clustered_us",
    ];
    let out: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.device.to_string(),
                format!("{:.4}", r.f32_speedup),
                format!("{:.4}", r.u8_speedup),
                format!("{:.2}", r.dense_f32_us),
                format!("{:.2}", r.clustered_f32_us),
            ]
        })
        .collect();
    csv::write_file(path, &header, &out)
}

pub fn print_rows(rows: &[Table2Row]) {
    println!(
        "{:<12} {:<12} {:>10} {:>16} {:>12} {:>14}",
        "Model", "Device", "float32", "uint8(quant)", "dense(us)", "clustered(us)"
    );
    for r in rows {
        println!(
            "{:<12} {:<12} {:>9.3}x {:>15.3}x {:>12.1} {:>14.1}",
            r.model, r.device, r.f32_speedup, r.u8_speedup, r.dense_f32_us, r.clustered_f32_us
        );
    }
}
