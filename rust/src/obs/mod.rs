//! Live observability: versioned event streams, non-blocking sinks,
//! and the terminal views behind `runs tail` and `sweep --watch`.
//!
//! ```text
//!  run loop (coordinator/server.rs)          sweep (sweep/mod.rs)
//!    |  canonical Event + ops StreamEvent      |  SweepEvent
//!    v                                         v
//!  EventSink::emit  -- non-blocking: bounded channel + drop counter
//!    |
//!    v
//!  <store>/events/<run_key>.jsonl   "EVNT1 {schema,run,fingerprint,..}"
//!    |                                          header line, then one
//!    v                                          JSON event per line
//!  parse_stream (tolerant: per-line errors, never aborts)
//!    |
//!    v
//!  RunView / SweepView  -> util::table  -> terminal
//! ```
//!
//! Two event classes cross a stream:
//!
//! * **canonical events** ([`crate::coordinator::events::Event`]) — the
//!   run's experimental record. Deterministic and transport-invariant:
//!   the TCP loopback suite asserts their JSONL is bit-identical to the
//!   in-process run, and `runs diff` compares them byte for byte. They
//!   are stored in the [`crate::store::RunRecord`], which is why a
//!   stored record can replay the same view offline.
//! * **ops events** (the other [`stream::StreamEvent`] variants) — what
//!   actually happened on *this* execution: per-slot resolution order,
//!   reorder-window depth (`peak_parked`), worker evictions, sweep
//!   progress. They exist only in the teed stream file and never enter
//!   the record, so observability cannot perturb the determinism
//!   contract.
//!
//! Sequencing is positional (`seq` counters), never wall-clock — the
//! whole module is inside fedlint's `no-wallclock-state` scope, and its
//! parsers are inside `no-panic-decode` (stream files face truncation
//! and bit rot, not trusted input).

pub mod sink;
pub mod stream;
pub mod view;

pub use sink::{BoundedSink, EventSink, FileSink, NullSink, NULL_SINK};
pub use stream::{parse_stream, StreamEvent, StreamHeader, StreamReplay, SCHEMA_VERSION};
pub use view::{RunView, SweepView};
