//! Terminal views over event streams: the per-round run table behind
//! `runs tail` (live and offline replay render through the *same* code
//! path, so they are byte-identical by construction) and the per-job
//! sweep table behind `sweep --watch`.

use std::collections::BTreeMap;

use crate::coordinator::events::Event;
use crate::net::proto::{framed_down, framed_up};
use crate::obs::stream::{StreamEvent, StreamHeader, StreamReplay};
use crate::store::key_hex;
use crate::sweep::SweepEvent;
use crate::util::table::{self, Align};

fn fmt_opt_f64(v: Option<f64>, decimals: usize) -> String {
    match v {
        Some(x) => format!("{x:.decimals$}"),
        None => "-".to_string(),
    }
}

fn fmt_opt_usize(v: Option<usize>) -> String {
    match v {
        Some(x) => x.to_string(),
        None => "-".to_string(),
    }
}

#[derive(Clone, Debug, Default)]
struct RoundRow {
    clusters: Option<usize>,
    accuracy: Option<f64>,
    loss: Option<f64>,
    /// clients that reached aggregation (from `aggregated`)
    survivors: Option<usize>,
    uploads: usize,
    drops: usize,
    deadline_cuts: usize,
    stragglers: Option<usize>,
    peak_parked: Option<usize>,
    sim_ms: Option<f64>,
    up_bytes: usize,
    down_bytes: usize,
    framed_bytes: usize,
}

/// Per-round view of one run's event stream. Fold events in with
/// [`RunView::from_replay`], render with [`RunView::render`].
#[derive(Clone, Debug, Default)]
pub struct RunView {
    header: Option<StreamHeader>,
    rows: BTreeMap<usize, RoundRow>,
    events: usize,
    parse_errors: usize,
    evictions: usize,
}

impl RunView {
    pub fn from_replay(replay: &StreamReplay) -> RunView {
        let mut view = RunView {
            header: replay.header.clone(),
            events: replay.events.len(),
            parse_errors: replay.errors.len(),
            ..RunView::default()
        };
        for ev in &replay.events {
            view.apply(ev);
        }
        view
    }

    fn apply(&mut self, ev: &StreamEvent) {
        match ev {
            StreamEvent::Run(e) => self.apply_run(e),
            StreamEvent::RoundOps {
                round,
                stragglers,
                peak_parked,
                sim_ms,
            } => {
                let row = self.rows.entry(*round).or_default();
                row.stragglers = Some(*stragglers);
                row.peak_parked = Some(*peak_parked);
                row.sim_ms = Some(*sim_ms);
            }
            StreamEvent::Evicted { .. } => self.evictions += 1,
            // per-slot arrival order is forensic detail (grep the
            // stream file); sweep events belong to the SweepView
            StreamEvent::Slot { .. }
            | StreamEvent::SweepPlanned { .. }
            | StreamEvent::SweepJobStart { .. }
            | StreamEvent::SweepJobDone { .. }
            | StreamEvent::SweepJobFailed { .. } => {}
        }
    }

    fn apply_run(&mut self, e: &Event) {
        let row = self.rows.entry(e.round()).or_default();
        match e {
            Event::RoundStart { clusters, .. } => row.clusters = Some(*clusters),
            Event::Dispatch { bytes, .. } => {
                row.down_bytes += bytes;
                row.framed_bytes += framed_down(*bytes);
            }
            Event::Upload { bytes, .. } => {
                row.uploads += 1;
                row.up_bytes += bytes;
                row.framed_bytes += framed_up(*bytes);
            }
            Event::Aggregated { clients, .. } => row.survivors = Some(*clients),
            Event::Evaluated { accuracy, loss, .. } => {
                row.accuracy = Some(*accuracy);
                row.loss = Some(*loss);
            }
            Event::Dropout { .. } => row.drops += 1,
            Event::Deadline { .. } => row.deadline_cuts += 1,
            Event::SelfCompress { .. }
            | Event::ControllerGrow { .. }
            | Event::ResumeMismatch { .. } => {}
        }
    }

    pub fn final_round(&self) -> Option<usize> {
        self.rows.keys().next_back().copied()
    }

    /// Render the full view: identity line (when the stream carried a
    /// header), the per-round table, and a summary line. The summary
    /// always names the final round — scripts (and CI) grep for it.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(h) = &self.header {
            out.push_str(&format!(
                "stream: run={} strategy={} schema={} fingerprint={}\n",
                key_hex(h.run),
                h.strategy,
                h.schema,
                key_hex(h.fingerprint)
            ));
        }
        let header = [
            "round", "acc", "loss", "C", "ok", "drop", "cut", "strag", "park", "up_B", "down_B",
            "framed_B", "sim_s",
        ];
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|(round, r)| {
                vec![
                    round.to_string(),
                    fmt_opt_f64(r.accuracy, 4),
                    fmt_opt_f64(r.loss, 4),
                    fmt_opt_usize(r.clusters),
                    fmt_opt_usize(r.survivors.or((r.uploads > 0).then_some(r.uploads))),
                    r.drops.to_string(),
                    r.deadline_cuts.to_string(),
                    fmt_opt_usize(r.stragglers),
                    fmt_opt_usize(r.peak_parked),
                    r.up_bytes.to_string(),
                    r.down_bytes.to_string(),
                    r.framed_bytes.to_string(),
                    fmt_opt_f64(r.sim_ms.map(|ms| ms / 1e3), 1),
                ]
            })
            .collect();
        out.push_str(&table::render(&header, &rows, &[]));
        match self.final_round() {
            Some(last) => out.push_str(&format!(
                "stream: {} event(s), {} parse error(s) — final round {last}",
                self.events, self.parse_errors
            )),
            None => out.push_str(&format!(
                "stream: {} event(s), {} parse error(s) — no rounds",
                self.events, self.parse_errors
            )),
        }
        if self.evictions > 0 {
            out.push_str(&format!(" — {} eviction(s)", self.evictions));
        }
        out.push('\n');
        out
    }
}

#[derive(Clone, Debug, Default)]
struct JobRow {
    label: String,
    status: String,
    accuracy: Option<f64>,
    wall_s: Option<f64>,
    key: Option<u64>,
    note: String,
}

/// Per-job view of a sweep's progress events — the `sweep --watch`
/// table. Feed it [`StreamEvent`]s (sweep variants; everything else is
/// ignored) and re-render on change.
#[derive(Clone, Debug, Default)]
pub struct SweepView {
    total: usize,
    planned_cached: usize,
    rows: BTreeMap<usize, JobRow>,
}

impl SweepView {
    pub fn new() -> SweepView {
        SweepView::default()
    }

    pub fn apply(&mut self, ev: &StreamEvent) {
        match ev {
            StreamEvent::SweepPlanned { total, cached } => {
                self.total = *total;
                self.planned_cached = *cached;
            }
            StreamEvent::SweepJobStart { idx, label } => {
                let row = self.rows.entry(*idx).or_default();
                row.label = label.clone();
                row.status = "run".to_string();
            }
            StreamEvent::SweepJobDone {
                idx,
                key,
                label,
                cached,
                final_accuracy,
                wall_s,
            } => {
                let row = self.rows.entry(*idx).or_default();
                row.label = label.clone();
                row.status = if *cached { "cached" } else { "done" }.to_string();
                row.accuracy = Some(*final_accuracy);
                row.wall_s = Some(*wall_s);
                row.key = Some(*key);
            }
            StreamEvent::SweepJobFailed { idx, label, error } => {
                let row = self.rows.entry(*idx).or_default();
                row.label = label.clone();
                row.status = "FAILED".to_string();
                row.note = error.clone();
            }
            _ => {}
        }
    }

    pub fn render(&self) -> String {
        let done = self
            .rows
            .values()
            .filter(|r| r.status == "done" || r.status == "cached")
            .count();
        let running = self.rows.values().filter(|r| r.status == "run").count();
        let failed = self.rows.values().filter(|r| r.status == "FAILED").count();
        let mut out = format!(
            "sweep: {done}/{} done ({} cached at plan) — {running} running, {failed} failed\n",
            self.total, self.planned_cached
        );
        let header = ["job", "status", "label", "acc", "wall_s", "key", "note"];
        let aligns = [
            Align::Right,
            Align::Left,
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Left,
            Align::Left,
        ];
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|(idx, r)| {
                vec![
                    (idx + 1).to_string(),
                    r.status.clone(),
                    r.label.clone(),
                    fmt_opt_f64(r.accuracy, 4),
                    fmt_opt_f64(r.wall_s, 1),
                    r.key.map(key_hex).unwrap_or_else(|| "-".to_string()),
                    r.note.clone(),
                ]
            })
            .collect();
        out.push_str(&table::render(&header, &rows, &aligns));
        out
    }
}

/// The plain (non-`--watch`) sweep progress line for one event — the
/// historical stdout format, shared here so batch output and the watch
/// table come from one module.
pub fn sweep_progress_line(e: &SweepEvent, total: usize, workers: usize) -> String {
    match e {
        SweepEvent::Planned { total, cached } => format!(
            "sweep: {total} job(s), {cached} already in the store, {workers} worker(s)"
        ),
        SweepEvent::JobStart { idx, label } => {
            format!("[{:>3}/{total}] run    {label}", idx + 1)
        }
        SweepEvent::JobDone {
            idx,
            key,
            label,
            cached,
            final_accuracy,
            wall_s,
        } => {
            if *cached {
                format!(
                    "[{:>3}/{total}] cached {label} acc={final_accuracy:.4} key={}",
                    idx + 1,
                    key_hex(*key)
                )
            } else {
                format!(
                    "[{:>3}/{total}] done   {label} acc={final_accuracy:.4} \
                     ({wall_s:.1}s) key={}",
                    idx + 1,
                    key_hex(*key)
                )
            }
        }
        SweepEvent::JobFailed { idx, label, error } => {
            format!("[{:>3}/{total}] FAILED {label}: {error}", idx + 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::events::DropPhase;
    use crate::obs::stream::SCHEMA_VERSION;

    fn demo_replay() -> StreamReplay {
        let header = StreamHeader {
            schema: SCHEMA_VERSION,
            run: 0xaa,
            fingerprint: 0xbb,
            strategy: "fedcompress".to_string(),
        };
        let events = vec![
            StreamEvent::Run(Event::RoundStart {
                round: 0,
                clusters: 16,
            }),
            StreamEvent::Run(Event::Dispatch {
                round: 0,
                client: 0,
                bytes: 1000,
                compressed: true,
            }),
            StreamEvent::Run(Event::Upload {
                round: 0,
                client: 0,
                bytes: 200,
                score: 4.5,
                mean_ce: 2.1,
            }),
            StreamEvent::Run(Event::Dropout {
                round: 0,
                client: 1,
                phase: DropPhase::BeforeTrain,
            }),
            StreamEvent::Run(Event::Deadline {
                round: 0,
                client: 2,
                sim_s: 31.0,
            }),
            StreamEvent::Run(Event::Aggregated {
                round: 0,
                clients: 1,
                score: 4.5,
            }),
            StreamEvent::Run(Event::Evaluated {
                round: 0,
                accuracy: 0.5,
                loss: 1.5,
            }),
            StreamEvent::RoundOps {
                round: 0,
                stragglers: 1,
                peak_parked: 3,
                sim_ms: 1500.0,
            },
        ];
        StreamReplay {
            header: Some(header),
            events,
            errors: Vec::new(),
        }
    }

    #[test]
    fn run_view_folds_rounds_and_names_the_final_round() {
        let view = RunView::from_replay(&demo_replay());
        assert_eq!(view.final_round(), Some(0));
        let text = view.render();
        assert!(text.contains("run=00000000000000aa"), "{text}");
        assert!(text.contains("final round 0"), "{text}");
        assert!(text.contains("0 parse error(s)"), "{text}");
        // framed bytes = ideal + per-message overheads, so strictly more
        assert!(text.contains("0.5000"), "{text}");
    }

    #[test]
    fn framed_bytes_exceed_ideal_bytes() {
        let view = RunView::from_replay(&demo_replay());
        let text = view.render();
        // down 1000 + up 200 ideal; framed adds both overheads
        let framed = framed_down(1000) + framed_up(200);
        assert!(text.contains(&framed.to_string()), "{text}");
    }

    #[test]
    fn sweep_view_tracks_job_lifecycle() {
        let mut view = SweepView::new();
        view.apply(&StreamEvent::SweepPlanned { total: 2, cached: 0 });
        view.apply(&StreamEvent::SweepJobStart {
            idx: 0,
            label: "a".to_string(),
        });
        view.apply(&StreamEvent::SweepJobDone {
            idx: 0,
            key: 7,
            label: "a".to_string(),
            cached: false,
            final_accuracy: 0.5,
            wall_s: 1.25,
        });
        view.apply(&StreamEvent::SweepJobFailed {
            idx: 1,
            label: "b".to_string(),
            error: "boom".to_string(),
        });
        let text = view.render();
        assert!(text.contains("1/2 done"), "{text}");
        assert!(text.contains("1 failed"), "{text}");
        assert!(text.contains("boom"), "{text}");
        assert!(text.contains(&key_hex(7)), "{text}");
    }

    #[test]
    fn progress_lines_match_the_historical_format() {
        let line = sweep_progress_line(
            &SweepEvent::JobStart {
                idx: 0,
                label: "fedavg/s1".to_string(),
            },
            4,
            2,
        );
        assert_eq!(line, "[  1/4] run    fedavg/s1");
        let line = sweep_progress_line(&SweepEvent::Planned { total: 4, cached: 1 }, 4, 2);
        assert_eq!(line, "sweep: 4 job(s), 1 already in the store, 2 worker(s)");
    }
}
