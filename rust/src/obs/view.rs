//! Terminal views over event streams: the per-round run table behind
//! `runs tail` (live and offline replay render through the *same* code
//! path, so they are byte-identical by construction) and the per-job
//! sweep table behind `sweep --watch`.

use std::collections::BTreeMap;

use crate::coordinator::events::Event;
use crate::net::proto::{framed_down, framed_up};
use crate::obs::stream::{StreamEvent, StreamHeader, StreamReplay};
use crate::store::key_hex;
use crate::sweep::SweepEvent;
use crate::util::table::{self, Align};

fn fmt_opt_f64(v: Option<f64>, decimals: usize) -> String {
    match v {
        Some(x) => format!("{x:.decimals$}"),
        None => "-".to_string(),
    }
}

fn fmt_opt_usize(v: Option<usize>) -> String {
    match v {
        Some(x) => x.to_string(),
        None => "-".to_string(),
    }
}

#[derive(Clone, Debug, Default)]
struct RoundRow {
    clusters: Option<usize>,
    accuracy: Option<f64>,
    loss: Option<f64>,
    /// clients that reached aggregation (from `aggregated`)
    survivors: Option<usize>,
    uploads: usize,
    drops: usize,
    deadline_cuts: usize,
    stragglers: Option<usize>,
    peak_parked: Option<usize>,
    sim_ms: Option<f64>,
    up_bytes: usize,
    down_bytes: usize,
    framed_bytes: usize,
    /// live-only per-phase wall ns (`phase_timing` ops events); absent
    /// on replayed record streams, so the timing column group only
    /// renders for teed live runs
    phase_ns: Option<Vec<(String, u64)>>,
}

/// Canonical phase column order for the timing group: (column header,
/// phase key as emitted by the coordinator round loop).
const PHASE_COLUMNS: [(&str, &str); 7] = [
    ("sel_ms", "select"),
    ("dn_ms", "encode_down"),
    ("tr_ms", "train"),
    ("up_ms", "encode_up"),
    ("ing_ms", "ingest"),
    ("agg_ms", "aggregate"),
    ("ev_ms", "evaluate"),
];

fn fmt_phase_ms(row: &RoundRow, phase: &str) -> String {
    match &row.phase_ns {
        Some(ns) => ns
            .iter()
            .find(|(p, _)| p == phase)
            .map(|&(_, v)| format!("{:.2}", v as f64 / 1e6))
            .unwrap_or_else(|| "-".to_string()),
        None => "-".to_string(),
    }
}

/// Per-round view of one run's event stream. Fold events in with
/// [`RunView::from_replay`], render with [`RunView::render`].
#[derive(Clone, Debug, Default)]
pub struct RunView {
    header: Option<StreamHeader>,
    rows: BTreeMap<usize, RoundRow>,
    events: usize,
    parse_errors: usize,
    evictions: usize,
}

impl RunView {
    pub fn from_replay(replay: &StreamReplay) -> RunView {
        let mut view = RunView {
            header: replay.header.clone(),
            events: replay.events.len(),
            parse_errors: replay.errors.len(),
            ..RunView::default()
        };
        for ev in &replay.events {
            view.apply(ev);
        }
        view
    }

    fn apply(&mut self, ev: &StreamEvent) {
        match ev {
            StreamEvent::Run(e) => self.apply_run(e),
            StreamEvent::RoundOps {
                round,
                stragglers,
                peak_parked,
                sim_ms,
            } => {
                let row = self.rows.entry(*round).or_default();
                row.stragglers = Some(*stragglers);
                row.peak_parked = Some(*peak_parked);
                row.sim_ms = Some(*sim_ms);
            }
            StreamEvent::PhaseTiming { round, ns } => {
                self.rows.entry(*round).or_default().phase_ns = Some(ns.clone());
            }
            StreamEvent::Evicted { .. } => self.evictions += 1,
            // per-slot arrival order is forensic detail (grep the
            // stream file); sweep events belong to the SweepView
            StreamEvent::Slot { .. }
            | StreamEvent::SweepPlanned { .. }
            | StreamEvent::SweepJobStart { .. }
            | StreamEvent::SweepJobDone { .. }
            | StreamEvent::SweepJobFailed { .. } => {}
        }
    }

    fn apply_run(&mut self, e: &Event) {
        let row = self.rows.entry(e.round()).or_default();
        match e {
            Event::RoundStart { clusters, .. } => row.clusters = Some(*clusters),
            Event::Dispatch { bytes, .. } => {
                row.down_bytes += bytes;
                row.framed_bytes += framed_down(*bytes);
            }
            Event::Upload { bytes, .. } => {
                row.uploads += 1;
                row.up_bytes += bytes;
                row.framed_bytes += framed_up(*bytes);
            }
            Event::Aggregated { clients, .. } => row.survivors = Some(*clients),
            Event::Evaluated { accuracy, loss, .. } => {
                row.accuracy = Some(*accuracy);
                row.loss = Some(*loss);
            }
            Event::Dropout { .. } => row.drops += 1,
            Event::Deadline { .. } => row.deadline_cuts += 1,
            Event::SelfCompress { .. }
            | Event::ControllerGrow { .. }
            | Event::ResumeMismatch { .. } => {}
        }
    }

    pub fn final_round(&self) -> Option<usize> {
        self.rows.keys().next_back().copied()
    }

    /// Render the full view: identity line (when the stream carried a
    /// header), the per-round table, and a summary line. The summary
    /// always names the final round — scripts (and CI) grep for it.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(h) = &self.header {
            out.push_str(&format!(
                "stream: run={} strategy={} schema={} fingerprint={}\n",
                key_hex(h.run),
                h.strategy,
                h.schema,
                key_hex(h.fingerprint)
            ));
        }
        // the timing column group renders only when the stream carried
        // `phase_timing` ops events (live tees); replayed record
        // streams never have them, so replay output stays byte-stable
        let timed = self.rows.values().any(|r| r.phase_ns.is_some());
        let mut header = vec![
            "round", "acc", "loss", "C", "ok", "drop", "cut", "strag", "park", "up_B", "down_B",
            "framed_B", "sim_s",
        ];
        if timed {
            header.extend(PHASE_COLUMNS.iter().map(|&(col, _)| col));
        }
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|(round, r)| {
                let mut cells = vec![
                    round.to_string(),
                    fmt_opt_f64(r.accuracy, 4),
                    fmt_opt_f64(r.loss, 4),
                    fmt_opt_usize(r.clusters),
                    fmt_opt_usize(r.survivors.or((r.uploads > 0).then_some(r.uploads))),
                    r.drops.to_string(),
                    r.deadline_cuts.to_string(),
                    fmt_opt_usize(r.stragglers),
                    fmt_opt_usize(r.peak_parked),
                    r.up_bytes.to_string(),
                    r.down_bytes.to_string(),
                    r.framed_bytes.to_string(),
                    fmt_opt_f64(r.sim_ms.map(|ms| ms / 1e3), 1),
                ];
                if timed {
                    cells.extend(PHASE_COLUMNS.iter().map(|&(_, phase)| fmt_phase_ms(r, phase)));
                }
                cells
            })
            .collect();
        out.push_str(&table::render(&header, &rows, &[]));
        match self.final_round() {
            Some(last) => out.push_str(&format!(
                "stream: {} event(s), {} parse error(s) — final round {last}",
                self.events, self.parse_errors
            )),
            None => out.push_str(&format!(
                "stream: {} event(s), {} parse error(s) — no rounds",
                self.events, self.parse_errors
            )),
        }
        if self.evictions > 0 {
            out.push_str(&format!(" — {} eviction(s)", self.evictions));
        }
        out.push('\n');
        out
    }
}

#[derive(Clone, Debug, Default)]
struct JobRow {
    label: String,
    status: String,
    accuracy: Option<f64>,
    wall_s: Option<f64>,
    key: Option<u64>,
    note: String,
}

/// Per-job view of a sweep's progress events — the `sweep --watch`
/// table. Feed it [`StreamEvent`]s (sweep variants; everything else is
/// ignored) and re-render on change.
#[derive(Clone, Debug, Default)]
pub struct SweepView {
    total: usize,
    planned_cached: usize,
    rows: BTreeMap<usize, JobRow>,
    /// summed live-only `phase_timing` ns across every profiled round
    /// of every job (cached jobs replay record streams and carry none)
    phase_ns: BTreeMap<String, u64>,
    profiled_rounds: usize,
}

impl SweepView {
    pub fn new() -> SweepView {
        SweepView::default()
    }

    pub fn apply(&mut self, ev: &StreamEvent) {
        match ev {
            StreamEvent::SweepPlanned { total, cached } => {
                self.total = *total;
                self.planned_cached = *cached;
            }
            StreamEvent::SweepJobStart { idx, label } => {
                let row = self.rows.entry(*idx).or_default();
                row.label = label.clone();
                row.status = "run".to_string();
            }
            StreamEvent::SweepJobDone {
                idx,
                key,
                label,
                cached,
                final_accuracy,
                wall_s,
            } => {
                let row = self.rows.entry(*idx).or_default();
                row.label = label.clone();
                row.status = if *cached { "cached" } else { "done" }.to_string();
                row.accuracy = Some(*final_accuracy);
                row.wall_s = Some(*wall_s);
                row.key = Some(*key);
            }
            StreamEvent::SweepJobFailed { idx, label, error } => {
                let row = self.rows.entry(*idx).or_default();
                row.label = label.clone();
                row.status = "FAILED".to_string();
                row.note = error.clone();
            }
            StreamEvent::PhaseTiming { ns, .. } => {
                for (phase, v) in ns {
                    let slot = self.phase_ns.entry(phase.clone()).or_insert(0);
                    *slot = slot.saturating_add(*v);
                }
                self.profiled_rounds += 1;
            }
            _ => {}
        }
    }

    pub fn render(&self) -> String {
        let done = self
            .rows
            .values()
            .filter(|r| r.status == "done" || r.status == "cached")
            .count();
        let running = self.rows.values().filter(|r| r.status == "run").count();
        let failed = self.rows.values().filter(|r| r.status == "FAILED").count();
        let mut out = format!(
            "sweep: {done}/{} done ({} cached at plan) — {running} running, {failed} failed\n",
            self.total, self.planned_cached
        );
        let header = ["job", "status", "label", "acc", "wall_s", "key", "note"];
        let aligns = [
            Align::Right,
            Align::Left,
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Left,
            Align::Left,
        ];
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|(idx, r)| {
                vec![
                    (idx + 1).to_string(),
                    r.status.clone(),
                    r.label.clone(),
                    fmt_opt_f64(r.accuracy, 4),
                    fmt_opt_f64(r.wall_s, 1),
                    r.key.map(key_hex).unwrap_or_else(|| "-".to_string()),
                    r.note.clone(),
                ]
            })
            .collect();
        out.push_str(&table::render(&header, &rows, &aligns));
        // mean per-round phase profile (live runs only — cached jobs
        // replay record streams, which carry no phase_timing events)
        if self.profiled_rounds > 0 {
            let parts: Vec<String> = self
                .phase_ns
                .iter()
                .map(|(phase, ns)| {
                    format!("{phase}={:.2}ms", *ns as f64 / self.profiled_rounds as f64 / 1e6)
                })
                .collect();
            out.push_str(&format!(
                "phase profile (mean over {} live round(s)): {}\n",
                self.profiled_rounds,
                parts.join(" ")
            ));
        }
        out
    }
}

/// The plain (non-`--watch`) sweep progress line for one event — the
/// historical stdout format, shared here so batch output and the watch
/// table come from one module.
pub fn sweep_progress_line(e: &SweepEvent, total: usize, workers: usize) -> String {
    match e {
        SweepEvent::Planned { total, cached } => format!(
            "sweep: {total} job(s), {cached} already in the store, {workers} worker(s)"
        ),
        SweepEvent::JobStart { idx, label } => {
            format!("[{:>3}/{total}] run    {label}", idx + 1)
        }
        SweepEvent::JobDone {
            idx,
            key,
            label,
            cached,
            final_accuracy,
            wall_s,
        } => {
            if *cached {
                format!(
                    "[{:>3}/{total}] cached {label} acc={final_accuracy:.4} key={}",
                    idx + 1,
                    key_hex(*key)
                )
            } else {
                format!(
                    "[{:>3}/{total}] done   {label} acc={final_accuracy:.4} \
                     ({wall_s:.1}s) key={}",
                    idx + 1,
                    key_hex(*key)
                )
            }
        }
        SweepEvent::JobFailed { idx, label, error } => {
            format!("[{:>3}/{total}] FAILED {label}: {error}", idx + 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::events::DropPhase;
    use crate::obs::stream::SCHEMA_VERSION;

    fn demo_replay() -> StreamReplay {
        let header = StreamHeader {
            schema: SCHEMA_VERSION,
            run: 0xaa,
            fingerprint: 0xbb,
            strategy: "fedcompress".to_string(),
        };
        let events = vec![
            StreamEvent::Run(Event::RoundStart {
                round: 0,
                clusters: 16,
            }),
            StreamEvent::Run(Event::Dispatch {
                round: 0,
                client: 0,
                bytes: 1000,
                compressed: true,
            }),
            StreamEvent::Run(Event::Upload {
                round: 0,
                client: 0,
                bytes: 200,
                score: 4.5,
                mean_ce: 2.1,
            }),
            StreamEvent::Run(Event::Dropout {
                round: 0,
                client: 1,
                phase: DropPhase::BeforeTrain,
            }),
            StreamEvent::Run(Event::Deadline {
                round: 0,
                client: 2,
                sim_s: 31.0,
            }),
            StreamEvent::Run(Event::Aggregated {
                round: 0,
                clients: 1,
                score: 4.5,
            }),
            StreamEvent::Run(Event::Evaluated {
                round: 0,
                accuracy: 0.5,
                loss: 1.5,
            }),
            StreamEvent::RoundOps {
                round: 0,
                stragglers: 1,
                peak_parked: 3,
                sim_ms: 1500.0,
            },
        ];
        StreamReplay {
            header: Some(header),
            events,
            errors: Vec::new(),
        }
    }

    #[test]
    fn run_view_folds_rounds_and_names_the_final_round() {
        let view = RunView::from_replay(&demo_replay());
        assert_eq!(view.final_round(), Some(0));
        let text = view.render();
        assert!(text.contains("run=00000000000000aa"), "{text}");
        assert!(text.contains("final round 0"), "{text}");
        assert!(text.contains("0 parse error(s)"), "{text}");
        // framed bytes = ideal + per-message overheads, so strictly more
        assert!(text.contains("0.5000"), "{text}");
    }

    #[test]
    fn framed_bytes_exceed_ideal_bytes() {
        let view = RunView::from_replay(&demo_replay());
        let text = view.render();
        // down 1000 + up 200 ideal; framed adds both overheads
        let framed = framed_down(1000) + framed_up(200);
        assert!(text.contains(&framed.to_string()), "{text}");
    }

    #[test]
    fn timing_columns_render_only_when_phase_events_are_present() {
        let plain = RunView::from_replay(&demo_replay()).render();
        assert!(!plain.contains("tr_ms"), "{plain}");

        let mut replay = demo_replay();
        replay.events.push(StreamEvent::PhaseTiming {
            round: 0,
            ns: vec![
                ("aggregate".to_string(), 2_500_000),
                ("train".to_string(), 750_000_000),
            ],
        });
        replay.events.push(StreamEvent::Run(Event::RoundStart {
            round: 1,
            clusters: 16,
        }));
        let timed = RunView::from_replay(&replay).render();
        assert!(timed.contains("tr_ms"), "{timed}");
        assert!(timed.contains("750.00"), "{timed}");
        assert!(timed.contains("2.50"), "{timed}");
        // round 1 has no phase event: its timing cells are dashes
        assert!(timed.contains("sel_ms"), "{timed}");
        // the footer greps CI relies on survive the extra columns
        assert!(timed.contains("final round 1"), "{timed}");
    }

    #[test]
    fn sweep_view_tracks_job_lifecycle() {
        let mut view = SweepView::new();
        view.apply(&StreamEvent::SweepPlanned { total: 2, cached: 0 });
        view.apply(&StreamEvent::SweepJobStart {
            idx: 0,
            label: "a".to_string(),
        });
        view.apply(&StreamEvent::SweepJobDone {
            idx: 0,
            key: 7,
            label: "a".to_string(),
            cached: false,
            final_accuracy: 0.5,
            wall_s: 1.25,
        });
        view.apply(&StreamEvent::SweepJobFailed {
            idx: 1,
            label: "b".to_string(),
            error: "boom".to_string(),
        });
        let text = view.render();
        assert!(text.contains("1/2 done"), "{text}");
        assert!(text.contains("1 failed"), "{text}");
        assert!(text.contains("boom"), "{text}");
        assert!(text.contains(&key_hex(7)), "{text}");
        assert!(!text.contains("phase profile"), "{text}");

        view.apply(&StreamEvent::PhaseTiming {
            round: 0,
            ns: vec![("train".to_string(), 4_000_000)],
        });
        view.apply(&StreamEvent::PhaseTiming {
            round: 1,
            ns: vec![("train".to_string(), 2_000_000)],
        });
        let text = view.render();
        assert!(
            text.contains("phase profile (mean over 2 live round(s)): train=3.00ms"),
            "{text}"
        );
    }

    #[test]
    fn progress_lines_match_the_historical_format() {
        let line = sweep_progress_line(
            &SweepEvent::JobStart {
                idx: 0,
                label: "fedavg/s1".to_string(),
            },
            4,
            2,
        );
        assert_eq!(line, "[  1/4] run    fedavg/s1");
        let line = sweep_progress_line(&SweepEvent::Planned { total: 4, cached: 1 }, 4, 2);
        assert_eq!(line, "sweep: 4 job(s), 1 already in the store, 2 worker(s)");
    }
}
