//! The versioned JSONL event stream: header format, the [`StreamEvent`]
//! union crossing it, the tolerant reader, and record replay.
//!
//! A stream file looks like:
//!
//! ```text
//! EVNT1 {"fingerprint":"<hex16>","run":"<hex16>","schema":1,"strategy":"fedavg"}
//! {"kind":"round_start","round":0,"clusters":16,"seq":0}
//! {"kind":"dispatch","round":0,"client":0,"bytes":4096,"compressed":true,"seq":1}
//! ...
//! ```
//!
//! The magic+version prefix (`EVNT1`) makes the format self-describing;
//! `run` is the store content key, `fingerprint` is FNV-1a64 over the
//! bit-exact config image, so a stream can be matched to its record
//! without parsing a single event. Every event line carries a
//! monotonic `seq` stamped by the sink — gaps mean a bounded sink
//! dropped events. Canonical (`Run`) lines never encode wall-clock
//! time; ops lines may carry monotonic *durations* (`wall_s`,
//! `phase_timing` ns), which is why ops events are live-only and never
//! enter a record.
//!
//! Reading is tolerant end to end: [`parse_stream`] turns every
//! unreadable line into a counted [`EventParseError`] and keeps going,
//! so truncation or bit rot degrades a replay instead of aborting it.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::FedConfig;
use crate::coordinator::events::{Event, EventParseError};
use crate::net::proto::config_image;
use crate::store::RunRecord;
use crate::sweep::SweepEvent;
use crate::util::hash::fnv1a64;
use crate::util::json::Json;

/// Bump when the stream grammar changes incompatibly. Readers accept
/// any schema and report unknown event kinds per line, so old readers
/// degrade gracefully on newer streams.
pub const SCHEMA_VERSION: u32 = 1;

/// Magic prefix of the header line; the `1` is the schema generation.
pub const STREAM_MAGIC: &str = "EVNT1";

fn hex16(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex64(s: &str) -> Result<u64> {
    u64::from_str_radix(s.trim(), 16).map_err(|e| anyhow!("bad hex key '{s}': {e}"))
}

/// First line of every stream file: schema version plus enough identity
/// (run key, config fingerprint, strategy) to match the stream to its
/// store record without reading any events.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamHeader {
    pub schema: u32,
    /// store content key of the run (`store::run_key`)
    pub run: u64,
    /// FNV-1a64 over the bit-exact config image
    pub fingerprint: u64,
    pub strategy: String,
}

impl StreamHeader {
    pub fn new(run: u64, cfg: &FedConfig, strategy: &str) -> StreamHeader {
        StreamHeader {
            schema: SCHEMA_VERSION,
            run,
            fingerprint: fnv1a64(&config_image(cfg)),
            strategy: strategy.to_string(),
        }
    }

    /// Header a stored record's offline replay synthesizes — identical
    /// to what the live tee wrote, because the record carries the same
    /// key, strategy, and config image.
    pub fn for_record(rec: &RunRecord) -> StreamHeader {
        StreamHeader {
            schema: SCHEMA_VERSION,
            run: rec.key,
            fingerprint: fnv1a64(&rec.cfg_image),
            strategy: rec.strategy.clone(),
        }
    }

    pub fn render(&self) -> String {
        let j = Json::obj(vec![
            ("fingerprint", Json::str(&hex16(self.fingerprint))),
            ("run", Json::str(&hex16(self.run))),
            ("schema", Json::from(self.schema as usize)),
            ("strategy", Json::str(&self.strategy)),
        ]);
        format!("{STREAM_MAGIC} {j}")
    }

    pub fn parse(line: &str) -> Result<StreamHeader> {
        let rest = line
            .strip_prefix(STREAM_MAGIC)
            .ok_or_else(|| anyhow!("missing {STREAM_MAGIC} magic"))?;
        let j = Json::parse(rest.trim())?;
        Ok(StreamHeader {
            schema: j.get("schema")?.as_usize()? as u32,
            run: parse_hex64(j.get("run")?.as_str()?)?,
            fingerprint: parse_hex64(j.get("fingerprint")?.as_str()?)?,
            strategy: j.get("strategy")?.as_str()?.to_string(),
        })
    }
}

/// Everything that can cross a stream. Two classes:
///
/// * [`StreamEvent::Run`] wraps a canonical, transport-invariant
///   [`Event`] — the same record the `RunRecord` stores.
/// * Every other variant is an **ops event**: true about this
///   execution only (arrival order, reorder depth, evictions, sweep
///   progress). Ops events never enter the run record, so the
///   determinism contract (TCP == in-process, bit for bit) is
///   untouched by observability.
#[derive(Clone, Debug, PartialEq)]
pub enum StreamEvent {
    /// A canonical run event, verbatim.
    Run(Event),
    /// One intake slot resolved (arrival order, not canonical order).
    Slot {
        round: usize,
        client: usize,
        outcome: String,
    },
    /// Per-round operational counters, emitted once after `evaluated`:
    /// straggler count, peak reorder-window depth in the streaming
    /// accumulator, and the simulated round duration.
    RoundOps {
        round: usize,
        stragglers: usize,
        peak_parked: usize,
        sim_ms: f64,
    },
    /// Live-only per-phase wall durations for one round, in
    /// nanoseconds, sorted by phase name (`util::timer` is the only
    /// clock behind them). Emitted right after `round_ops`; never
    /// synthesized on replay — the record keeps no wall time — so a
    /// cached tee legitimately has none of these lines.
    PhaseTiming {
        round: usize,
        ns: Vec<(String, u64)>,
    },
    /// A worker connection was evicted mid-round and why.
    Evicted {
        round: usize,
        conn: usize,
        cause: String,
        dropped_clients: usize,
    },
    SweepPlanned {
        total: usize,
        cached: usize,
    },
    SweepJobStart {
        idx: usize,
        label: String,
    },
    SweepJobDone {
        idx: usize,
        key: u64,
        label: String,
        cached: bool,
        final_accuracy: f64,
        wall_s: f64,
    },
    SweepJobFailed {
        idx: usize,
        label: String,
        error: String,
    },
}

impl StreamEvent {
    pub fn kind(&self) -> &'static str {
        match self {
            StreamEvent::Run(e) => e.kind(),
            StreamEvent::Slot { .. } => "slot",
            StreamEvent::RoundOps { .. } => "round_ops",
            StreamEvent::PhaseTiming { .. } => "phase_timing",
            StreamEvent::Evicted { .. } => "evicted",
            StreamEvent::SweepPlanned { .. } => "sweep_planned",
            StreamEvent::SweepJobStart { .. } => "sweep_job_start",
            StreamEvent::SweepJobDone { .. } => "sweep_job_done",
            StreamEvent::SweepJobFailed { .. } => "sweep_job_failed",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            StreamEvent::Run(e) => e.to_json(),
            StreamEvent::Slot {
                round,
                client,
                outcome,
            } => Json::obj(vec![
                ("kind", Json::str("slot")),
                ("round", Json::from(*round)),
                ("client", Json::from(*client)),
                ("outcome", Json::str(outcome)),
            ]),
            StreamEvent::RoundOps {
                round,
                stragglers,
                peak_parked,
                sim_ms,
            } => Json::obj(vec![
                ("kind", Json::str("round_ops")),
                ("round", Json::from(*round)),
                ("stragglers", Json::from(*stragglers)),
                ("peak_parked", Json::from(*peak_parked)),
                ("sim_ms", Json::num(*sim_ms)),
            ]),
            StreamEvent::PhaseTiming { round, ns } => Json::obj(vec![
                ("kind", Json::str("phase_timing")),
                ("round", Json::from(*round)),
                (
                    "ns",
                    Json::Obj(
                        ns.iter()
                            .map(|(phase, v)| (phase.clone(), Json::from(*v as usize)))
                            .collect(),
                    ),
                ),
            ]),
            StreamEvent::Evicted {
                round,
                conn,
                cause,
                dropped_clients,
            } => Json::obj(vec![
                ("kind", Json::str("evicted")),
                ("round", Json::from(*round)),
                ("conn", Json::from(*conn)),
                ("cause", Json::str(cause)),
                ("dropped_clients", Json::from(*dropped_clients)),
            ]),
            StreamEvent::SweepPlanned { total, cached } => Json::obj(vec![
                ("kind", Json::str("sweep_planned")),
                ("total", Json::from(*total)),
                ("cached", Json::from(*cached)),
            ]),
            StreamEvent::SweepJobStart { idx, label } => Json::obj(vec![
                ("kind", Json::str("sweep_job_start")),
                ("idx", Json::from(*idx)),
                ("label", Json::str(label)),
            ]),
            StreamEvent::SweepJobDone {
                idx,
                key,
                label,
                cached,
                final_accuracy,
                wall_s,
            } => Json::obj(vec![
                ("kind", Json::str("sweep_job_done")),
                ("idx", Json::from(*idx)),
                ("key", Json::str(&hex16(*key))),
                ("label", Json::str(label)),
                ("cached", Json::from(*cached)),
                ("final_accuracy", Json::num(*final_accuracy)),
                ("wall_s", Json::num(*wall_s)),
            ]),
            StreamEvent::SweepJobFailed { idx, label, error } => Json::obj(vec![
                ("kind", Json::str("sweep_job_failed")),
                ("idx", Json::from(*idx)),
                ("label", Json::str(label)),
                ("error", Json::str(error)),
            ]),
        }
    }

    /// One stream-file line: the event's JSON with the sink's monotonic
    /// `seq` stamped in.
    pub fn to_json_line(&self, seq: u64) -> String {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("seq".to_string(), Json::from(seq as usize));
        }
        j.to_string()
    }

    pub fn from_json(j: &Json) -> Result<StreamEvent> {
        let kind = j.get("kind")?.as_str()?;
        Ok(match kind {
            "slot" => StreamEvent::Slot {
                round: j.get("round")?.as_usize()?,
                client: j.get("client")?.as_usize()?,
                outcome: j.get("outcome")?.as_str()?.to_string(),
            },
            "round_ops" => StreamEvent::RoundOps {
                round: j.get("round")?.as_usize()?,
                stragglers: j.get("stragglers")?.as_usize()?,
                peak_parked: j.get("peak_parked")?.as_usize()?,
                sim_ms: j.get("sim_ms")?.as_f64()?,
            },
            "phase_timing" => StreamEvent::PhaseTiming {
                round: j.get("round")?.as_usize()?,
                // object keys are BTreeMap-ordered, so the vec comes
                // back sorted by phase name — the writer's invariant
                ns: j
                    .get("ns")?
                    .as_obj()?
                    .iter()
                    .map(|(phase, v)| Ok((phase.clone(), v.as_usize()? as u64)))
                    .collect::<Result<Vec<_>>>()?,
            },
            "evicted" => StreamEvent::Evicted {
                round: j.get("round")?.as_usize()?,
                conn: j.get("conn")?.as_usize()?,
                cause: j.get("cause")?.as_str()?.to_string(),
                dropped_clients: j.get("dropped_clients")?.as_usize()?,
            },
            "sweep_planned" => StreamEvent::SweepPlanned {
                total: j.get("total")?.as_usize()?,
                cached: j.get("cached")?.as_usize()?,
            },
            "sweep_job_start" => StreamEvent::SweepJobStart {
                idx: j.get("idx")?.as_usize()?,
                label: j.get("label")?.as_str()?.to_string(),
            },
            "sweep_job_done" => StreamEvent::SweepJobDone {
                idx: j.get("idx")?.as_usize()?,
                key: parse_hex64(j.get("key")?.as_str()?)?,
                label: j.get("label")?.as_str()?.to_string(),
                cached: j.get("cached")?.as_bool()?,
                final_accuracy: j.get("final_accuracy")?.as_f64()?,
                wall_s: j.get("wall_s")?.as_f64()?,
            },
            "sweep_job_failed" => StreamEvent::SweepJobFailed {
                idx: j.get("idx")?.as_usize()?,
                label: j.get("label")?.as_str()?.to_string(),
                error: j.get("error")?.as_str()?.to_string(),
            },
            _ => StreamEvent::Run(Event::from_json(j)?),
        })
    }
}

impl From<&SweepEvent> for StreamEvent {
    fn from(e: &SweepEvent) -> StreamEvent {
        match e {
            SweepEvent::Planned { total, cached } => StreamEvent::SweepPlanned {
                total: *total,
                cached: *cached,
            },
            SweepEvent::JobStart { idx, label } => StreamEvent::SweepJobStart {
                idx: *idx,
                label: label.clone(),
            },
            SweepEvent::JobDone {
                idx,
                key,
                label,
                cached,
                final_accuracy,
                wall_s,
            } => StreamEvent::SweepJobDone {
                idx: *idx,
                key: *key,
                label: label.clone(),
                cached: *cached,
                final_accuracy: *final_accuracy,
                wall_s: *wall_s,
            },
            SweepEvent::JobFailed { idx, label, error } => StreamEvent::SweepJobFailed {
                idx: *idx,
                label: label.clone(),
                error: error.clone(),
            },
        }
    }
}

/// Result of the tolerant stream reader: whatever parsed, plus a
/// per-line error report for whatever did not. Never a failure.
#[derive(Clone, Debug, Default)]
pub struct StreamReplay {
    pub header: Option<StreamHeader>,
    pub events: Vec<StreamEvent>,
    pub errors: Vec<EventParseError>,
}

/// Parse a stream file's text. Tolerant by contract: any line that
/// fails to parse — truncated tail, flipped bit, unknown kind from a
/// newer schema — becomes an [`EventParseError`] with its 1-based line
/// number, and parsing continues. This function cannot fail or panic.
pub fn parse_stream(text: &str) -> StreamReplay {
    let mut replay = StreamReplay::default();
    for (idx, line) in text.lines().enumerate() {
        let line_no = idx + 1;
        if line.trim().is_empty() {
            continue;
        }
        if line.starts_with(STREAM_MAGIC) {
            match StreamHeader::parse(line) {
                Ok(h) if replay.header.is_none() => replay.header = Some(h),
                Ok(_) => replay.errors.push(EventParseError {
                    line: line_no,
                    error: "unexpected extra stream header".to_string(),
                }),
                Err(e) => replay.errors.push(EventParseError {
                    line: line_no,
                    error: e.to_string(),
                }),
            }
            continue;
        }
        match Json::parse(line).and_then(|j| StreamEvent::from_json(&j)) {
            Ok(ev) => replay.events.push(ev),
            Err(e) => replay.errors.push(EventParseError {
                line: line_no,
                error: e.to_string(),
            }),
        }
    }
    replay
}

/// Synthesize the stream a live tee would have produced for a stored
/// record: every canonical event in order, plus one `round_ops` line
/// after each round's `evaluated` event, filled from the recorded
/// [`crate::coordinator::metrics::RoundMetrics`] (`peak_parked` is 0 —
/// the record does not keep transport arrival order). Returns the
/// events and any per-line errors from the stored log.
pub fn record_stream_events(rec: &RunRecord) -> (Vec<StreamEvent>, Vec<EventParseError>) {
    let parsed = rec.events();
    let mut metrics: BTreeMap<usize, (usize, f64)> = rec
        .rounds
        .iter()
        .map(|r| (r.round, (r.stragglers, r.round_sim_ms)))
        .collect();
    let mut out = Vec::with_capacity(parsed.log.len() + rec.rounds.len());
    for e in parsed.log.all() {
        let round = e.round();
        let is_eval = matches!(e, Event::Evaluated { .. });
        out.push(StreamEvent::Run(e.clone()));
        if is_eval {
            if let Some((stragglers, sim_ms)) = metrics.remove(&round) {
                out.push(StreamEvent::RoundOps {
                    round,
                    stragglers,
                    peak_parked: 0,
                    sim_ms,
                });
            }
        }
    }
    (out, parsed.errors)
}

/// Render a full stream file (header line + one line per event, `seq`
/// numbered from 0).
pub fn render_stream(header: &StreamHeader, events: &[StreamEvent]) -> String {
    let mut s = header.render();
    s.push('\n');
    for (seq, e) in events.iter().enumerate() {
        s.push_str(&e.to_json_line(seq as u64));
        s.push('\n');
    }
    s
}

/// Write a stored record's synthesized stream to `path` (creating
/// parent directories) — the tee a cached or smoke run gets, and the
/// fallback `runs tail` replays when no live stream file exists.
pub fn write_record_stream(rec: &RunRecord, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let header = StreamHeader::for_record(rec);
    let (events, _errors) = record_stream_events(rec);
    std::fs::write(path, render_stream(&header, &events))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::events::DropPhase;

    fn every_variant() -> Vec<StreamEvent> {
        vec![
            StreamEvent::Run(Event::RoundStart {
                round: 0,
                clusters: 16,
            }),
            StreamEvent::Run(Event::Dropout {
                round: 0,
                client: 3,
                phase: DropPhase::BeforeUpload,
            }),
            StreamEvent::Slot {
                round: 0,
                client: 7,
                outcome: "upload".to_string(),
            },
            StreamEvent::RoundOps {
                round: 0,
                stragglers: 2,
                peak_parked: 5,
                sim_ms: 1500.25,
            },
            StreamEvent::PhaseTiming {
                round: 2,
                ns: vec![
                    ("aggregate".to_string(), 188_021),
                    ("train".to_string(), 52_000_913),
                ],
            },
            StreamEvent::Evicted {
                round: 1,
                conn: 2,
                cause: "unsolicited_frame".to_string(),
                dropped_clients: 40,
            },
            StreamEvent::SweepPlanned { total: 8, cached: 3 },
            StreamEvent::SweepJobStart {
                idx: 0,
                label: "fedavg/cifar10/ideal/s1".to_string(),
            },
            StreamEvent::SweepJobDone {
                idx: 0,
                key: 0xdead_beef_0123_4567,
                label: "fedavg/cifar10/ideal/s1".to_string(),
                cached: false,
                final_accuracy: 0.8049999999999999,
                wall_s: 12.5,
            },
            StreamEvent::SweepJobFailed {
                idx: 1,
                label: "fedzip/cifar10/ideal/s1".to_string(),
                error: "injected".to_string(),
            },
        ]
    }

    #[test]
    fn header_round_trips() {
        let h = StreamHeader {
            schema: SCHEMA_VERSION,
            run: 0x0123_4567_89ab_cdef,
            fingerprint: 0xfedc_ba98_7654_3210,
            strategy: "fedcompress".to_string(),
        };
        let line = h.render();
        assert!(line.starts_with("EVNT1 {"));
        assert_eq!(StreamHeader::parse(&line).unwrap(), h);
        assert!(StreamHeader::parse("EVNT1 not json").is_err());
        assert!(StreamHeader::parse("{\"schema\":1}").is_err());
    }

    #[test]
    fn every_stream_variant_round_trips() {
        for ev in every_variant() {
            let line = ev.to_json_line(42);
            let j = Json::parse(&line).unwrap();
            assert_eq!(j.get("seq").unwrap().as_usize().unwrap(), 42);
            let back = StreamEvent::from_json(&j).unwrap();
            assert_eq!(back, ev, "variant {} must round-trip", ev.kind());
        }
    }

    #[test]
    fn full_stream_round_trips_with_positional_seq() {
        let h = StreamHeader {
            schema: SCHEMA_VERSION,
            run: 1,
            fingerprint: 2,
            strategy: "fedavg".to_string(),
        };
        let events = every_variant();
        let text = render_stream(&h, &events);
        let replay = parse_stream(&text);
        assert!(replay.errors.is_empty(), "{:?}", replay.errors);
        assert_eq!(replay.header, Some(h.clone()));
        assert_eq!(replay.events, events);
        // fixpoint: re-rendering the replay reproduces the bytes
        assert_eq!(render_stream(&h, &replay.events), text);
    }

    #[test]
    fn unknown_kinds_and_garbage_are_per_line_errors() {
        let text = "EVNT1 {\"fingerprint\":\"0\",\"run\":\"0\",\"schema\":9,\"strategy\":\"x\"}\n\
                    {\"kind\":\"from_the_future\",\"round\":0}\n\
                    garbage\n\
                    {\"kind\":\"round_ops\",\"round\":1,\"stragglers\":0,\"peak_parked\":0,\"sim_ms\":1}\n";
        let replay = parse_stream(text);
        assert_eq!(replay.header.as_ref().map(|h| h.schema), Some(9));
        assert_eq!(replay.events.len(), 1);
        assert_eq!(
            replay.errors.iter().map(|e| e.line).collect::<Vec<_>>(),
            vec![2, 3]
        );
    }
}
