//! Event sinks: where a run's live [`StreamEvent`]s go.
//!
//! The contract every sink honours: **`emit` never blocks the round
//! loop**. A slow disk or a stalled consumer costs events (counted in
//! a drop counter, visible as `seq` gaps in the stream), never round
//! latency. Sinks serialize with a monotonic per-sink sequence number
//! — no wall-clock reads anywhere on this path.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::obs::stream::{StreamEvent, StreamHeader};

/// A non-blocking consumer of live run events.
pub trait EventSink: Sync {
    /// Deliver one event. Must return promptly under all conditions;
    /// an overwhelmed sink drops the event instead of waiting.
    fn emit(&self, ev: &StreamEvent);

    /// False when emissions go nowhere (the [`NullSink`]). Producers
    /// use this to skip building events that would only be discarded —
    /// per-slot ops events on a 100k-client round are not free.
    fn enabled(&self) -> bool {
        true
    }
}

/// The default sink: discards everything.
pub struct NullSink;

impl EventSink for NullSink {
    fn emit(&self, _ev: &StreamEvent) {}

    fn enabled(&self) -> bool {
        false
    }
}

/// Shared instance for default sink wiring (a `&'static` target for
/// any lifetime).
pub static NULL_SINK: NullSink = NullSink;

/// Serializes events into a bounded channel of JSONL lines.
///
/// `emit` stamps each line with the next `seq`, then `try_send`s it:
/// if the channel is full the line is dropped and the drop counter
/// incremented. The sequence number is consumed either way, so a
/// reader can detect losses as gaps without trusting the writer.
pub struct BoundedSink {
    tx: SyncSender<String>,
    seq: AtomicU64,
    dropped: AtomicU64,
}

impl BoundedSink {
    pub fn new(tx: SyncSender<String>) -> BoundedSink {
        BoundedSink {
            tx,
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Events discarded because the channel was full (or closed).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events offered so far (delivered + dropped).
    pub fn offered(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }
}

impl EventSink for BoundedSink {
    fn emit(&self, ev: &StreamEvent) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let line = ev.to_json_line(seq);
        match self.tx.try_send(line) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

fn drain_to_file(
    rx: Receiver<String>,
    mut out: std::io::BufWriter<std::fs::File>,
) -> std::io::Result<()> {
    for line in rx {
        out.write_all(line.as_bytes())?;
        out.write_all(b"\n")?;
        // flush per event so `runs tail --follow` sees the stream live;
        // event rate is per-round, not per-byte, so this is cheap
        out.flush()?;
    }
    out.flush()
}

/// A [`BoundedSink`] drained by a dedicated writer thread into a
/// `<store>/events/<run_key>.jsonl` stream file. The file starts with
/// the `EVNT1` header line; every subsequent line is one event.
pub struct FileSink {
    sink: BoundedSink,
    writer: JoinHandle<std::io::Result<()>>,
    path: PathBuf,
}

impl FileSink {
    /// Create the stream file (and its parent directory), write the
    /// header line, and start the writer thread. `capacity` bounds the
    /// in-flight channel; past it, events drop rather than block.
    pub fn create(path: &Path, header: &StreamHeader, capacity: usize) -> Result<FileSink> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        file.write_all(header.render().as_bytes())?;
        file.write_all(b"\n")?;
        file.flush()?;
        let (tx, rx) = sync_channel(capacity.max(1));
        let writer = std::thread::Builder::new()
            .name("obs-stream-writer".to_string())
            .spawn(move || drain_to_file(rx, file))?;
        Ok(FileSink {
            sink: BoundedSink::new(tx),
            writer,
            path: path.to_path_buf(),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Events dropped so far (final count is returned by `finish`).
    pub fn dropped(&self) -> u64 {
        self.sink.dropped()
    }

    /// Close the stream: stop accepting events, join the writer, and
    /// return how many events were dropped over the sink's lifetime.
    pub fn finish(self) -> Result<u64> {
        let FileSink { sink, writer, path } = self;
        let dropped = sink.dropped();
        drop(sink); // closes the channel; the writer drains and exits
        match writer.join() {
            Ok(Ok(())) => Ok(dropped),
            Ok(Err(e)) => Err(anyhow!("event stream {}: {e}", path.display())),
            Err(_) => Err(anyhow!("event stream writer thread panicked")),
        }
    }
}

impl EventSink for FileSink {
    fn emit(&self, ev: &StreamEvent) {
        self.sink.emit(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::stream::parse_stream;

    fn ev(round: usize) -> StreamEvent {
        StreamEvent::RoundOps {
            round,
            stragglers: 0,
            peak_parked: 0,
            sim_ms: 0.0,
        }
    }

    #[test]
    fn bounded_sink_drops_instead_of_blocking() {
        let (tx, rx) = sync_channel(2);
        let sink = BoundedSink::new(tx);
        // nothing drains rx: after 2 queued lines every emit must
        // return immediately and count a drop
        for r in 0..10 {
            sink.emit(&ev(r));
        }
        assert_eq!(sink.offered(), 10);
        assert_eq!(sink.dropped(), 8);
        let delivered: Vec<String> = rx.try_iter().collect();
        assert_eq!(delivered.len(), 2);
        // seq gaps expose the drops to any reader
        let text = delivered.join("\n");
        let replay = parse_stream(&text);
        assert!(replay.errors.is_empty());
        assert_eq!(replay.events.len(), 2);
    }

    #[test]
    fn file_sink_writes_header_then_events_and_reports_drops() {
        let dir = std::env::temp_dir().join("fedcompress_obs_sink");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("events").join("demo.jsonl");
        let header = StreamHeader {
            schema: crate::obs::stream::SCHEMA_VERSION,
            run: 0xabcd,
            fingerprint: 0x1234,
            strategy: "fedavg".to_string(),
        };
        let sink = FileSink::create(&path, &header, 64).unwrap();
        for r in 0..5 {
            sink.emit(&ev(r));
        }
        let dropped = sink.finish().unwrap();
        assert_eq!(dropped, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("EVNT1 "));
        let replay = parse_stream(&text);
        assert!(replay.errors.is_empty());
        let h = replay.header.unwrap();
        assert_eq!(h.run, 0xabcd);
        assert_eq!(h.strategy, "fedavg");
        assert_eq!(replay.events.len(), 5);
    }
}
