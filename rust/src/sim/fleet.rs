//! Fleet profiles: who the clients *are*, physically.
//!
//! The paper's evaluation (like most FL reproductions) assumes an ideal
//! fleet — every selected client has infinite bandwidth, identical
//! compute, and always reports. Real fleets are dominated by
//! heterogeneity (arXiv 2107.10996), so this module assigns every
//! client a [`ClientProfile`]: a compute tier drawn from the Table-2
//! [`DeviceProfile`]s, up/down link bandwidth, an availability rate,
//! and a straggler propensity. Profiles are drawn seed-deterministically
//! from a named [`FleetPreset`], so fleet runs are bit-reproducible and
//! paired across strategies.

use std::fmt;

use crate::edge::device::{DeviceProfile, EDGE_DEVICES};
use crate::util::rng::Rng;

/// The three named fleet scenarios of `exp/fleet.rs`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FleetPreset {
    /// The pre-sim world: one fast device class, gigabit symmetric
    /// links, perfect availability, no stragglers. Runs under `Ideal`
    /// are byte-identical to runs without any fleet machinery.
    #[default]
    Ideal,
    /// Phones on LTE/Wi-Fi: mixed device tiers, 5-20 Mbps uplinks,
    /// occasional unavailability and mild stragglers.
    Mobile,
    /// The stress scenario: slow devices over 1-5 Mbps uplinks, flaky
    /// availability, frequent heavy stragglers.
    Hostile,
}

impl FleetPreset {
    pub const ALL: [FleetPreset; 3] =
        [FleetPreset::Ideal, FleetPreset::Mobile, FleetPreset::Hostile];

    pub fn name(&self) -> &'static str {
        match self {
            FleetPreset::Ideal => "ideal",
            FleetPreset::Mobile => "mobile",
            FleetPreset::Hostile => "hostile",
        }
    }

    pub fn from_name(name: &str) -> Result<FleetPreset, UnknownFleetPreset> {
        match name.to_ascii_lowercase().as_str() {
            "ideal" => Ok(FleetPreset::Ideal),
            "mobile" => Ok(FleetPreset::Mobile),
            "hostile" => Ok(FleetPreset::Hostile),
            _ => Err(UnknownFleetPreset {
                name: name.to_string(),
            }),
        }
    }

    /// Parse a comma-separated preset list (`--fleets a,b` / sweep
    /// spec `fleets = a,b`); `all` expands to every preset. Blank
    /// segments are skipped, so trailing commas are harmless.
    pub fn parse_list(list: &str) -> Result<Vec<FleetPreset>, UnknownFleetPreset> {
        let mut out = Vec::new();
        for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if name.eq_ignore_ascii_case("all") {
                out.extend(FleetPreset::ALL);
            } else {
                out.push(FleetPreset::from_name(name)?);
            }
        }
        Ok(out)
    }

    /// Sampling parameters the preset draws client profiles from.
    fn params(&self) -> PresetParams {
        match self {
            FleetPreset::Ideal => PresetParams {
                // every client is the fastest device tier on a fat pipe
                device_weights: [0.0, 1.0, 0.0],
                up_mbps: (1000.0, 1000.0),
                down_mbps: (1000.0, 1000.0),
                availability: (1.0, 1.0),
                straggler_prob: 0.0,
                straggler_slowdown: (1.0, 1.0),
            },
            FleetPreset::Mobile => PresetParams {
                device_weights: [0.6, 0.25, 0.15],
                up_mbps: (5.0, 20.0),
                down_mbps: (20.0, 50.0),
                availability: (0.92, 1.0),
                straggler_prob: 0.1,
                straggler_slowdown: (1.5, 3.0),
            },
            FleetPreset::Hostile => PresetParams {
                device_weights: [0.5, 0.1, 0.4],
                up_mbps: (1.0, 5.0),
                down_mbps: (5.0, 20.0),
                availability: (0.7, 0.95),
                straggler_prob: 0.25,
                straggler_slowdown: (2.0, 6.0),
            },
        }
    }
}

/// Typed parse failure for `--fleet` / `set("fleet", ...)`, in the
/// style of `WireBlob::ensure_param_count`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownFleetPreset {
    pub name: String,
}

impl fmt::Display for UnknownFleetPreset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown fleet preset '{}' (known: ideal, mobile, hostile)",
            self.name
        )
    }
}

impl std::error::Error for UnknownFleetPreset {}

/// The fleet knob block inside `FedConfig`. The derived default is the
/// ideal fleet with no extra dropout and no reporting deadline —
/// exactly the pre-sim semantics, so existing runs stay byte-identical.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetConfig {
    pub preset: FleetPreset,
    /// Extra i.i.d. per-selected-client per-round dropout probability,
    /// layered on top of each client's availability (`--dropout`).
    pub dropout: f64,
    /// Round reporting deadline in simulated seconds; clients that
    /// cannot report in time are cut (`--deadline-s`). 0 disables it.
    pub deadline_s: f64,
    /// Edge-tier aggregation: group every `edge_of` consecutive
    /// selected clients behind one edge aggregator that pre-folds their
    /// uploads before the coordinator sees them (`--edge-of`, sweep
    /// axis `edge_of`). 0 disables the tier — every client uploads
    /// directly, the pre-sim semantics.
    pub edge_of: usize,
}

impl FleetConfig {
    /// True when the config cannot perturb a run: ideal fleet, no extra
    /// dropout, no edge tier. (A deadline on an ideal gigabit fleet can
    /// still cut clients, so it keeps the config non-trivial; an edge
    /// tier reorders the aggregation tree, so it is never trivial.)
    pub fn is_ideal(&self) -> bool {
        self.preset == FleetPreset::Ideal
            && self.dropout == 0.0
            && self.deadline_s == 0.0
            && self.edge_of == 0
    }
}

/// Per-preset sampling ranges (uniform unless noted).
struct PresetParams {
    device_weights: [f64; 3],
    up_mbps: (f64, f64),
    down_mbps: (f64, f64),
    availability: (f64, f64),
    straggler_prob: f64,
    straggler_slowdown: (f64, f64),
}

/// One client's physical situation for a whole run.
#[derive(Clone, Debug)]
pub struct ClientProfile {
    /// Compute tier (a Table-2 edge device spec).
    pub device: DeviceProfile,
    pub up_mbps: f64,
    pub down_mbps: f64,
    /// Per-round probability the client is reachable at all.
    pub availability: f64,
    /// Per-round probability of a straggler slowdown when healthy.
    pub straggler_prob: f64,
}

/// The materialized fleet: one profile per client plus the preset-level
/// straggler slowdown range the fault schedule draws from.
#[derive(Clone, Debug)]
pub struct FleetProfile {
    pub preset: FleetPreset,
    pub clients: Vec<ClientProfile>,
    /// Straggler slowdown factor range (multiplies local train time).
    pub straggler_slowdown: (f64, f64),
}

/// Uniform draw in `[lo, hi)` (shared with the fault schedule's
/// straggler slowdown draws so the two can never diverge).
pub(crate) fn uniform_in(rng: &mut Rng, (lo, hi): (f64, f64)) -> f64 {
    lo + rng.f64() * (hi - lo)
}

impl FleetProfile {
    /// Draw `clients` profiles for a preset, seed-deterministically.
    /// Each client's draws come from an independent RNG fork, so the
    /// profile of client k does not depend on the fleet size.
    pub fn build(cfg: &FleetConfig, clients: usize, seed: u64) -> FleetProfile {
        let params = cfg.preset.params();
        // fedlint:allow(rng-discipline) -- fleet-profile root stream, domain-separated from training seeds
        let base = Rng::new(seed ^ 0xF1EE7);
        let profiles = (0..clients)
            .map(|k| {
                let mut rng = base.fork(k as u64);
                let tier = rng.categorical(&params.device_weights);
                ClientProfile {
                    device: EDGE_DEVICES[tier].clone(),
                    up_mbps: uniform_in(&mut rng, params.up_mbps),
                    down_mbps: uniform_in(&mut rng, params.down_mbps),
                    availability: uniform_in(&mut rng, params.availability),
                    straggler_prob: params.straggler_prob,
                }
            })
            .collect();
        FleetProfile {
            preset: cfg.preset,
            clients: profiles,
            straggler_slowdown: params.straggler_slowdown,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_names_round_trip() {
        for p in FleetPreset::ALL {
            assert_eq!(FleetPreset::from_name(p.name()).unwrap(), p);
        }
        assert_eq!(FleetPreset::from_name("MOBILE").unwrap(), FleetPreset::Mobile);
        let e = FleetPreset::from_name("cosmic").unwrap_err();
        assert!(e.to_string().contains("cosmic"));
        assert!(e.to_string().contains("ideal"));
    }

    #[test]
    fn preset_lists_parse_with_all_sugar() {
        assert_eq!(
            FleetPreset::parse_list("ideal, hostile,").unwrap(),
            vec![FleetPreset::Ideal, FleetPreset::Hostile]
        );
        assert_eq!(FleetPreset::parse_list("all").unwrap(), FleetPreset::ALL.to_vec());
        assert_eq!(
            FleetPreset::parse_list("mobile,ALL").unwrap().len(),
            1 + FleetPreset::ALL.len()
        );
        assert!(FleetPreset::parse_list("ideal,marsnet").is_err());
        assert!(FleetPreset::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn default_fleet_is_ideal_and_trivial() {
        let f = FleetConfig::default();
        assert_eq!(f.preset, FleetPreset::Ideal);
        assert!(f.is_ideal());
        let perturbed = FleetConfig {
            dropout: 0.1,
            ..FleetConfig::default()
        };
        assert!(!perturbed.is_ideal());
        let edged = FleetConfig {
            edge_of: 8,
            ..FleetConfig::default()
        };
        assert!(!edged.is_ideal(), "an edge tier reorders aggregation");
    }

    #[test]
    fn build_is_seed_deterministic() {
        let cfg = FleetConfig {
            preset: FleetPreset::Mobile,
            ..FleetConfig::default()
        };
        let a = FleetProfile::build(&cfg, 12, 7);
        let b = FleetProfile::build(&cfg, 12, 7);
        for (x, y) in a.clients.iter().zip(&b.clients) {
            assert_eq!(x.up_mbps, y.up_mbps);
            assert_eq!(x.down_mbps, y.down_mbps);
            assert_eq!(x.availability, y.availability);
            assert_eq!(x.device.name, y.device.name);
        }
        let c = FleetProfile::build(&cfg, 12, 8);
        let ups = |p: &FleetProfile| p.clients.iter().map(|x| x.up_mbps).collect::<Vec<_>>();
        assert_ne!(ups(&a), ups(&c), "a different seed must redraw the fleet");
    }

    #[test]
    fn client_profile_independent_of_fleet_size() {
        let cfg = FleetConfig {
            preset: FleetPreset::Hostile,
            ..FleetConfig::default()
        };
        let small = FleetProfile::build(&cfg, 4, 42);
        let large = FleetProfile::build(&cfg, 40, 42);
        for k in 0..4 {
            assert_eq!(small.clients[k].up_mbps, large.clients[k].up_mbps);
            assert_eq!(small.clients[k].availability, large.clients[k].availability);
        }
    }

    #[test]
    fn ideal_profiles_are_perfect() {
        let p = FleetProfile::build(&FleetConfig::default(), 8, 1);
        for c in &p.clients {
            assert_eq!(c.availability, 1.0);
            assert_eq!(c.straggler_prob, 0.0);
            assert_eq!(c.up_mbps, 1000.0);
        }
        assert_eq!(p.straggler_slowdown, (1.0, 1.0));
    }

    #[test]
    fn presets_are_ordered_by_hostility() {
        let mk = |preset| {
            let cfg = FleetConfig {
                preset,
                ..FleetConfig::default()
            };
            FleetProfile::build(&cfg, 32, 3)
        };
        let mean_up = |p: &FleetProfile| {
            p.clients.iter().map(|c| c.up_mbps).sum::<f64>() / p.clients.len() as f64
        };
        let mean_avail = |p: &FleetProfile| {
            p.clients.iter().map(|c| c.availability).sum::<f64>() / p.clients.len() as f64
        };
        let (ideal, mobile, hostile) = (
            mk(FleetPreset::Ideal),
            mk(FleetPreset::Mobile),
            mk(FleetPreset::Hostile),
        );
        assert!(mean_up(&ideal) > mean_up(&mobile));
        assert!(mean_up(&mobile) > mean_up(&hostile));
        assert!(mean_avail(&mobile) > mean_avail(&hostile));
    }
}
