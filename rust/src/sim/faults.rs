//! Seed-deterministic fault schedule: who drops, who straggles, when.
//!
//! Every (round, client) pair gets its fate from an independent RNG
//! fork of a dedicated fault stream, so fates are bit-reproducible,
//! independent of evaluation order, and — crucially — drawing them
//! consumes nothing from the selection/training RNG streams. An ideal
//! fleet therefore produces byte-identical runs whether or not the
//! schedule is consulted.

use crate::util::rng::Rng;

use super::fleet::{uniform_in, FleetProfile};

/// What happens to one selected client in one round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClientFate {
    /// Client completes the round; local train time is multiplied by
    /// `slowdown` (1.0 = on time, >1.0 = straggler).
    Healthy { slowdown: f64 },
    /// Client is unreachable before training starts (never receives or
    /// never acts on the dispatch).
    DropBeforeTrain,
    /// Client would train but its upload is lost (battery, network,
    /// kill). The server observes the same nothing as `DropBeforeTrain`
    /// — the coordinator therefore elides the client's (discarded)
    /// training work; only the logged drop phase differs. A sim
    /// extension that costs client energy/compute would spend the
    /// train term for this variant.
    DropBeforeUpload,
}

impl ClientFate {
    pub fn is_drop(&self) -> bool {
        !matches!(self, ClientFate::Healthy { .. })
    }

    /// Straggler slowdown factor (1.0 for drops and on-time clients).
    pub fn slowdown(&self) -> f64 {
        match self {
            ClientFate::Healthy { slowdown } => *slowdown,
            _ => 1.0,
        }
    }

    pub fn is_straggler(&self) -> bool {
        matches!(self, ClientFate::Healthy { slowdown } if *slowdown > 1.0)
    }
}

/// Per-run fault schedule derived from the fleet profile plus the
/// config's extra dropout rate.
#[derive(Clone, Debug)]
pub struct FaultSchedule {
    base: Rng,
    /// Effective per-round drop probability per client:
    /// `1 - availability_k * (1 - dropout)`.
    drop_prob: Vec<f64>,
    straggler_prob: Vec<f64>,
    slowdown: (f64, f64),
}

impl FaultSchedule {
    pub fn new(profile: &FleetProfile, dropout: f64, seed: u64) -> FaultSchedule {
        FaultSchedule {
            // fedlint:allow(rng-discipline) -- fault-schedule root stream, domain-separated from training seeds
            base: Rng::new(seed ^ 0xFA17),
            drop_prob: profile
                .clients
                .iter()
                .map(|c| 1.0 - c.availability * (1.0 - dropout))
                .collect(),
            straggler_prob: profile.clients.iter().map(|c| c.straggler_prob).collect(),
            slowdown: profile.straggler_slowdown,
        }
    }

    /// The fate of `client` in `round`. Pure given (round, client):
    /// repeated calls agree, and no shared RNG state is consumed.
    pub fn fate(&self, round: usize, client: usize) -> ClientFate {
        let mut rng = self.base.fork(round as u64 * 1_000_003 + client as u64);
        let p_drop = self.drop_prob.get(client).copied().unwrap_or(0.0);
        if p_drop > 0.0 && rng.f64() < p_drop {
            // split drops evenly between the two phases
            return if rng.f64() < 0.5 {
                ClientFate::DropBeforeTrain
            } else {
                ClientFate::DropBeforeUpload
            };
        }
        let p_strag = self.straggler_prob.get(client).copied().unwrap_or(0.0);
        if p_strag > 0.0 && rng.f64() < p_strag {
            return ClientFate::Healthy {
                slowdown: uniform_in(&mut rng, self.slowdown),
            };
        }
        ClientFate::Healthy { slowdown: 1.0 }
    }

    /// Fates for a round's selected set, in selection order.
    pub fn round_fates(&self, round: usize, selected: &[usize]) -> Vec<ClientFate> {
        selected.iter().map(|&k| self.fate(round, k)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fleet::{FleetConfig, FleetPreset, FleetProfile};

    fn profile(preset: FleetPreset) -> FleetProfile {
        let cfg = FleetConfig {
            preset,
            ..FleetConfig::default()
        };
        FleetProfile::build(&cfg, 16, 11)
    }

    #[test]
    fn ideal_fleet_never_faults() {
        let sched = FaultSchedule::new(&profile(FleetPreset::Ideal), 0.0, 11);
        for round in 0..50 {
            for client in 0..16 {
                assert_eq!(
                    sched.fate(round, client),
                    ClientFate::Healthy { slowdown: 1.0 }
                );
            }
        }
    }

    #[test]
    fn fates_are_deterministic_and_order_independent() {
        let sched = FaultSchedule::new(&profile(FleetPreset::Hostile), 0.2, 11);
        let forward: Vec<ClientFate> = (0..16).map(|k| sched.fate(3, k)).collect();
        let mut backward: Vec<ClientFate> = (0..16).rev().map(|k| sched.fate(3, k)).collect();
        backward.reverse();
        assert_eq!(forward, backward);
        // a fresh schedule from the same inputs agrees exactly
        let again = FaultSchedule::new(&profile(FleetPreset::Hostile), 0.2, 11);
        assert_eq!(again.round_fates(3, &(0..16).collect::<Vec<_>>()), forward);
    }

    #[test]
    fn full_dropout_drops_everyone() {
        let sched = FaultSchedule::new(&profile(FleetPreset::Ideal), 1.0, 5);
        let mut before_train = 0;
        let mut before_upload = 0;
        for round in 0..20 {
            for client in 0..16 {
                match sched.fate(round, client) {
                    ClientFate::DropBeforeTrain => before_train += 1,
                    ClientFate::DropBeforeUpload => before_upload += 1,
                    f => panic!("expected a drop, got {f:?}"),
                }
            }
        }
        // both phases occur (split is ~50/50)
        assert!(before_train > 50 && before_upload > 50);
    }

    #[test]
    fn dropout_rate_lands_near_requested() {
        let sched = FaultSchedule::new(&profile(FleetPreset::Ideal), 0.25, 5);
        let n = 400 * 16;
        let drops: usize = (0..400)
            .flat_map(|r| (0..16).map(move |k| (r, k)))
            .filter(|&(r, k)| sched.fate(r, k).is_drop())
            .count();
        let rate = drops as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "observed drop rate {rate}");
    }

    #[test]
    fn hostile_fleet_straggles_more_than_mobile() {
        let count = |preset| {
            let sched = FaultSchedule::new(&profile(preset), 0.0, 11);
            (0..200)
                .flat_map(|r| (0..16).map(move |k| (r, k)))
                .filter(|&(r, k)| sched.fate(r, k).is_straggler())
                .count()
        };
        let mobile = count(FleetPreset::Mobile);
        let hostile = count(FleetPreset::Hostile);
        assert!(mobile > 0, "mobile fleet should straggle sometimes");
        assert!(hostile > mobile, "hostile {hostile} vs mobile {mobile}");
    }

    #[test]
    fn straggler_slowdowns_stay_in_preset_band() {
        let p = profile(FleetPreset::Hostile);
        let sched = FaultSchedule::new(&p, 0.0, 11);
        let (lo, hi) = p.straggler_slowdown;
        for round in 0..100 {
            for client in 0..16 {
                let f = sched.fate(round, client);
                if f.is_straggler() {
                    assert!(f.slowdown() >= lo && f.slowdown() <= hi);
                }
            }
        }
    }
}
