//! Fleet simulation layer: heterogeneous clients, fault injection, and
//! simulated round clocks.
//!
//! Data flow (see ARCHITECTURE.md):
//!
//! ```text
//! FleetConfig (preset + dropout + deadline, part of FedConfig)
//!   -> FleetProfile::build       per-client device / bandwidth /
//!                                availability draws  (fleet.rs)
//!   -> FaultSchedule             seed-deterministic per-(round, client)
//!                                fates: drops + straggler slowdowns
//!                                (faults.rs)
//!   -> RoundClock                ledgered bytes + train FLOPs ->
//!                                simulated seconds, deadline cuts
//!                                (clock.rs)
//! ```
//!
//! The coordinator consults the layer through [`FleetSim`], one handle
//! per run. All randomness comes from dedicated streams
//! (`seed ^ 0xF1EE7`, `seed ^ 0xFA17`), never from the selection or
//! training streams — with the default (ideal) fleet every existing run
//! is byte-identical to the pre-sim coordinator.

pub mod clock;
pub mod faults;
pub mod fleet;

pub use clock::RoundClock;
pub use faults::{ClientFate, FaultSchedule};
pub use fleet::{ClientProfile, FleetConfig, FleetPreset, FleetProfile, UnknownFleetPreset};

/// Per-run simulation handle: the materialized fleet, its fault
/// schedule, and the round clock, built once from a `FleetConfig`.
#[derive(Clone, Debug)]
pub struct FleetSim {
    profile: FleetProfile,
    faults: FaultSchedule,
    clock: RoundClock,
}

impl FleetSim {
    /// `train_flops_per_sample` is the per-sample per-epoch training
    /// cost (forward + backward) of the run's model.
    pub fn new(
        cfg: &FleetConfig,
        clients: usize,
        seed: u64,
        train_flops_per_sample: f64,
    ) -> FleetSim {
        let profile = FleetProfile::build(cfg, clients, seed);
        let faults = FaultSchedule::new(&profile, cfg.dropout, seed);
        FleetSim {
            profile,
            faults,
            clock: RoundClock {
                train_flops_per_sample,
                deadline_s: cfg.deadline_s,
            },
        }
    }

    pub fn profile(&self) -> &FleetProfile {
        &self.profile
    }

    pub fn clock(&self) -> &RoundClock {
        &self.clock
    }

    /// Fate of a selected client in a round (pure; see `FaultSchedule`).
    pub fn fate(&self, round: usize, client: usize) -> ClientFate {
        self.faults.fate(round, client)
    }

    /// Fates for a round's selected set, in selection order.
    pub fn round_fates(&self, round: usize, selected: &[usize]) -> Vec<ClientFate> {
        self.faults.round_fates(round, selected)
    }

    /// Simulated completion time for one client's round.
    pub fn client_time_s(
        &self,
        client: usize,
        down_bytes: usize,
        up_bytes: usize,
        samples: usize,
        epochs: usize,
        slowdown: f64,
    ) -> f64 {
        self.clock.client_time_s(
            &self.profile.clients[client],
            down_bytes,
            up_bytes,
            samples,
            epochs,
            slowdown,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_handle_wires_the_parts_together() {
        let cfg = FleetConfig {
            preset: FleetPreset::Mobile,
            dropout: 0.1,
            deadline_s: 30.0,
            edge_of: 0,
        };
        let sim = FleetSim::new(&cfg, 8, 42, 3.0e6);
        assert_eq!(sim.profile().clients.len(), 8);
        assert_eq!(sim.clock().deadline_s, 30.0);
        // deterministic across handles
        let again = FleetSim::new(&cfg, 8, 42, 3.0e6);
        for round in 0..10 {
            for k in 0..8 {
                assert_eq!(sim.fate(round, k), again.fate(round, k));
                let t = sim.client_time_s(k, 50_000, 10_000, 64, 2, 1.0);
                assert_eq!(t, again.client_time_s(k, 50_000, 10_000, 64, 2, 1.0));
                assert!(t > 0.0);
            }
        }
    }
}
