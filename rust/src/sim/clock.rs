//! Simulated round wall-clock: bytes + FLOPs -> seconds.
//!
//! Converts the quantities the coordinator already accounts exactly —
//! `CommLedger` byte counts per direction and the model's train FLOPs —
//! into per-client round completion times under a fleet profile:
//!
//! ```text
//! t_client = down_bytes / down_bw
//!          + slowdown * epochs * samples * train_flops_per_sample / device_rate
//!          + up_bytes / up_bw
//! ```
//!
//! The round ends when the slowest *reporting* client finishes; if a
//! reporting deadline is set, the server cuts the round there instead
//! and clients that could not make it are dropped. Without a deadline,
//! dropped clients are assumed detected out-of-band (the idealized
//! pre-sim behavior), so they do not hold the round open.

use super::fleet::ClientProfile;

/// Converts per-client byte counts and train work into simulated time.
#[derive(Clone, Copy, Debug)]
pub struct RoundClock {
    /// FLOPs per training sample per epoch (forward + backward).
    pub train_flops_per_sample: f64,
    /// Reporting deadline in seconds; 0 disables deadline enforcement.
    pub deadline_s: f64,
}

impl RoundClock {
    /// Simulated seconds for one client to receive the dispatch, run
    /// local training, and push its upload.
    pub fn client_time_s(
        &self,
        p: &ClientProfile,
        down_bytes: usize,
        up_bytes: usize,
        samples: usize,
        epochs: usize,
        slowdown: f64,
    ) -> f64 {
        let down_s = down_bytes as f64 * 8.0 / (p.down_mbps * 1e6);
        let up_s = up_bytes as f64 * 8.0 / (p.up_mbps * 1e6);
        let train_flops = self.train_flops_per_sample * samples as f64 * epochs as f64;
        let train_s = slowdown * train_flops / (p.device.f32_gflops * 1e9);
        down_s + train_s + up_s
    }

    /// Would a client finishing at `t` seconds miss the deadline?
    pub fn over_deadline(&self, t: f64) -> bool {
        self.deadline_s > 0.0 && t > self.deadline_s
    }

    /// Round wall-clock given the slowest reporting client and whether
    /// any selected client was lost (fault or deadline). With a
    /// deadline, any loss means the server waited the full deadline.
    pub fn round_time_s(&self, max_reporting_s: f64, any_lost: bool) -> f64 {
        if self.deadline_s > 0.0 && any_lost {
            self.deadline_s
        } else {
            max_reporting_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fleet::{FleetConfig, FleetPreset, FleetProfile};

    fn client(preset: FleetPreset) -> ClientProfile {
        let cfg = FleetConfig {
            preset,
            ..FleetConfig::default()
        };
        FleetProfile::build(&cfg, 1, 9).clients[0].clone()
    }

    fn clock(deadline_s: f64) -> RoundClock {
        RoundClock {
            train_flops_per_sample: 3.0e6,
            deadline_s,
        }
    }

    #[test]
    fn more_bytes_take_longer() {
        let p = client(FleetPreset::Mobile);
        let c = clock(0.0);
        let small = c.client_time_s(&p, 10_000, 10_000, 64, 2, 1.0);
        let big = c.client_time_s(&p, 100_000, 100_000, 64, 2, 1.0);
        assert!(big > small);
        assert!(small > 0.0 && small.is_finite());
    }

    #[test]
    fn slowdown_scales_only_the_train_term() {
        let p = client(FleetPreset::Mobile);
        let c = clock(0.0);
        let base = c.client_time_s(&p, 0, 0, 64, 2, 1.0);
        let slow = c.client_time_s(&p, 0, 0, 64, 2, 3.0);
        assert!((slow - 3.0 * base).abs() < 1e-12);
        // with wire bytes, the comm terms are unaffected by slowdown
        let base_w = c.client_time_s(&p, 80_000, 20_000, 64, 2, 1.0);
        let slow_w = c.client_time_s(&p, 80_000, 20_000, 64, 2, 3.0);
        assert!((slow_w - base_w - 2.0 * base).abs() < 1e-12);
    }

    #[test]
    fn compression_buys_wall_clock_on_thin_uplinks() {
        // the question the sim exists to answer: fewer upload bytes ->
        // faster rounds on a bandwidth-bound fleet
        let p = client(FleetPreset::Hostile);
        let c = clock(0.0);
        let dense = c.client_time_s(&p, 80_000, 80_000, 64, 2, 1.0);
        let compressed = c.client_time_s(&p, 80_000, 10_000, 64, 2, 1.0);
        assert!(dense > compressed * 1.5, "{dense} vs {compressed}");
    }

    #[test]
    fn deadline_classification() {
        let c = clock(2.0);
        assert!(!c.over_deadline(1.99));
        assert!(c.over_deadline(2.01));
        let off = clock(0.0);
        assert!(!off.over_deadline(1e12));
    }

    #[test]
    fn round_time_waits_deadline_only_on_loss() {
        let c = clock(5.0);
        assert_eq!(c.round_time_s(1.25, false), 1.25);
        assert_eq!(c.round_time_s(1.25, true), 5.0);
        let off = clock(0.0);
        assert_eq!(off.round_time_s(1.25, true), 1.25);
    }

    #[test]
    fn ideal_fleet_rounds_are_fast() {
        let p = client(FleetPreset::Ideal);
        let h = client(FleetPreset::Hostile);
        let c = clock(0.0);
        let t_ideal = c.client_time_s(&p, 80_000, 80_000, 96, 6, 1.0);
        let t_hostile = c.client_time_s(&h, 80_000, 80_000, 96, 6, 1.0);
        assert!(t_ideal < t_hostile);
    }
}
