//! In-memory dataset container + batching.

use crate::util::rng::Rng;

/// One labeled example: flat NCHW-ordered features + class id.
#[derive(Clone, Debug)]
pub struct Sample {
    pub x: Vec<f32>,
    pub y: i32,
}

/// A materialized dataset (train or test split, or one client's shard).
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub samples: Vec<Sample>,
    /// feature shape as (channels, height, width)
    pub shape: (usize, usize, usize),
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn feature_len(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    /// Class histogram (length num_classes).
    pub fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for s in &self.samples {
            h[s.y as usize] += 1;
        }
        h
    }

    /// Shuffled epoch of full batches: each batch is (x-flat, y) with
    /// exactly `batch` samples; a short tail wraps around with samples
    /// from the epoch start so every batch is full (static HLO shapes).
    pub fn epoch_batches(&self, batch: usize, rng: &mut Rng) -> Vec<(Vec<f32>, Vec<i32>)> {
        assert!(batch > 0 && !self.is_empty());
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        let nb = self.len().div_ceil(batch);
        let mut out = Vec::with_capacity(nb);
        for b in 0..nb {
            let mut xs = Vec::with_capacity(batch * self.feature_len());
            let mut ys = Vec::with_capacity(batch);
            for k in 0..batch {
                let idx = order[(b * batch + k) % self.len()];
                xs.extend_from_slice(&self.samples[idx].x);
                ys.push(self.samples[idx].y);
            }
            out.push((xs, ys));
        }
        out
    }

    /// Deterministic full batches for evaluation. The final short batch
    /// is padded by repeating *its own first sample* (consumers correct
    /// metrics by measuring that sample's contribution separately); the
    /// returned `valid` count per batch excludes padding.
    pub fn eval_batches(&self, batch: usize) -> Vec<(Vec<f32>, Vec<i32>, usize)> {
        assert!(batch > 0 && !self.is_empty());
        let nb = self.len().div_ceil(batch);
        let mut out = Vec::with_capacity(nb);
        for b in 0..nb {
            let mut xs = Vec::with_capacity(batch * self.feature_len());
            let mut ys = Vec::with_capacity(batch);
            let mut valid = 0usize;
            for k in 0..batch {
                let i = b * batch + k;
                let idx = if i < self.len() {
                    valid += 1;
                    i
                } else {
                    b * batch // pad with the batch's own first sample
                };
                xs.extend_from_slice(&self.samples[idx].x);
                ys.push(self.samples[idx].y);
            }
            out.push((xs, ys, valid));
        }
        out
    }

    /// Split off the first `n` samples as a new dataset (used to carve
    /// the small unlabeled validation shard D_u from a client's data).
    pub fn take(&self, n: usize) -> (Dataset, Dataset) {
        let n = n.min(self.len());
        let head = Dataset {
            samples: self.samples[..n].to_vec(),
            shape: self.shape,
            num_classes: self.num_classes,
        };
        let tail = Dataset {
            samples: self.samples[n..].to_vec(),
            shape: self.shape,
            num_classes: self.num_classes,
        };
        (head, tail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> Dataset {
        Dataset {
            samples: (0..n)
                .map(|i| Sample {
                    x: vec![i as f32; 4],
                    y: (i % 3) as i32,
                })
                .collect(),
            shape: (1, 2, 2),
            num_classes: 3,
        }
    }

    #[test]
    fn epoch_batches_are_full_and_cover() {
        let d = tiny(10);
        let mut rng = Rng::new(0);
        let batches = d.epoch_batches(4, &mut rng);
        assert_eq!(batches.len(), 3); // ceil(10/4)
        for (xs, ys) in &batches {
            assert_eq!(ys.len(), 4);
            assert_eq!(xs.len(), 16);
        }
    }

    #[test]
    fn eval_batches_track_valid_counts() {
        let d = tiny(10);
        let batches = d.eval_batches(4);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].2, 4);
        assert_eq!(batches[1].2, 4);
        assert_eq!(batches[2].2, 2);
    }

    #[test]
    fn label_histogram_counts() {
        let d = tiny(9);
        assert_eq!(d.label_histogram(), vec![3, 3, 3]);
    }

    #[test]
    fn take_splits() {
        let d = tiny(10);
        let (a, b) = d.take(3);
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 7);
    }
}
