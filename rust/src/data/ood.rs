//! Out-of-distribution data for server-side self-compression.
//!
//! The paper uses StyleGAN-Oriented noise images (vision) and
//! Librispeech segments (audio), citing Baradad 2021 / Asano & Saeed
//! 2023 for the claim that *noise-like* data suffices for distillation.
//! We generate exactly that class of data procedurally:
//!   vision  -> oriented filtered noise (random orientation fields with
//!              band-limited spatial correlation — StyleGAN-Oriented's
//!              statistical signature);
//!   audio   -> smooth colored-noise spectrograms (speech-shaped 1/f
//!              band energy, no class structure).

use super::dataset::{Dataset, Sample};
use crate::util::rng::Rng;

/// Oriented band-limited noise image, channels x h x w.
fn oriented_noise(c: usize, h: usize, w: usize, rng: &mut Rng) -> Vec<f32> {
    // random orientation + wavelength; superpose a few oriented waves on
    // top of white noise, then soft-clip. Cheap surrogate for oriented
    // GAN noise: anisotropic second-order statistics, no semantics.
    let mut x = vec![0.0f32; c * h * w];
    let n_waves = 4 + rng.below(4);
    for _ in 0..n_waves {
        let angle = rng.f32() * std::f32::consts::PI;
        let (s, co) = angle.sin_cos();
        let freq = 0.5 + rng.f32() * 3.0;
        let phase = rng.f32() * std::f32::consts::TAU;
        let amp = 0.4 + rng.f32();
        let ch = rng.below(c);
        for i in 0..h {
            for j in 0..w {
                let u = (co * j as f32 + s * i as f32) / w as f32;
                x[ch * h * w + i * w + j] +=
                    amp * (freq * u * std::f32::consts::TAU + phase).cos();
            }
        }
    }
    for v in &mut x {
        *v += rng.normal() * 0.5;
        *v = v.tanh() * 2.0;
    }
    x
}

/// Smooth colored-noise spectrogram, 1 x t x f.
fn noise_spectrogram(t: usize, f: usize, rng: &mut Rng) -> Vec<f32> {
    // 1/f-ish band energy envelope, slow temporal amplitude modulation
    let band: Vec<f32> = (0..f)
        .map(|j| 1.5 / (1.0 + j as f32 * 0.3) + 0.2 * rng.f32())
        .collect();
    let mut x = vec![0.0f32; t * f];
    let mut amp = 1.0f32;
    for i in 0..t {
        amp = 0.8 * amp + 0.2 * (1.0 + rng.normal() * 0.5);
        for j in 0..f {
            x[i * f + j] = band[j] * amp * 2.0 + rng.normal() * 0.3;
        }
    }
    x
}

/// Build an OOD dataset matching a target task's input shape. Labels are
/// dummy zeros: distillation never reads them.
pub fn generate(domain: &str, shape: (usize, usize, usize), n: usize, seed: u64) -> Dataset {
    let (c, h, w) = shape;
    let mut rng = Rng::new(seed ^ 0x00D_DA7A);
    let samples = (0..n)
        .map(|_| Sample {
            x: match domain {
                "vision" => oriented_noise(c, h, w, &mut rng),
                "audio" => noise_spectrogram(h, w, &mut rng),
                other => panic!("unknown domain '{other}'"),
            },
            y: 0,
        })
        .collect();
    Dataset {
        samples,
        shape,
        num_classes: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_finiteness() {
        let d = generate("vision", (3, 16, 16), 32, 1);
        assert_eq!(d.len(), 32);
        for s in &d.samples {
            assert_eq!(s.x.len(), 3 * 16 * 16);
            assert!(s.x.iter().all(|v| v.is_finite()));
        }
        let a = generate("audio", (1, 32, 16), 8, 1);
        assert_eq!(a.samples[0].x.len(), 32 * 16);
    }

    #[test]
    fn vision_ood_is_bounded_by_soft_clip() {
        let d = generate("vision", (3, 16, 16), 16, 3);
        for s in &d.samples {
            assert!(s.x.iter().all(|v| v.abs() <= 2.0 + 1e-6));
        }
    }

    #[test]
    fn deterministic() {
        let a = generate("audio", (1, 32, 16), 4, 9);
        let b = generate("audio", (1, 32, 16), 4, 9);
        assert_eq!(a.samples[3].x, b.samples[3].x);
    }

    #[test]
    fn ood_differs_from_seeded_duplicates() {
        let a = generate("vision", (3, 16, 16), 2, 1);
        assert_ne!(a.samples[0].x, a.samples[1].x);
    }
}
