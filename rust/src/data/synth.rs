//! Synthetic class-structured generators for the five paper datasets.
//!
//! Vision (CIFAR-10/100, PathMNIST analogues): each class owns a seeded
//! low-frequency prototype pattern (sum of random 2-D cosine modes);
//! PathMNIST's analogue uses higher-frequency "texture" modes to mimic
//! histopathology texture statistics. Samples = prototype warped by a
//! random phase shift + amplitude jitter + pixel noise.
//!
//! Audio (SpeechCommands / VoxForge analogues): spectrogram-like 1xT xF
//! maps. Keyword classes are time-frequency ridge trajectories (distinct
//! start bin / slope / curvature per class); language-ID classes are
//! spectral-envelope families (per-class band-energy profile) — the
//! second is deliberately "easier" (coarser structure), matching the
//! relative accuracies in the paper's Table 1.

use super::dataset::{Dataset, Sample};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Flavor {
    /// low-frequency object-like patterns (CIFAR analogue)
    VisionSmooth,
    /// high-frequency texture patterns (PathMNIST analogue)
    VisionTexture,
    /// time-frequency ridge trajectories (keyword-spotting analogue)
    AudioRidge,
    /// spectral-envelope families (language-ID analogue)
    AudioEnvelope,
}

/// Generator parameters for one synthetic task.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub flavor: Flavor,
    pub num_classes: usize,
    pub shape: (usize, usize, usize),
    /// per-pixel observation noise
    pub noise: f32,
    /// within-class variation strength (phase/amplitude jitter)
    pub jitter: f32,
}

impl SynthSpec {
    pub fn for_dataset(name: &str) -> SynthSpec {
        match name {
            "cifar10" => SynthSpec {
                flavor: Flavor::VisionSmooth,
                num_classes: 10,
                shape: (3, 16, 16),
                noise: 0.35,
                jitter: 0.5,
            },
            "cifar100" => SynthSpec {
                flavor: Flavor::VisionSmooth,
                num_classes: 100,
                shape: (3, 16, 16),
                noise: 0.35,
                jitter: 0.5,
            },
            "pathmnist" => SynthSpec {
                flavor: Flavor::VisionTexture,
                num_classes: 9,
                shape: (3, 16, 16),
                noise: 0.3,
                jitter: 0.45,
            },
            "speechcommands" => SynthSpec {
                flavor: Flavor::AudioRidge,
                num_classes: 12,
                shape: (1, 32, 16),
                noise: 0.25,
                jitter: 0.4,
            },
            "voxforge" => SynthSpec {
                flavor: Flavor::AudioEnvelope,
                num_classes: 6,
                shape: (1, 32, 16),
                noise: 0.25,
                jitter: 0.35,
            },
            other => panic!("unknown dataset '{other}'"),
        }
    }
}

/// Per-class frozen prototype parameters (seeded once per task).
struct ClassProto {
    /// cosine modes: (freq_y, freq_x, phase, amplitude) per channel
    modes: Vec<Vec<(f32, f32, f32, f32)>>,
    /// audio-ridge parameters: start bin, slope, curvature, width
    ridge: (f32, f32, f32, f32),
    /// audio-envelope band profile (length F)
    envelope: Vec<f32>,
}

fn build_proto(spec: &SynthSpec, class: usize, rng: &mut Rng) -> ClassProto {
    let (c, _h, w) = spec.shape;
    let n_modes = match spec.flavor {
        Flavor::VisionSmooth => 3,
        Flavor::VisionTexture => 6,
        _ => 0,
    };
    let freq_scale = match spec.flavor {
        Flavor::VisionSmooth => 1.5,
        Flavor::VisionTexture => 4.0,
        _ => 0.0,
    };
    let modes = (0..c)
        .map(|_| {
            (0..n_modes)
                .map(|_| {
                    (
                        0.5 + freq_scale * rng.f32(),
                        0.5 + freq_scale * rng.f32(),
                        rng.f32() * std::f32::consts::TAU,
                        0.6 + 0.8 * rng.f32(),
                    )
                })
                .collect()
        })
        .collect();
    // ridges spread across the frequency axis by class id for separability
    let f = w as f32;
    let ridge = (
        (class as f32 + 0.5) / spec.num_classes as f32 * (f - 2.0),
        (rng.f32() - 0.5) * 0.5,
        (rng.f32() - 0.5) * 0.02,
        1.0 + rng.f32(),
    );
    let envelope = (0..w)
        .map(|j| {
            let t = j as f32 / f;
            // per-class band profile: two bumps at class-dependent places
            let c1 = (class as f32 * 0.37).fract();
            let c2 = (class as f32 * 0.61 + 0.29).fract();
            (-(t - c1).powi(2) / 0.02).exp() + 0.7 * (-(t - c2).powi(2) / 0.04).exp()
        })
        .collect();
    ClassProto {
        modes,
        ridge,
        envelope,
    }
}

fn render(
    spec: &SynthSpec,
    proto: &ClassProto,
    rng: &mut Rng,
) -> Vec<f32> {
    let (c, h, w) = spec.shape;
    let mut x = vec![0.0f32; c * h * w];
    match spec.flavor {
        Flavor::VisionSmooth | Flavor::VisionTexture => {
            // phase-jittered sum of class cosine modes + noise
            for ch in 0..c {
                for (fy, fx, phase, amp) in &proto.modes[ch] {
                    let dp = (rng.f32() - 0.5) * spec.jitter * std::f32::consts::TAU;
                    let da = 1.0 + (rng.f32() - 0.5) * spec.jitter;
                    for i in 0..h {
                        for j in 0..w {
                            let v = amp
                                * da
                                * (fy * i as f32 / h as f32 * std::f32::consts::TAU
                                    + fx * j as f32 / w as f32 * std::f32::consts::TAU
                                    + phase
                                    + dp)
                                    .cos();
                            x[ch * h * w + i * w + j] += v;
                        }
                    }
                }
            }
        }
        Flavor::AudioRidge => {
            // one ridge sweeping through time; h = time, w = freq
            let (start, slope, curve, width) = proto.ridge;
            let ds = (rng.f32() - 0.5) * spec.jitter * 3.0;
            let dslope = (rng.f32() - 0.5) * spec.jitter * 0.3;
            for i in 0..h {
                let t = i as f32;
                let center = start + ds + (slope + dslope) * t + curve * t * t;
                for j in 0..w {
                    let d = j as f32 - center;
                    x[i * w + j] += (-(d * d) / (2.0 * width * width)).exp() * 2.0;
                }
            }
        }
        Flavor::AudioEnvelope => {
            // stationary band profile with per-frame amplitude modulation
            for i in 0..h {
                let amp = 1.0 + 0.5 * ((i as f32 * 0.3).sin() + (rng.f32() - 0.5) * spec.jitter);
                for j in 0..w {
                    x[i * w + j] += proto.envelope[j] * amp * 2.0;
                }
            }
        }
    }
    for v in &mut x {
        *v += rng.normal() * spec.noise;
    }
    x
}

/// Generate a dataset of `n` samples with near-uniform class balance.
/// `seed` controls everything: prototypes derive from (seed, task) so
/// train/test splits built with different sample seeds share classes.
pub fn generate(spec: &SynthSpec, n: usize, seed: u64, sample_stream: u64) -> Dataset {
    let base = Rng::new(seed);
    let mut proto_rng = base.fork(0xC1A55);
    let protos: Vec<ClassProto> = (0..spec.num_classes)
        .map(|k| build_proto(spec, k, &mut proto_rng))
        .collect();

    let mut rng = base.fork(0x5A3F1E ^ sample_stream);
    let samples = (0..n)
        .map(|i| {
            let y = i % spec.num_classes;
            Sample {
                x: render(spec, &protos[y], &mut rng),
                y: y as i32,
            }
        })
        .collect();
    Dataset {
        samples,
        shape: spec.shape,
        num_classes: spec.num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        for name in ["cifar10", "cifar100", "pathmnist", "speechcommands", "voxforge"] {
            let spec = SynthSpec::for_dataset(name);
            let d = generate(&spec, 64, 7, 0);
            assert_eq!(d.len(), 64);
            assert_eq!(d.num_classes, spec.num_classes);
            for s in &d.samples {
                assert_eq!(s.x.len(), d.feature_len());
                assert!((s.y as usize) < spec.num_classes);
                assert!(s.x.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn class_balance_is_near_uniform() {
        let spec = SynthSpec::for_dataset("cifar10");
        let d = generate(&spec, 1000, 3, 0);
        let h = d.label_histogram();
        for &c in &h {
            assert!((95..=105).contains(&c), "{h:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SynthSpec::for_dataset("speechcommands");
        let a = generate(&spec, 16, 5, 1);
        let b = generate(&spec, 16, 5, 1);
        assert_eq!(a.samples[7].x, b.samples[7].x);
        let c = generate(&spec, 16, 6, 1);
        assert_ne!(a.samples[7].x, c.samples[7].x);
    }

    #[test]
    fn train_test_share_prototypes_but_not_samples() {
        let spec = SynthSpec::for_dataset("cifar10");
        let train = generate(&spec, 32, 5, 0);
        let test = generate(&spec, 32, 5, 1);
        assert_ne!(train.samples[0].x, test.samples[0].x);
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // nearest-class-mean classification on clean-ish data must beat
        // chance by a wide margin, otherwise the task is unlearnable
        let spec = SynthSpec::for_dataset("cifar10");
        let train = generate(&spec, 500, 9, 0);
        let test = generate(&spec, 200, 9, 1);
        let dim = train.feature_len();
        let mut means = vec![vec![0.0f64; dim]; spec.num_classes];
        let mut counts = vec![0usize; spec.num_classes];
        for s in &train.samples {
            counts[s.y as usize] += 1;
            for (m, &v) in means[s.y as usize].iter_mut().zip(&s.x) {
                *m += v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        let mut correct = 0;
        for s in &test.samples {
            let mut best = (f64::MAX, 0usize);
            for (k, m) in means.iter().enumerate() {
                let d: f64 = m
                    .iter()
                    .zip(&s.x)
                    .map(|(a, &b)| (a - b as f64).powi(2))
                    .sum();
                if d < best.0 {
                    best = (d, k);
                }
            }
            if best.1 == s.y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy too low: {acc}");
    }
}
