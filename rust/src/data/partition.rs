//! Non-IID federated partitioner.
//!
//! The paper controls heterogeneity with a "data distribution variance"
//! sigma (25% in Table 1). We realize that knob as label-distribution
//! skew: each client's class mixture is Dirichlet(alpha)-distributed,
//! with alpha mapped from sigma so that sigma=0 -> IID (alpha -> inf)
//! and sigma=1 -> near one-class clients (alpha -> 0). Samples are
//! assigned without overlap, matching "randomly partitioned ... in a
//! non-overlapping fashion".

use super::dataset::Dataset;
use crate::util::rng::Rng;

/// Map the paper's sigma in [0,1) to a Dirichlet concentration.
/// sigma=0.25 -> alpha=3.0: moderate skew (each client sees most
/// classes but with uneven mass), the regime Table 1 reports.
pub fn sigma_to_alpha(sigma: f64) -> f64 {
    assert!((0.0..1.0).contains(&sigma));
    (1.0 - sigma) / sigma.max(1e-3)
}

/// Partition `data` into `k` non-overlapping client shards with
/// Dirichlet(alpha) label skew. Every sample lands on exactly one
/// client; every client receives at least `min_per_client` samples
/// (top-up from a round-robin of leftovers keeps shards trainable).
pub fn partition_dirichlet(
    data: &Dataset,
    k: usize,
    alpha: f64,
    min_per_client: usize,
    rng: &mut Rng,
) -> Vec<Dataset> {
    assert!(k > 0);
    let n_classes = data.num_classes;

    // per-class index pools, shuffled
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, s) in data.samples.iter().enumerate() {
        pools[s.y as usize].push(i);
    }
    for p in &mut pools {
        rng.shuffle(p);
    }

    // each client draws a class mixture, then claims samples class by class
    let mixtures: Vec<Vec<f64>> = (0..k).map(|_| rng.dirichlet(alpha, n_classes)).collect();

    let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (c, pool) in pools.iter().enumerate() {
        // split this class's samples proportionally to clients' mixture weight
        let weights: Vec<f64> = mixtures.iter().map(|m| m[c]).collect();
        let total: f64 = weights.iter().sum::<f64>().max(1e-12);
        let mut cursor = 0usize;
        for (ci, wgt) in weights.iter().enumerate() {
            let share = ((wgt / total) * pool.len() as f64).floor() as usize;
            let end = (cursor + share).min(pool.len());
            assignment[ci].extend_from_slice(&pool[cursor..end]);
            cursor = end;
        }
        // leftovers round-robin
        let mut ci = 0;
        while cursor < pool.len() {
            assignment[ci % k].push(pool[cursor]);
            cursor += 1;
            ci += 1;
        }
    }

    // enforce the floor by stealing from the largest shards
    loop {
        let (small_i, small_n) = assignment
            .iter()
            .enumerate()
            .map(|(i, a)| (i, a.len()))
            .min_by_key(|&(_, n)| n)
            .unwrap();
        if small_n >= min_per_client {
            break;
        }
        let (big_i, _) = assignment
            .iter()
            .enumerate()
            .map(|(i, a)| (i, a.len()))
            .max_by_key(|&(_, n)| n)
            .unwrap();
        if assignment[big_i].len() <= min_per_client {
            break; // not enough data to satisfy the floor everywhere
        }
        let moved = assignment[big_i].pop().unwrap();
        assignment[small_i].push(moved);
    }

    assignment
        .into_iter()
        .map(|idx| Dataset {
            samples: idx.iter().map(|&i| data.samples[i].clone()).collect(),
            shape: data.shape,
            num_classes: n_classes,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn base() -> Dataset {
        generate(&SynthSpec::for_dataset("cifar10"), 1000, 1, 0)
    }

    #[test]
    fn non_overlapping_and_complete() {
        let d = base();
        let mut rng = Rng::new(2);
        let shards = partition_dirichlet(&d, 10, 3.0, 10, &mut rng);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, d.len());
        // feature vectors are unique per sample index in synth data, so
        // count distinct first-pixels as a proxy for no duplication
        let mut seen = std::collections::HashSet::new();
        for sh in &shards {
            for s in &sh.samples {
                let key = s.x.iter().map(|v| v.to_bits() as u64).fold(0u64, |a, b| {
                    a.wrapping_mul(31).wrapping_add(b)
                });
                assert!(seen.insert(key), "duplicate sample across shards");
            }
        }
    }

    #[test]
    fn min_floor_is_respected() {
        let d = base();
        let mut rng = Rng::new(3);
        let shards = partition_dirichlet(&d, 20, 0.3, 16, &mut rng);
        for s in &shards {
            assert!(s.len() >= 16, "shard too small: {}", s.len());
        }
    }

    #[test]
    fn low_alpha_skews_high_alpha_uniform() {
        let d = base();
        let mut rng = Rng::new(4);
        let skewed = partition_dirichlet(&d, 8, 0.1, 5, &mut rng);
        let uniform = partition_dirichlet(&d, 8, 1000.0, 5, &mut rng);

        // max class share per client, averaged
        let dominance = |shards: &[Dataset]| -> f64 {
            shards
                .iter()
                .map(|s| {
                    let h = s.label_histogram();
                    let m = *h.iter().max().unwrap() as f64;
                    m / s.len().max(1) as f64
                })
                .sum::<f64>()
                / shards.len() as f64
        };
        assert!(
            dominance(&skewed) > dominance(&uniform) + 0.1,
            "skewed {} vs uniform {}",
            dominance(&skewed),
            dominance(&uniform)
        );
    }

    #[test]
    fn sigma_mapping_monotone() {
        assert!(sigma_to_alpha(0.1) > sigma_to_alpha(0.25));
        assert!(sigma_to_alpha(0.25) > sigma_to_alpha(0.5));
        let a = sigma_to_alpha(0.25);
        assert!((2.9..3.1).contains(&a), "{a}");
    }
}
