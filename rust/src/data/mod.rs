//! Data substrate: synthetic dataset generators standing in for the
//! paper's five public datasets, OOD generators for server-side
//! distillation, and the non-IID federated partitioner.
//!
//! Substitution rationale (DESIGN.md §3): the compression pipeline needs
//! *learnable, heterogeneous, class-structured* client data, not the
//! actual CIFAR pixels; the generators below preserve class counts,
//! modality split and relative difficulty ordering.

pub mod dataset;
pub mod ood;
pub mod partition;
pub mod synth;

pub use dataset::{Dataset, Sample};
pub use partition::partition_dirichlet;
