//! FedCompress — communication-efficient federated learning via
//! adaptive weight clustering + server-side distillation.
//!
//! Reproduction of Tsouvalas et al., 2024 (see DESIGN.md for the full
//! system inventory). Three-layer architecture:
//!
//! * **Layer 3 (this crate)** — the federated coordinator: round loop,
//!   aggregation, compression codecs, dynamic cluster control, metrics.
//! * **Layer 2** — JAX model graphs (`python/compile/model.py`),
//!   AOT-lowered once to HLO text under `artifacts/`.
//! * **Layer 1** — Pallas kernels for the weight-clustering hot spot
//!   (`python/compile/kernels/`), lowered inside the L2 graphs.
//!
//! The rust binary loads the HLO artifacts through the PJRT C API
//! (`runtime`) and never touches python at runtime.

pub mod baselines;
pub mod bench;
pub mod check;
pub mod cli;
pub mod client;
pub mod clustering;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod edge;
pub mod exp;
pub mod linalg;
pub mod models;
pub mod runtime;
pub mod util;
