//! FedCompress — communication-efficient federated learning via
//! adaptive weight clustering + server-side distillation.
//!
//! Reproduction of Tsouvalas et al., 2024 (see DESIGN.md for the full
//! system inventory). Three-layer architecture:
//!
//! * **Layer 3 (this crate)** — the federated coordinator: a
//!   strategy-agnostic round loop, strategy plugins, compression
//!   codecs, dynamic cluster control, metrics.
//! * **Layer 2** — JAX model graphs (`python/compile/model.py`),
//!   AOT-lowered once to HLO text under `artifacts/`.
//! * **Layer 1** — Pallas kernels for the weight-clustering hot spot
//!   (`python/compile/kernels/`), lowered inside the L2 graphs.
//!
//! The rust binary loads the HLO artifacts through the PJRT C API
//! (`runtime`) and never touches python at runtime.
//!
//! # Strategy plugin architecture
//!
//! Training strategies (FedAvg, FedZip, FedCompress, top-k, ...) are
//! *plugins*: implementations of [`coordinator::strategy::FedStrategy`],
//! a trait of round-lifecycle hooks — `round_start`, `encode_download`,
//! `client_train_opts`, `encode_upload`, `aggregate`, `post_aggregate`,
//! `finalize`. The driver ([`coordinator::server::run_with_strategy`])
//! owns the loop — selection, dispatch, client training, ledger,
//! events, evaluation — and contains no per-strategy branches; the
//! CLI/config layer resolves strategy *names* through
//! [`baselines::registry::StrategyRegistry`]. Adding a baseline is one
//! trait impl plus one registry entry (see ARCHITECTURE.md for a
//! <20-line walkthrough).
//!
//! Per-client upload encoding (k-means + Huffman, the dominant pure-
//! rust cost) fans out over [`util::threadpool::parallel_map`]; each
//! client owns a deterministic RNG fork, so results are bit-identical
//! regardless of worker count. The engine-bound training phase stays on
//! the coordinator thread — the PJRT client is thread-confined,
//! faithful to a single shared accelerator.
//!
//! # Codec API
//!
//! Compression is a first-class subsystem shaped like the strategy
//! API: [`codec::Stage`]s (the `compression/` substrate as registry
//! parts — `dense`, `topk`, `kmeans`, `codebook`, `huffman`, `delta`)
//! compose into [`codec::Pipeline`]s parsed from spec strings
//! (`topk(keep=0.6)|kmeans(c=15,iters=25)|huffman` — FedZip,
//! literally), resolved by name through [`codec::CodecRegistry`] with
//! aliases and typo suggestions. A pipeline's canonical spec is also
//! its self-describing wire header: `net::proto` ships it ahead of
//! every payload and the receiver decodes through a
//! [`codec::CodecCache`], so *any* codec registered on both ends —
//! including downstream user codecs — crosses the TCP transport
//! end-to-end (the old `Opaque` in-process-only carve-out is gone).
//! Per-stage wire bytes are ledgered individually
//! (`CommLedger::stage_totals`).
//!
//! CLI surface:
//!
//! * `--codec <spec>` — override every strategy's compressed-upload
//!   pipeline for a run (`--set codec=`): it applies exactly where the
//!   strategy's declared upload pipeline did, so warmup-dense
//!   strategies stay dense during warmup and always-compressed ones
//!   (fedzip, topk) apply it from round 0;
//! * `--codec list` — print the codec registry (`train`);
//! * `sweep --axis codec=a,b` — sweep pipelines x strategies x fleets
//!   through the run store; the spec participates in the bit-exact
//!   config image and therefore in record content keys.
//!
//! # Fleet simulation
//!
//! Real FL fleets are dominated by client heterogeneity — stragglers,
//! dropouts, thin uplinks — which the paper's lock-step evaluation
//! ignores. The [`sim`] layer models it: [`sim::FleetProfile`] draws
//! per-client device tiers (from [`edge::DeviceProfile`]), link
//! bandwidths and availability for a named preset (`ideal`, `mobile`,
//! `hostile`); [`sim::FaultSchedule`] assigns seed-deterministic
//! per-round fates (dropout before train/upload, straggler slowdowns);
//! and [`sim::RoundClock`] converts the ledgered bytes plus train FLOPs
//! into simulated round wall-clock under an optional reporting
//! deadline. The coordinator aggregates survivors only, emits
//! `Event::Dropout` / `Event::Deadline`, and records `round_sim_ms`,
//! `stragglers` and `dropped` in [`coordinator::RoundMetrics`]. The
//! default [`sim::FleetConfig`] is the ideal fleet, under which every
//! run is byte-identical to the pre-sim coordinator.
//!
//! CLI surface:
//!
//! * `--fleet <ideal|mobile|hostile>` — named fleet preset
//!   (equivalently `--set fleet=<name>`);
//! * `--dropout <p>` — extra i.i.d. per-round client dropout
//!   probability in `[0, 1)` (`--set dropout=<p>`);
//! * `--deadline-s <s>` — simulated round reporting deadline in
//!   seconds; clients that cannot report in time are cut
//!   (`--set deadline_s=<s>`; 0 disables);
//! * `--edge-of <N>` — emulate the edge aggregation tier in-process:
//!   every `N` consecutive participants pre-fold behind one aggregator
//!   through the same `resolve_edge` path a `worker --edge-of N` uses
//!   (`--set edge_of=<N>`, sweep axis `edge_of`; 0 disables);
//! * `fedcompress fleet [--fleet <name>] [--dropout p] [--deadline-s s]`
//!   — the scenario table: every registered strategy under the fleet
//!   presets, comparing rounds-to-accuracy and simulated
//!   time-to-accuracy (`exp::fleet`).
//!
//! # Networked transport
//!
//! The round loop drives a [`net::Transport`]: the default
//! [`net::InProcess`] backend trains and encodes in this process
//! (byte-identical to the historical coordinator), while
//! [`net::TcpTransport`] speaks a framed binary protocol
//! (magic + version + type + length + CRC32; see [`net::frame`]) to
//! worker processes. Workers rebuild the whole experiment — data
//! shards, RNG streams, strategy plugin — from the config image in the
//! `HelloAck` handshake, so only (encoded) models cross the wire and a
//! loopback run reproduces the in-process run bit-exactly. The ledger
//! records `framed_bytes` (payload + protocol overhead, ≤ 64 bytes per
//! message) alongside the ideal `bytes`; round control and centroid
//! sidecars are tracked as `TcpTransport::control_bytes`.
//!
//! CLI surface:
//!
//! * `fedcompress serve --bind ADDR --workers N [--timeout-s s]
//!   [train options...]` — run the coordinator: wait for `N` workers,
//!   then train over TCP. `--timeout-s` bounds each per-client upload
//!   wait; late workers surface as `Event::Deadline`, dead ones as
//!   `Event::Dropout` — the same fault machinery the simulator feeds.
//! * `fedcompress worker --connect ADDR [--artifacts dir]` — run one
//!   worker process. Everything else (strategy, config, client ids)
//!   arrives at handshake.
//! * `fedcompress train --resume ckpt [...]` / `serve --resume ckpt` —
//!   continue a checkpointed run; the checkpoint records the transport
//!   kind + fleet preset it was produced under and the run emits
//!   `Event::ResumeMismatch` when they differ.
//!
//! # Run store + sweep orchestrator
//!
//! Runs persist: the [`store`] layer records every completed run as a
//! content-addressed [`store::RunRecord`] — per-round metrics, the
//! event JSONL, the comm ledger, final scores — in an append-only
//! record file keyed by `FNV-1a64(strategy ‖ config_image)`, where the
//! config image is the bit-exact serialization the TCP handshake
//! already ships (`net::proto::config_image`). Corrupt or truncated
//! stores surface typed [`store::StoreError`]s, never panics. The
//! [`sweep`] layer expands a declarative grid (strategies x fleet
//! presets x seeds x any `--set`able knob) into jobs, executes them on
//! the thread pool with engine-per-worker isolation, and skips every
//! job whose key already has a record (resume-by-cache).
//!
//! CLI surface:
//!
//! * `fedcompress sweep [--strategies a,b] [--fleets x,y] [--seeds
//!   1,2] [--axis key=v1,v2]... [--spec file] [--store dir] [--jobs n]
//!   [--smoke] [--force]` — expand and run a grid; `--smoke` uses a
//!   deterministic synthetic runner (no artifacts) that still
//!   exercises hashing, parallel execution, persistence, and cache.
//! * `fedcompress runs list|show|diff|compare|export-bench` — query
//!   the store: `show --key <hex>` prints one record (unique key
//!   prefixes accepted), `diff --a <hex> --b <hex>` asserts bit-exact
//!   equality (exit code reports drift; `--other <dir>` diffs every
//!   shared key of two stores), `export-bench` writes the
//!   `BENCH_sweep.json` perf summary. `--csv`/`--out` route any table
//!   through the shared `util::csv` writer.
//! * `fedcompress table1 --store runs` / `fleet --store runs` —
//!   experiment drivers read prior runs from the store by content key
//!   instead of re-executing; `table2 --from-run <hex>` deploys the
//!   cluster count a stored run actually landed on.
//!
//! # Observability
//!
//! Every run path can tee a **versioned JSONL event stream** (header
//! line `EVNT1 {...}` with schema version, run key, and config
//! fingerprint) to `<store>/events/<run_key>.jsonl` through the
//! non-blocking [`obs::EventSink`] trait (bounded channel + drop
//! counter — a slow disk costs events, never round latency). The
//! stream carries the canonical run events *plus* ops-only detail
//! (per-slot arrival order, reorder-window depth, worker evictions,
//! per-phase round timings) that never enters the bit-exact run
//! record. `runs tail <key> [--follow]` and `sweep --watch` render
//! live terminal tables from the stream via a tolerant parser
//! (per-line errors are counted, a damaged stream still replays), and
//! the same renderer reconstructs the identical view offline from a
//! stored [`store::RunRecord`] — minus the live-only timing columns,
//! which only a teed stream carries.
//!
//! # Perf trajectory (bench)
//!
//! Performance is a committed artifact, not a side effect: `bench run
//! [--area codec|net|store|aggregate|runtime|all] [--quick]` drives
//! the same suite functions the `cargo bench` targets wrap
//! ([`bench::suite`]) headlessly and writes one versioned
//! `BENCH_<area>.json` per area ([`bench::schema::BenchDoc`], format
//! 2: median/p10/p90 ns per row plus derived MiB/s wherever a byte
//! count exists). `bench diff <old> <new> [--threshold-pct N]`
//! compares two documents row by row and exits 3 on any regression
//! past the threshold — CI runs quick suites against the committed
//! baselines at the repo root and flags drifts; an intentional speedup
//! is ratified by refreshing the baseline JSON in the same PR.
//! In-run profiling feeds the same trajectory: the round loop times
//! each phase (select, encode_down, train, encode_up, ingest,
//! aggregate, evaluate) through the sanctioned [`util::timer`]
//! monotonic API — the *only* wall-clock read site fedlint's
//! `no-wallclock-state` rule tolerates — and emits live-only
//! `phase_timing` ops events that `runs tail` renders as a timing
//! column group and `bench run --area rounds` rolls into
//! `BENCH_rounds.json`. Canonical records stay byte-identical: every
//! timing is observability, never state.
//!
//! # SIMD kernels
//!
//! The codec hot paths (magnitude pruning, k-means assignment, Huffman
//! frequency counting, fixed-width bit packing, the aggregation fold)
//! run through the [`kernels`] narrow waist: one scalar reference
//! backend that is the semantic source of truth, plus runtime-detected
//! AVX2 (x86-64) and NEON (aarch64) backends that are **bit-identical**
//! to it — SIMD is restricted to order-independent lanes and float
//! reductions reproduce the scalar association order, so wire bytes,
//! run keys, and aggregates never depend on the machine. The backend is
//! selected once at startup (`kernels::active()`); set
//! `FEDCOMPRESS_KERNELS=scalar|avx2|neon` to override detection (an
//! unavailable choice warns and falls back). `bench run --area kernels`
//! prints per-kernel MiB/s, scalar vs detected-SIMD side by side, and
//! `tests/kernels_equiv.rs` holds the cross-backend equivalence suite.
//!
//! # Invariants as lint rules (fedlint)
//!
//! Everything above rests on invariants the compiler cannot check:
//! map iteration order must never cross the wire or land in records,
//! decode paths must never panic on adversarial bytes, wall clocks and
//! ad-hoc RNG seeds must never leak into bit-exact state, and float
//! narrowing in codec hot paths must be deliberate. The [`lint`]
//! module enforces them statically — a std-only, self-hosted pass over
//! the crate's own sources (lightweight lexer, heuristic rules, scopes
//! from `fedlint.toml`, suppression only via reasoned
//! `// fedlint:allow(rule) -- why` comments). CI runs it as a hard
//! gate next to the test suites.
//!
//! CLI surface:
//!
//! * `fedcompress lint [--json] [--rule <name>] [--out report.json]
//!   [paths...]` — lint the crate (or just `paths`); nonzero exit on
//!   any deny-severity violation. See ARCHITECTURE.md
//!   "Invariants & lint" for the rule table and the allow contract.

pub mod baselines;
pub mod bench;
pub mod check;
pub mod cli;
pub mod client;
pub mod clustering;
pub mod codec;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod edge;
pub mod exp;
pub mod kernels;
pub mod linalg;
pub mod lint;
pub mod models;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod sweep;
pub mod util;
