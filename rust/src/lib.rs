//! FedCompress — communication-efficient federated learning via
//! adaptive weight clustering + server-side distillation.
//!
//! Reproduction of Tsouvalas et al., 2024 (see DESIGN.md for the full
//! system inventory). Three-layer architecture:
//!
//! * **Layer 3 (this crate)** — the federated coordinator: a
//!   strategy-agnostic round loop, strategy plugins, compression
//!   codecs, dynamic cluster control, metrics.
//! * **Layer 2** — JAX model graphs (`python/compile/model.py`),
//!   AOT-lowered once to HLO text under `artifacts/`.
//! * **Layer 1** — Pallas kernels for the weight-clustering hot spot
//!   (`python/compile/kernels/`), lowered inside the L2 graphs.
//!
//! The rust binary loads the HLO artifacts through the PJRT C API
//! (`runtime`) and never touches python at runtime.
//!
//! # Strategy plugin architecture
//!
//! Training strategies (FedAvg, FedZip, FedCompress, top-k, ...) are
//! *plugins*: implementations of [`coordinator::strategy::FedStrategy`],
//! a trait of round-lifecycle hooks — `round_start`, `encode_download`,
//! `client_train_opts`, `encode_upload`, `aggregate`, `post_aggregate`,
//! `finalize`. The driver ([`coordinator::server::run_with_strategy`])
//! owns the loop — selection, dispatch, client training, ledger,
//! events, evaluation — and contains no per-strategy branches; the
//! CLI/config layer resolves strategy *names* through
//! [`baselines::registry::StrategyRegistry`]. Adding a baseline is one
//! trait impl plus one registry entry (see ARCHITECTURE.md for a
//! <20-line walkthrough).
//!
//! Per-client upload encoding (k-means + Huffman, the dominant pure-
//! rust cost) fans out over [`util::threadpool::parallel_map`]; each
//! client owns a deterministic RNG fork, so results are bit-identical
//! regardless of worker count. The engine-bound training phase stays on
//! the coordinator thread — the PJRT client is thread-confined,
//! faithful to a single shared accelerator.

pub mod baselines;
pub mod bench;
pub mod check;
pub mod cli;
pub mod client;
pub mod clustering;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod edge;
pub mod exp;
pub mod linalg;
pub mod models;
pub mod runtime;
pub mod util;
