//! Micro-benchmark harness (criterion is not in the vendored crate
//! set). `cargo bench` targets use `harness = false` and drive this.
//!
//! Methodology: warmup runs, then adaptive iteration count targeting a
//! minimum measurement window, then median / p10 / p90 over samples.
//! Results print in a stable machine-greppable format:
//!     BENCH <name> median_ns=<n> p10_ns=<n> p90_ns=<n> iters=<n>

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters_per_sample: usize,
}

/// Measure `f`, returning per-iteration stats. `f` is called in batches;
/// use `std::hint::black_box` inside to defeat dead-code elimination.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup + calibrate iteration count for a ~20ms sample window
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((0.02 / one).ceil() as usize).clamp(1, 100_000);

    let samples = 15usize;
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        median_ns: per_iter[samples / 2],
        p10_ns: per_iter[samples / 10],
        p90_ns: per_iter[samples * 9 / 10],
        iters_per_sample: iters,
    };
    println!(
        "BENCH {} median_ns={:.0} p10_ns={:.0} p90_ns={:.0} iters={}",
        result.name, result.median_ns, result.p10_ns, result.p90_ns, result.iters_per_sample
    );
    result
}

/// Pretty throughput helper: bytes processed per iteration -> GB/s line.
pub fn report_throughput(r: &BenchResult, bytes_per_iter: usize) {
    let gbps = bytes_per_iter as f64 / r.median_ns;
    println!("  -> {:.3} GB/s ({} B/iter)", gbps, bytes_per_iter);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop_loop", || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(s);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }
}
