//! Micro-benchmark harness (criterion is not in the vendored crate
//! set). `cargo bench` targets use `harness = false` and drive this,
//! as does the headless `bench run` CLI verb via [`suite`].
//!
//! Methodology: warmup runs, then adaptive iteration count targeting a
//! minimum measurement window, then median / p10 / p90 over samples.
//! Results print in a stable machine-greppable format:
//!     BENCH <name> median_ns=<n> p10_ns=<n> p90_ns=<n> iters=<n>

pub mod diff;
pub mod schema;
pub mod suite;

use crate::util::timer::Stopwatch;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters_per_sample: usize,
}

/// Sampling knobs: the default profile targets ~20ms windows over 15
/// samples; `quick()` trades precision for wall time so a CI job can
/// sweep every suite in seconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub window_s: f64,
    pub samples: usize,
}

impl BenchOpts {
    pub fn full() -> BenchOpts {
        BenchOpts {
            window_s: 0.02,
            samples: 15,
        }
    }

    pub fn quick() -> BenchOpts {
        BenchOpts {
            window_s: 0.005,
            samples: 7,
        }
    }
}

/// Measure `f`, returning per-iteration stats. `f` is called in batches;
/// use `std::hint::black_box` inside to defeat dead-code elimination.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_opts(name, BenchOpts::full(), f)
}

/// [`bench`] with explicit sampling knobs (the quick CI profile).
pub fn bench_opts<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    // warmup + calibrate iteration count for the target sample window
    let sw = Stopwatch::start();
    f();
    let one = sw.elapsed_s().max(1e-9);
    let iters = ((opts.window_s / one).ceil() as usize).clamp(1, 100_000);

    let samples = opts.samples.max(3);
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Stopwatch::start();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed_s() * 1e9 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.total_cmp(b));
    let result = BenchResult {
        name: name.to_string(),
        median_ns: per_iter[samples / 2],
        p10_ns: per_iter[samples / 10],
        p90_ns: per_iter[samples * 9 / 10],
        iters_per_sample: iters,
    };
    println!(
        "BENCH {} median_ns={:.0} p10_ns={:.0} p90_ns={:.0} iters={}",
        result.name, result.median_ns, result.p10_ns, result.p90_ns, result.iters_per_sample
    );
    result
}

/// Bytes-per-nanosecond → MiB/s (the unit ROADMAP tracks).
pub fn mib_per_s(bytes_per_iter: usize, median_ns: f64) -> f64 {
    if !median_ns.is_finite() || median_ns <= 0.0 {
        return 0.0;
    }
    bytes_per_iter as f64 / (median_ns * 1e-9) / (1024.0 * 1024.0)
}

/// Pretty throughput helper: bytes processed per iteration ->
/// MiB/s + GB/s line.
pub fn report_throughput(r: &BenchResult, bytes_per_iter: usize) {
    let gbps = bytes_per_iter as f64 / r.median_ns;
    println!(
        "  -> {:.1} MiB/s ({:.3} GB/s, {} B/iter)",
        mib_per_s(bytes_per_iter, r.median_ns),
        gbps,
        bytes_per_iter
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop_loop", || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(s);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }

    #[test]
    fn quick_opts_use_fewer_samples() {
        let q = BenchOpts::quick();
        let f = BenchOpts::full();
        assert!(q.samples < f.samples && q.window_s < f.window_s);
    }

    #[test]
    fn mib_per_s_handles_degenerate_medians() {
        assert_eq!(mib_per_s(1024, 0.0), 0.0);
        assert_eq!(mib_per_s(1024, f64::NAN), 0.0);
        assert_eq!(mib_per_s(1024, -5.0), 0.0);
        // 1 MiB per millisecond = 1000 MiB/s
        let v = mib_per_s(1024 * 1024, 1e6);
        assert!((v - 1000.0).abs() < 1e-9);
    }
}
