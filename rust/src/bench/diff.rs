//! `bench diff` — the name-wise regression gate over two
//! [`BenchDoc`]s. Rows pair by `suite/name`; a pair regresses when
//! the new median exceeds the old by strictly more than the threshold
//! percentage. Missing / added rows and incomparable medians (NaN or
//! non-positive) are reported but never fail the gate — only a
//! measured slowdown does. Schema errors are the caller's problem and
//! must fail hard (a baseline that stops parsing is not a pass).

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::schema::{BenchDoc, BenchRow};

/// Default `--threshold-pct`: generous on purpose, since shared CI
/// runners are noisy. Tighten per-invocation for local A/B runs.
pub const DEFAULT_THRESHOLD_PCT: f64 = 25.0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowStatus {
    /// Within threshold (includes improvements smaller than noise).
    Ok,
    /// New median faster than old by more than the threshold.
    Improved,
    /// New median slower than old by strictly more than the threshold.
    Regressed,
    /// A median on either side is NaN or non-positive — no ratio.
    Incomparable,
}

impl RowStatus {
    pub fn label(&self) -> &'static str {
        match self {
            RowStatus::Ok => "ok",
            RowStatus::Improved => "improved",
            RowStatus::Regressed => "REGRESSED",
            RowStatus::Incomparable => "incomparable",
        }
    }
}

#[derive(Clone, Debug)]
pub struct DiffRow {
    pub id: String,
    pub old_ns: f64,
    pub new_ns: f64,
    /// Percent change of the new median over the old; `None` when
    /// incomparable.
    pub delta_pct: Option<f64>,
    pub status: RowStatus,
}

#[derive(Clone, Debug)]
pub struct BenchDiff {
    pub threshold_pct: f64,
    pub rows: Vec<DiffRow>,
    /// Row ids present in the baseline but absent from the fresh run.
    pub missing: Vec<String>,
    /// Row ids new in the fresh run (no baseline to compare against).
    pub added: Vec<String>,
}

impl BenchDiff {
    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.status == RowStatus::Regressed).count()
    }

    pub fn incomparable(&self) -> usize {
        self.rows.iter().filter(|r| r.status == RowStatus::Incomparable).count()
    }

    /// Human-readable report, one line per compared row plus
    /// missing/added sections and a verdict line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.rows {
            let delta = match r.delta_pct {
                Some(d) => format!("{d:+.1}%"),
                None => "n/a".to_string(),
            };
            out.push_str(&format!(
                "{:<13} {:<48} {:>12.0} -> {:>12.0} ns  {}\n",
                r.status.label(),
                r.id,
                r.old_ns,
                r.new_ns,
                delta
            ));
        }
        for id in &self.missing {
            out.push_str(&format!("missing       {id} (in baseline, not in fresh run)\n"));
        }
        for id in &self.added {
            out.push_str(&format!("added         {id} (no baseline row)\n"));
        }
        let verdict = if self.regressions() > 0 { "FAIL" } else { "ok" };
        out.push_str(&format!(
            "bench diff: {} compared, {} regressed (threshold {:.0}%), {} incomparable, \
             {} missing, {} added -> {}\n",
            self.rows.len(),
            self.regressions(),
            self.threshold_pct,
            self.incomparable(),
            self.missing.len(),
            self.added.len(),
            verdict
        ));
        out
    }

    /// Machine-readable report (`bench diff --json`).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::str(&r.id)),
                    ("old_ns", Json::num(r.old_ns)),
                    ("new_ns", Json::num(r.new_ns)),
                    (
                        "delta_pct",
                        r.delta_pct.map(Json::num).unwrap_or(Json::Null),
                    ),
                    ("status", Json::str(r.status.label())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("bench", Json::str("diff")),
            ("threshold_pct", Json::num(self.threshold_pct)),
            ("compared", Json::from(self.rows.len())),
            ("regressed", Json::from(self.regressions())),
            ("incomparable", Json::from(self.incomparable())),
            (
                "missing",
                Json::Arr(self.missing.iter().map(|s| Json::str(s)).collect()),
            ),
            (
                "added",
                Json::Arr(self.added.iter().map(|s| Json::str(s)).collect()),
            ),
            ("rows", Json::Arr(rows)),
        ])
    }
}

fn comparable(ns: f64) -> bool {
    ns.is_finite() && ns > 0.0
}

/// Compare `new` against the `old` baseline.
pub fn diff_docs(old: &BenchDoc, new: &BenchDoc, threshold_pct: f64) -> BenchDiff {
    let index = |doc: &BenchDoc| -> BTreeMap<String, BenchRow> {
        doc.rows.iter().map(|r| (r.id(), r.clone())).collect()
    };
    let old_rows = index(old);
    let new_rows = index(new);

    let mut rows = Vec::new();
    let mut missing = Vec::new();
    for (id, o) in &old_rows {
        let Some(n) = new_rows.get(id) else {
            missing.push(id.clone());
            continue;
        };
        let (delta_pct, status) = if comparable(o.median_ns) && comparable(n.median_ns) {
            let d = (n.median_ns - o.median_ns) / o.median_ns * 100.0;
            let s = if d > threshold_pct {
                RowStatus::Regressed
            } else if d < -threshold_pct {
                RowStatus::Improved
            } else {
                RowStatus::Ok
            };
            (Some(d), s)
        } else {
            (None, RowStatus::Incomparable)
        };
        rows.push(DiffRow {
            id: id.clone(),
            old_ns: o.median_ns,
            new_ns: n.median_ns,
            delta_pct,
            status,
        });
    }
    let added = new_rows
        .keys()
        .filter(|id| !old_rows.contains_key(*id))
        .cloned()
        .collect();
    BenchDiff {
        threshold_pct,
        rows,
        missing,
        added,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc_with(rows: Vec<(&str, &str, f64)>) -> BenchDoc {
        let mut doc = BenchDoc::new("codec", true);
        for (suite, name, median_ns) in rows {
            doc.rows.push(BenchRow {
                suite: suite.to_string(),
                name: name.to_string(),
                median_ns,
                p10_ns: median_ns,
                p90_ns: median_ns,
                iters: 1,
                bytes: None,
            });
        }
        doc
    }

    #[test]
    fn exact_threshold_is_not_a_regression() {
        let old = doc_with(vec![("s", "a", 100.0)]);
        let new = doc_with(vec![("s", "a", 125.0)]);
        let d = diff_docs(&old, &new, 25.0);
        assert_eq!(d.rows[0].status, RowStatus::Ok);
        // one tick past the boundary trips it
        let worse = doc_with(vec![("s", "a", 125.1)]);
        let d = diff_docs(&old, &worse, 25.0);
        assert_eq!(d.rows[0].status, RowStatus::Regressed);
        assert_eq!(d.regressions(), 1);
    }

    #[test]
    fn improvements_and_noise_pass() {
        let old = doc_with(vec![("s", "a", 100.0), ("s", "b", 100.0)]);
        let new = doc_with(vec![("s", "a", 40.0), ("s", "b", 110.0)]);
        let d = diff_docs(&old, &new, 25.0);
        assert_eq!(d.rows[0].status, RowStatus::Improved);
        assert_eq!(d.rows[1].status, RowStatus::Ok);
        assert_eq!(d.regressions(), 0);
    }

    #[test]
    fn degenerate_medians_never_fail_the_gate() {
        let old = doc_with(vec![("s", "nan", f64::NAN), ("s", "zero", 0.0)]);
        let new = doc_with(vec![("s", "nan", 100.0), ("s", "zero", 100.0)]);
        let d = diff_docs(&old, &new, 25.0);
        assert_eq!(d.incomparable(), 2);
        assert_eq!(d.regressions(), 0);
        assert!(d.rows.iter().all(|r| r.delta_pct.is_none()));
    }

    #[test]
    fn missing_and_added_rows_are_reported_not_failed() {
        let old = doc_with(vec![("s", "gone", 100.0), ("s", "kept", 100.0)]);
        let new = doc_with(vec![("s", "kept", 100.0), ("s", "fresh", 100.0)]);
        let d = diff_docs(&old, &new, 25.0);
        assert_eq!(d.missing, vec!["s/gone".to_string()]);
        assert_eq!(d.added, vec!["s/fresh".to_string()]);
        assert_eq!(d.regressions(), 0);
        let report = d.render();
        assert!(report.contains("missing") && report.contains("added"));
        assert!(report.contains("-> ok"));
    }

    #[test]
    fn json_report_shape() {
        let old = doc_with(vec![("s", "a", 100.0)]);
        let new = doc_with(vec![("s", "a", 200.0)]);
        let d = diff_docs(&old, &new, 25.0);
        let j = d.to_json();
        assert_eq!(j.get("regressed").unwrap().as_usize().unwrap(), 1);
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("status").unwrap().as_str().unwrap(), "REGRESSED");
    }
}
