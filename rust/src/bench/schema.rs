//! The versioned `BENCH_*.json` document — the one schema every perf
//! artifact in the repo speaks: `bench run` suite output, the
//! `runs export-bench` sweep summary, and the `bench diff` regression
//! gate all read and write [`BenchDoc`].
//!
//! Format 2 envelope (format 1 was the ad-hoc sweep summary):
//!
//! ```json
//! {"bench":"codec","format":2,"quick":true,
//!  "host":{"os":"linux","arch":"x86_64","threads":8},
//!  "fingerprint":"9f2c41d0a3b7e615",
//!  "rows":[{"suite":"pipelines","name":"enc[dense]/p19674",
//!           "median_ns":81234.0,"p10_ns":79000.0,"p90_ns":90210.0,
//!           "iters":246,"bytes":78696,"mib_s":924.1}, ...]}
//! ```
//!
//! `bytes` is the optional payload-size axis; when present the derived
//! `mib_s` throughput is written alongside (recomputed on load, never
//! trusted). Producers may attach extra top-level keys (the sweep
//! summary keeps its legacy `records`/`runs`/`by_strategy` sections);
//! they round-trip verbatim and the diff gate ignores them.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::util::hash::fnv1a64;
use crate::util::json::Json;

use super::mib_per_s;

/// Current envelope version. Bump on any breaking row/envelope change;
/// `bench diff` hard-fails on a mismatch rather than comparing apples
/// to oranges.
pub const BENCH_FORMAT: usize = 2;

/// Typed schema errors — a malformed baseline must fail the gate with
/// a diagnosable message, never a panic and never a silent pass.
#[derive(Debug)]
pub enum BenchError {
    /// File-level I/O (missing baseline, unreadable path).
    Io(String, std::io::Error),
    /// Not JSON at all.
    Json(String),
    /// Valid JSON, wrong shape (missing key, wrong type, bad format).
    Schema(String),
}

impl fmt::Display for BenchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BenchError::Io(path, e) => write!(f, "bench file {path}: {e}"),
            BenchError::Json(m) => write!(f, "bench file is not valid JSON: {m}"),
            BenchError::Schema(m) => write!(f, "bench schema violation: {m}"),
        }
    }
}

impl std::error::Error for BenchError {}

/// One measured row. Identity for the regression gate is
/// `suite/name`; everything else is payload.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    pub suite: String,
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
    /// Payload-size axis: bytes processed per iteration, when the
    /// benchmark has a natural byte count (codec/net/store rows).
    pub bytes: Option<usize>,
}

impl BenchRow {
    /// The name-wise diff key.
    pub fn id(&self) -> String {
        format!("{}/{}", self.suite, self.name)
    }

    /// Derived throughput where a byte count exists.
    pub fn mib_s(&self) -> Option<f64> {
        self.bytes.map(|b| mib_per_s(b, self.median_ns))
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("suite", Json::str(&self.suite)),
            ("name", Json::str(&self.name)),
            ("median_ns", Json::num(self.median_ns)),
            ("p10_ns", Json::num(self.p10_ns)),
            ("p90_ns", Json::num(self.p90_ns)),
            ("iters", Json::from(self.iters)),
        ];
        if let Some(b) = self.bytes {
            pairs.push(("bytes", Json::from(b)));
            pairs.push(("mib_s", Json::num(mib_per_s(b, self.median_ns))));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<BenchRow, BenchError> {
        let field = |key: &str| {
            j.get(key)
                .map_err(|e| BenchError::Schema(format!("row: {e}")))
        };
        let num = |key: &str| {
            field(key)?
                .as_f64()
                .map_err(|e| BenchError::Schema(format!("row {key}: {e}")))
        };
        let bytes = match j.opt("bytes") {
            Some(v) => Some(
                v.as_usize()
                    .map_err(|e| BenchError::Schema(format!("row bytes: {e}")))?,
            ),
            None => None,
        };
        Ok(BenchRow {
            suite: field("suite")?
                .as_str()
                .map_err(|e| BenchError::Schema(format!("row suite: {e}")))?
                .to_string(),
            name: field("name")?
                .as_str()
                .map_err(|e| BenchError::Schema(format!("row name: {e}")))?
                .to_string(),
            median_ns: num("median_ns")?,
            p10_ns: num("p10_ns")?,
            p90_ns: num("p90_ns")?,
            iters: field("iters")?
                .as_usize()
                .map_err(|e| BenchError::Schema(format!("row iters: {e}")))?,
            bytes,
        })
    }
}

/// Host descriptor — context for reading a baseline, deliberately
/// coarse (fine-grained CPU identity would churn on every runner).
#[derive(Clone, Debug, PartialEq)]
pub struct HostInfo {
    pub os: String,
    pub arch: String,
    pub threads: usize,
}

impl HostInfo {
    pub fn current() -> HostInfo {
        HostInfo {
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("os", Json::str(&self.os)),
            ("arch", Json::str(&self.arch)),
            ("threads", Json::from(self.threads)),
        ])
    }

    fn from_json(j: &Json) -> Result<HostInfo, BenchError> {
        let get = |key: &str| {
            j.get(key)
                .map_err(|e| BenchError::Schema(format!("host: {e}")))
        };
        Ok(HostInfo {
            os: get("os")?
                .as_str()
                .map_err(|e| BenchError::Schema(format!("host os: {e}")))?
                .to_string(),
            arch: get("arch")?
                .as_str()
                .map_err(|e| BenchError::Schema(format!("host arch: {e}")))?
                .to_string(),
            threads: get("threads")?
                .as_usize()
                .map_err(|e| BenchError::Schema(format!("host threads: {e}")))?,
        })
    }
}

/// A full `BENCH_<area>.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchDoc {
    /// Area name (`codec`, `net`, `store`, `aggregate`, `runtime`,
    /// `rounds`, `sweep`).
    pub bench: String,
    pub format: usize,
    /// Whether rows were sampled with the quick profile — baselines
    /// and fresh runs must agree on this to be comparable.
    pub quick: bool,
    pub host: HostInfo,
    /// Config fingerprint (crate version + area + sampling profile) —
    /// cheap drift detector for "this baseline predates a schema-
    /// relevant change".
    pub fingerprint: String,
    pub rows: Vec<BenchRow>,
    /// Producer-specific top-level sections, round-tripped verbatim
    /// (the sweep summary's `records` / `runs` / `by_strategy`).
    pub extra: BTreeMap<String, Json>,
}

const ENVELOPE_KEYS: [&str; 6] = ["bench", "format", "quick", "host", "fingerprint", "rows"];

impl BenchDoc {
    pub fn new(area: &str, quick: bool) -> BenchDoc {
        let host = HostInfo::current();
        BenchDoc {
            bench: area.to_string(),
            format: BENCH_FORMAT,
            quick,
            fingerprint: fingerprint(area, quick),
            host,
            rows: Vec::new(),
            extra: BTreeMap::new(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = self
            .extra
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        obj.insert("bench".to_string(), Json::str(&self.bench));
        obj.insert("format".to_string(), Json::from(self.format));
        obj.insert("quick".to_string(), Json::from(self.quick));
        obj.insert("host".to_string(), self.host.to_json());
        obj.insert("fingerprint".to_string(), Json::str(&self.fingerprint));
        obj.insert(
            "rows".to_string(),
            Json::Arr(self.rows.iter().map(|r| r.to_json()).collect()),
        );
        Json::Obj(obj)
    }

    pub fn from_json(j: &Json) -> Result<BenchDoc, BenchError> {
        let obj = j
            .as_obj()
            .map_err(|e| BenchError::Schema(format!("document: {e}")))?;
        let get = |key: &str| {
            j.get(key)
                .map_err(|e| BenchError::Schema(format!("document: {e}")))
        };
        let format = get("format")?
            .as_usize()
            .map_err(|e| BenchError::Schema(format!("format: {e}")))?;
        if format != BENCH_FORMAT {
            return Err(BenchError::Schema(format!(
                "unsupported bench format {format} (this build reads format {BENCH_FORMAT})"
            )));
        }
        let rows = get("rows")?
            .as_arr()
            .map_err(|e| BenchError::Schema(format!("rows: {e}")))?
            .iter()
            .map(BenchRow::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let extra: BTreeMap<String, Json> = obj
            .iter()
            .filter(|(k, _)| !ENVELOPE_KEYS.contains(&k.as_str()))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        Ok(BenchDoc {
            bench: get("bench")?
                .as_str()
                .map_err(|e| BenchError::Schema(format!("bench: {e}")))?
                .to_string(),
            format,
            quick: get("quick")?
                .as_bool()
                .map_err(|e| BenchError::Schema(format!("quick: {e}")))?,
            host: HostInfo::from_json(get("host")?)?,
            fingerprint: get("fingerprint")?
                .as_str()
                .map_err(|e| BenchError::Schema(format!("fingerprint: {e}")))?
                .to_string(),
            rows,
            extra,
        })
    }

    /// Parse a document from file contents.
    pub fn parse(text: &str) -> Result<BenchDoc, BenchError> {
        let j = Json::parse(text.trim()).map_err(|e| BenchError::Json(e.to_string()))?;
        BenchDoc::from_json(&j)
    }

    pub fn load(path: &Path) -> Result<BenchDoc, BenchError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| BenchError::Io(path.display().to_string(), e))?;
        BenchDoc::parse(&text)
    }

    /// Write `{json}\n` to `path`, creating parent directories — the
    /// single writer behind `bench run` and `runs export-bench`.
    pub fn write(&self, path: &Path) -> Result<(), BenchError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| BenchError::Io(parent.display().to_string(), e))?;
            }
        }
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| BenchError::Io(path.display().to_string(), e))
    }
}

/// Stable config fingerprint: hex-encoded FNV-1a over the inputs that
/// make two documents comparable.
fn fingerprint(area: &str, quick: bool) -> String {
    let image = format!(
        "fedcompress/{}|format={}|area={}|quick={}",
        env!("CARGO_PKG_VERSION"),
        BENCH_FORMAT,
        area,
        quick
    );
    format!("{:016x}", fnv1a64(image.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_doc() -> BenchDoc {
        let mut doc = BenchDoc::new("codec", true);
        doc.rows.push(BenchRow {
            suite: "pipelines".to_string(),
            name: "enc[dense]/p19674".to_string(),
            median_ns: 81234.0,
            p10_ns: 79000.0,
            p90_ns: 90210.0,
            iters: 246,
            bytes: Some(78_696),
        });
        doc.rows.push(BenchRow {
            suite: "kmeans".to_string(),
            name: "kmeans_full/p19674/c16".to_string(),
            median_ns: 2.5e6,
            p10_ns: 2.4e6,
            p90_ns: 2.9e6,
            iters: 8,
            bytes: None,
        });
        doc.extra.insert("note".to_string(), Json::str("unit fixture"));
        doc
    }

    #[test]
    fn document_round_trips() {
        let doc = demo_doc();
        let text = format!("{}", doc.to_json());
        let back = BenchDoc::parse(&text).unwrap();
        assert_eq!(back, doc);
        // extra keys survive a second trip verbatim
        assert_eq!(format!("{}", back.to_json()), text);
    }

    #[test]
    fn write_then_load() {
        let dir = std::env::temp_dir().join("fedcompress_bench_schema_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/BENCH_codec.json");
        let doc = demo_doc();
        doc.write(&path).unwrap();
        assert_eq!(BenchDoc::load(&path).unwrap(), doc);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn format_mismatch_is_a_schema_error() {
        let mut doc = demo_doc();
        doc.format = 1;
        let text = format!("{}", doc.to_json());
        match BenchDoc::parse(&text) {
            Err(BenchError::Schema(m)) => assert!(m.contains("format 1")),
            other => panic!("expected schema error, got {other:?}"),
        }
    }

    #[test]
    fn malformed_inputs_are_typed_errors() {
        assert!(matches!(BenchDoc::parse("not json"), Err(BenchError::Json(_))));
        assert!(matches!(
            BenchDoc::parse("{\"format\":2}"),
            Err(BenchError::Schema(_))
        ));
        assert!(matches!(
            BenchDoc::load(Path::new("/nonexistent/BENCH_x.json")),
            Err(BenchError::Io(_, _))
        ));
    }

    #[test]
    fn mib_s_is_written_for_byte_rows_only() {
        let doc = demo_doc();
        let j = doc.to_json();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert!(rows[0].opt("mib_s").is_some());
        assert!(rows[1].opt("mib_s").is_none());
    }
}
