//! The in-process suite registry behind `bench run`. Each
//! `benches/bench_*.rs` body lives here as a registered suite
//! function; the `harness = false` bench targets are thin wrappers
//! over the same functions, so `cargo bench` and the headless CLI
//! verb measure identical code and emit identical row names.
//!
//! A [`SuiteCtx`] threads the sampling profile (full vs `--quick`)
//! through every measurement and collects [`BenchRow`]s; `run_area`
//! wraps the rows of one area into the committed `BENCH_<area>.json`
//! document.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::clustering::{representation_score, CentroidState};
use crate::codec::{Codec, CodecInput, CodecRegistry, StageBytes};
use crate::compression::codec::{decode, encode, quantize_and_encode};
use crate::compression::huffman::{huffman_decode, huffman_encode};
use crate::compression::kmeans::{assign_sorted, kmeans_1d, kmeans_pp_init};
use crate::config::FedConfig;
use crate::coordinator::aggregate::fedavg;
use crate::net::frame::{encode_frame, framed_len, read_frame, write_frame};
use crate::net::proto::{Msg, Upload};
use crate::obs::stream::{parse_stream, StreamEvent};
use crate::runtime::artifacts::default_dir;
use crate::runtime::literals::Arg;
use crate::runtime::Engine;
use crate::store::{run_key, RunRecord, RunStore};
use crate::sweep::{JobRunner, SmokeRunner, SweepJob};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::schema::{BenchDoc, BenchRow};
use super::{bench_opts, report_throughput, BenchOpts};

/// Measurement context: sampling profile + collected rows + notes
/// destined for the document's extra section.
pub struct SuiteCtx {
    opts: BenchOpts,
    quick: bool,
    rows: Vec<BenchRow>,
    notes: BTreeMap<String, Json>,
}

impl SuiteCtx {
    pub fn new(quick: bool) -> SuiteCtx {
        SuiteCtx {
            opts: if quick { BenchOpts::quick() } else { BenchOpts::full() },
            quick,
            rows: Vec::new(),
            notes: BTreeMap::new(),
        }
    }

    pub fn quick(&self) -> bool {
        self.quick
    }

    /// Measure `f` under the context's sampling profile and record one
    /// row. `bytes` is the payload-size axis; when present the MiB/s
    /// throughput line prints and the row carries the byte count.
    pub fn bench<F: FnMut()>(&mut self, suite: &str, name: &str, bytes: Option<usize>, f: F) {
        let r = bench_opts(name, self.opts, f);
        if let Some(b) = bytes {
            report_throughput(&r, b);
        }
        self.rows.push(BenchRow {
            suite: suite.to_string(),
            name: r.name,
            median_ns: r.median_ns,
            p10_ns: r.p10_ns,
            p90_ns: r.p90_ns,
            iters: r.iters_per_sample,
            bytes,
        });
    }

    /// Record a row measured outside the adaptive harness (one-shot
    /// batch measurements like the store append).
    pub fn push(&mut self, row: BenchRow) {
        self.rows.push(row);
    }

    pub fn note(&mut self, key: &str, value: Json) {
        self.notes.insert(key.to_string(), value);
    }

    pub fn rows(&self) -> &[BenchRow] {
        self.rows.as_slice()
    }
}

/// One registered bench area.
pub struct Area {
    pub name: &'static str,
    pub summary: &'static str,
    run: fn(&mut SuiteCtx) -> Result<()>,
}

/// The registry `bench run --area <name>|all` resolves against.
/// (`rounds` is not here: it rolls up teed phase-timing events from a
/// run store instead of measuring code, see [`rounds_rollup`].)
pub const AREAS: [Area; 6] = [
    Area {
        name: "codec",
        summary: "pipeline encode/decode, quantize, huffman, k-means",
        run: |ctx| {
            codec_pipelines(ctx)?;
            codec_primitives(ctx)?;
            kmeans(ctx)
        },
    },
    Area {
        name: "net",
        summary: "frame codec, protocol messages, loopback TCP",
        run: net_micro,
    },
    Area {
        name: "store",
        summary: "record encode/decode, key hash, append, open scan",
        run: store,
    },
    Area {
        name: "aggregate",
        summary: "fedavg fold and representation score",
        run: aggregate,
    },
    Area {
        name: "runtime",
        summary: "PJRT entry-point latency (skips without artifacts)",
        run: runtime,
    },
    Area {
        name: "kernels",
        summary: "SIMD kernel throughput, scalar vs detected backend",
        run: kernels,
    },
];

pub fn area(name: &str) -> Option<&'static Area> {
    AREAS.iter().find(|a| a.name == name)
}

/// Run one area's suites and wrap the rows into a versioned document.
pub fn run_area(name: &str, quick: bool) -> Result<BenchDoc> {
    let Some(area) = area(name) else {
        let known: Vec<&str> = AREAS.iter().map(|a| a.name).collect();
        bail!("unknown bench area '{name}' (expected one of {known:?}, 'rounds', or 'all')");
    };
    let mut ctx = SuiteCtx::new(quick);
    (area.run)(&mut ctx).with_context(|| format!("bench area '{name}'"))?;
    let mut doc = BenchDoc::new(name, quick);
    doc.rows = ctx.rows;
    doc.extra = ctx.notes;
    Ok(doc)
}

// --- codec ----------------------------------------------------------------

/// Registry pipelines: encode + decode per spec at one realistic model
/// size, plus per-stage encode ns via the pipeline's timed path.
pub fn codec_pipelines(ctx: &mut SuiteCtx) -> Result<()> {
    use std::hint::black_box;
    let mut rng = Rng::new(1);
    let p = 19_674usize;
    let theta: Vec<f32> = (0..p).map(|_| rng.normal() * 0.2).collect();
    let cents = CentroidState::init_from_weights(&theta, 16, 32, &mut rng);
    let reg = CodecRegistry::builtin();

    for spec in [
        "dense",
        "topk(keep=0.1)",
        "kmeans(c=16,iters=25)",
        "codebook",
        "topk(keep=0.6)|kmeans(c=15,iters=25)|huffman",
        "codebook|huffman",
        "codebook|delta",
    ] {
        let pipe = reg.build(spec)?;
        let input = CodecInput {
            theta: &theta,
            centroids: Some(&cents),
            stream: crate::codec::stream::FINAL,
        };
        ctx.bench("pipelines", &format!("pipe_encode[{spec}]"), Some(4 * p), || {
            let mut enc_rng = Rng::new(7);
            let blob = pipe.encode(black_box(&input), &mut enc_rng).unwrap();
            black_box(blob.payload.len());
        });

        // the decode-bench blob comes from a FRESH sender instance:
        // the loop above advanced `pipe`'s delta stream state, and a
        // residual blob would be undecodable by a cold peer. A fresh
        // sender ships the flat baseline form, which a fresh peer
        // decodes repeatedly without needing stream history.
        let blob = reg.build(spec)?.encode(&input, &mut Rng::new(7))?;
        let peer = reg.build(spec)?;
        peer.decode(&blob.payload)?;
        let bytes = blob.payload.len();
        ctx.bench("pipelines", &format!("pipe_decode[{spec}]"), Some(bytes), || {
            let out = peer.decode(black_box(&blob.payload)).unwrap();
            black_box(out.len());
        });
    }

    // per-stage profile of the FedZip stack via the timed pipeline
    // path: medians over repeated timed encodes, one row per stage
    let spec = "topk(keep=0.6)|kmeans(c=15,iters=25)|huffman";
    let input = CodecInput {
        theta: &theta,
        centroids: Some(&cents),
        stream: crate::codec::stream::FINAL,
    };
    let reps = if ctx.quick() { 5 } else { 15 };
    let mut per_stage: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for _ in 0..reps {
        let pipe = reg.build(spec)?;
        let (_, stage_ns) = pipe.encode_timed(&input, &mut Rng::new(7))?;
        for (stage, ns) in stage_ns {
            per_stage.entry(stage).or_default().push(ns as f64);
        }
    }
    for (stage, samples) in per_stage {
        let (median, p10, p90) = percentiles(samples);
        ctx.push(BenchRow {
            suite: "stages".to_string(),
            name: format!("enc[{spec}]/{stage}"),
            median_ns: median,
            p10_ns: p10,
            p90_ns: p90,
            iters: reps,
            bytes: None,
        });
    }
    Ok(())
}

/// Quantize/encode/decode primitives at realistic (p, c) points.
pub fn codec_primitives(ctx: &mut SuiteCtx) -> Result<()> {
    use std::hint::black_box;
    let mut rng = Rng::new(1);
    for &(p, c) in &[(19_674usize, 16usize), (19_674, 32), (100_000, 16)] {
        let weights: Vec<f32> = (0..p).map(|_| rng.normal() * 0.2).collect();
        let (cb, _, _) = kmeans_1d(&weights, c, 25, &mut rng);

        ctx.bench(
            "primitives",
            &format!("quantize_encode_p{p}_c{c}"),
            Some(p * 4),
            || {
                let (enc, _) = quantize_and_encode(black_box(&weights), black_box(&cb));
                black_box(enc.wire_bytes());
            },
        );

        let (enc, _) = quantize_and_encode(&weights, &cb);
        let bytes = enc.bytes.len();
        ctx.bench("primitives", &format!("decode_p{p}_c{c}"), Some(bytes), || {
            let out = decode(black_box(&enc.bytes)).unwrap();
            black_box(out.0.len());
        });

        // pure huffman on the index stream
        let idx: Vec<u32> = (0..p).map(|_| rng.below(c) as u32).collect();
        ctx.bench("primitives", &format!("huffman_encode_p{p}_c{c}"), None, || {
            let e = huffman_encode(black_box(&idx), c);
            black_box(e.payload_bits);
        });
        let henc = huffman_encode(&idx, c);
        ctx.bench("primitives", &format!("huffman_decode_p{p}_c{c}"), None, || {
            let d = huffman_decode(black_box(&henc)).unwrap();
            black_box(d.len());
        });

        // flat-pack path (encode() picks it for uniform indices)
        ctx.bench("primitives", &format!("flat_encode_p{p}_c{c}"), None, || {
            let e = encode(black_box(&cb), black_box(&idx));
            black_box(e.bytes.len());
        });
    }
    Ok(())
}

/// k-means: the server re-fits codebooks (FedZip per upload;
/// FedCompress at warmup exit / final snap), so Lloyd iterations sit
/// on the coordinator path.
pub fn kmeans(ctx: &mut SuiteCtx) -> Result<()> {
    use std::hint::black_box;
    let mut rng = Rng::new(2);
    for &p in &[19_674usize, 100_000] {
        let weights: Vec<f32> = (0..p).map(|_| rng.normal() * 0.2).collect();

        for &c in &[15usize, 16, 32] {
            ctx.bench("kmeans", &format!("kmeanspp_init_p{p}_c{c}"), None, || {
                let mut r = Rng::new(3);
                let cb = kmeans_pp_init(black_box(&weights), c, &mut r);
                black_box(cb.len());
            });
            ctx.bench("kmeans", &format!("kmeans_full_p{p}_c{c}"), None, || {
                let mut r = Rng::new(3);
                let (cb, _, _) = kmeans_1d(black_box(&weights), c, 25, &mut r);
                black_box(cb.len());
            });
        }

        let mut r = Rng::new(3);
        let (cb, _, _) = kmeans_1d(&weights, 16, 25, &mut r);
        ctx.bench("kmeans", &format!("assign_all_p{p}_c16"), None, || {
            let mut acc = 0usize;
            for &w in black_box(&weights) {
                acc += assign_sorted(w, black_box(&cb));
            }
            black_box(acc);
        });
    }
    Ok(())
}

// --- aggregate ------------------------------------------------------------

/// FedAvg over M client vectors and the representation-score SVD — the
/// two pure-rust stages of every round.
pub fn aggregate(ctx: &mut SuiteCtx) -> Result<()> {
    use std::hint::black_box;
    let mut rng = Rng::new(3);
    for &(p, m) in &[(19_674usize, 20usize), (100_000, 20), (19_674, 100)] {
        let clients: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..p).map(|_| rng.normal()).collect())
            .collect();
        let weights: Vec<usize> = (0..m).map(|i| 50 + i).collect();
        ctx.bench("aggregate", &format!("fedavg_p{p}_m{m}"), Some(p * m * 4), || {
            let agg = fedavg(black_box(&clients), black_box(&weights)).unwrap();
            black_box(agg[0]);
        });
    }

    for &(n, d) in &[(64usize, 32usize), (256, 32), (64, 64)] {
        let emb: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        ctx.bench("aggregate", &format!("repr_score_n{n}_d{d}"), None, || {
            let s = representation_score(black_box(&emb), n, d);
            black_box(s);
        });
    }
    Ok(())
}

// --- kernels --------------------------------------------------------------

/// Comparative throughput of every SIMD kernel: one row per kernel x
/// available backend x payload size (1 KiB to 100 MiB of f32 input),
/// `{kernel}_{backend}_{size}`. Scalar always runs; on SIMD hardware
/// the detected backend's rows print side by side, so the MiB/s table
/// is the speedup report. Row set is identical in quick and full mode.
pub fn kernels(ctx: &mut SuiteCtx) -> Result<()> {
    use crate::kernels as k;
    use std::hint::black_box;

    const SIZES: [(usize, &str); 4] =
        [(1 << 10, "1KiB"), (64 << 10, "64KiB"), (1 << 20, "1MiB"), (100 << 20, "100MiB")];
    const CODEBOOK_C: usize = 16;
    const PACK_BITS: u32 = 11; // odd width: exercises straddled bytes

    let backends = k::available_backends();
    ctx.note(
        "backends",
        Json::Arr(backends.iter().map(|b| Json::Str(b.name().to_string())).collect()),
    );
    ctx.note("detected", Json::Str(k::detect().name().to_string()));

    let mut rng = Rng::new(11);
    for (bytes, label) in SIZES {
        let n = bytes / 4;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let keys = k::magnitude_keys(&xs);
        let threshold = keys[n / 2];
        let mut codebook: Vec<f32> = (0..CODEBOOK_C).map(|i| i as f32 * 0.25 - 2.0).collect();
        codebook.sort_by(f32::total_cmp);
        let symbols: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        let values: Vec<u32> = (0..n).map(|_| rng.below(1 << PACK_BITS) as u32).collect();
        let packed = k::pack_bits_on(k::Backend::Scalar, &values, PACK_BITS);

        for &b in &backends {
            let name = |kernel: &str| format!("{kernel}_{}_{label}", b.name());
            let mut out = vec![0u32; n];
            ctx.bench("kernels", &name("magnitude_keys"), Some(bytes), || {
                k::magnitude_keys_on(b, black_box(&xs), &mut out);
                black_box(out[0]);
            });
            ctx.bench("kernels", &name("abs_max"), Some(bytes), || {
                black_box(k::abs_max_on(b, black_box(&xs)));
            });
            ctx.bench("kernels", &name("threshold_count"), Some(bytes), || {
                black_box(k::threshold_count_on(b, black_box(&keys), threshold));
            });
            ctx.bench("kernels", &name("assign_nearest"), Some(bytes), || {
                k::assign_nearest_on(b, black_box(&xs), &codebook, &mut out);
                black_box(out[0]);
            });
            let mut snap_buf = xs.clone();
            ctx.bench("kernels", &name("snap_to_codebook"), Some(bytes), || {
                snap_buf.copy_from_slice(&xs);
                black_box(k::snap_to_codebook_on(b, &mut snap_buf, &codebook).len());
            });
            ctx.bench("kernels", &name("histogram_u32"), Some(bytes), || {
                black_box(k::histogram_u32_on(b, black_box(&symbols), 256)[0]);
            });
            ctx.bench("kernels", &name("pack_bits"), Some(bytes), || {
                black_box(k::pack_bits_on(b, black_box(&values), PACK_BITS).len());
            });
            ctx.bench("kernels", &name("unpack_bits"), Some(bytes), || {
                black_box(k::unpack_bits_on(b, black_box(&packed), PACK_BITS, n));
            });
            let mut acc = vec![0.0f64; n];
            ctx.bench("kernels", &name("axpy_f64"), Some(bytes), || {
                k::axpy_f64_on(b, &mut acc, black_box(&xs), 0.125);
                black_box(acc[0]);
            });
        }
    }
    Ok(())
}

// --- net ------------------------------------------------------------------

/// Frame codec, full `Upload` protocol message, loopback TCP
/// round-trips. The fleet-scale mux smoke stays in
/// `benches/bench_net.rs` — it is an assertion harness with env
/// knobs (CI's flat-RSS gate), not a trajectory row.
pub fn net_micro(ctx: &mut SuiteCtx) -> Result<()> {
    use std::hint::black_box;
    let mut rng = Rng::new(1);

    // --- frame codec ------------------------------------------------------
    for &size in &[1_000usize, 78_696, 1_000_000] {
        let payload: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
        ctx.bench("frame", &format!("frame_encode_{size}B"), Some(size), || {
            let f = encode_frame(4, black_box(&payload));
            black_box(f.len());
        });

        let frame = encode_frame(4, &payload);
        ctx.bench("frame", &format!("frame_decode_{size}B"), Some(size), || {
            let (ty, body) = read_frame(&mut black_box(&frame[..])).unwrap();
            black_box((ty, body.len()));
        });
    }

    // --- full Upload message (the per-client per-round unit) --------------
    let payload: Vec<u8> = (0..20_000).map(|_| rng.below(256) as u8).collect();
    let upload = Msg::Upload(Upload {
        round: 3,
        client: 7,
        score: 4.5,
        n: 96,
        mean_ce: 1.25,
        mu: (0..32).map(|_| rng.normal()).collect(),
        stages: vec![
            StageBytes {
                stage: "codebook".to_string(),
                bytes: 24_000,
            },
            StageBytes {
                stage: "huffman".to_string(),
                bytes: 20_000,
            },
        ],
        spec: "codebook|huffman".to_string(),
        payload: payload.clone(),
    });
    let encoded = {
        let mut buf = Vec::new();
        upload.write_to(&mut buf)?;
        buf
    };
    let enc_len = encoded.len();
    ctx.bench("proto", "upload_msg_encode_20kB", Some(enc_len), || {
        let mut buf = Vec::with_capacity(enc_len);
        upload.write_to(&mut buf).unwrap();
        black_box(buf.len());
    });
    ctx.bench("proto", "upload_msg_decode_20kB", Some(enc_len), || {
        let m = Msg::read_from(&mut black_box(&encoded[..])).unwrap();
        black_box(m.kind());
    });

    // --- loopback TCP round-trip ------------------------------------------
    // an echo peer: every received frame comes straight back
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let echo = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        stream.set_nodelay(true).ok();
        while let Ok((ty, payload)) = read_frame(&mut &stream) {
            if write_frame(&mut &stream, ty, &payload).is_err() {
                break;
            }
        }
    });
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    for &size in &[1_000usize, 78_696, 1_000_000] {
        let payload: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
        // a round trip moves the frame both ways
        let moved = 2 * framed_len(size);
        ctx.bench("loopback", &format!("loopback_roundtrip_{size}B"), Some(moved), || {
            write_frame(&mut &stream, 4, black_box(&payload)).unwrap();
            let (_, body) = read_frame(&mut &stream).unwrap();
            black_box(body.len());
        });
    }
    drop(stream);
    echo.join().ok();
    Ok(())
}

// --- store ----------------------------------------------------------------

fn smoke_record(seed: u64) -> Result<RunRecord> {
    let mut cfg = FedConfig::quick("cifar10");
    cfg.seed = seed;
    cfg.rounds = 20;
    cfg.clients = 20;
    let job = SweepJob {
        idx: 0,
        strategy: "fedcompress".to_string(),
        cfg: cfg.clone(),
        key: run_key("fedcompress", &cfg),
    };
    SmokeRunner.run(&job)
}

/// Record encode/decode, content-key hashing, append, and the
/// checksum-verifying open scan. No artifacts needed — records come
/// from the sweep's synthetic runner.
pub fn store(ctx: &mut SuiteCtx) -> Result<()> {
    let rec = smoke_record(1)?;
    let body = rec.to_body_bytes();
    println!(
        "record: {} rounds, {} transfers, {} B body",
        rec.rounds.len(),
        rec.ledger.transfer_count(),
        body.len()
    );

    ctx.bench("store", "store_record_encode", Some(body.len()), || {
        std::hint::black_box(rec.to_body_bytes());
    });
    ctx.bench("store", "store_record_decode", Some(body.len()), || {
        std::hint::black_box(RunRecord::from_body_bytes(&body).unwrap());
    });

    let cfg = FedConfig::paper("cifar10");
    ctx.bench("store", "store_run_key", None, || {
        std::hint::black_box(run_key("fedcompress", &cfg));
    });

    // append + open over a populated store; append is measured once
    // over a fixed batch (the adaptive harness would grow the file —
    // and the derived index.json rewrite — without bound)
    let dir = std::env::temp_dir().join("fedcompress_bench_store");
    let _ = std::fs::remove_dir_all(&dir);
    let mut store = RunStore::open(&dir)?;
    let n = if ctx.quick() { 16u64 } else { 64 };
    let records: Vec<RunRecord> = (0..n).map(smoke_record).collect::<Result<_>>()?;
    let sw = Stopwatch::start();
    for rec in &records {
        store.append(rec)?;
    }
    let total_ms = sw.elapsed_ms();
    let per_append_ns = 1e6 * total_ms / records.len() as f64;
    println!(
        "BENCH store_append_batch n={} total_ms={:.1} per_append_us={:.1}",
        records.len(),
        total_ms,
        per_append_ns / 1e3
    );
    ctx.push(BenchRow {
        suite: "store".to_string(),
        name: "store_append_batch".to_string(),
        median_ns: per_append_ns,
        p10_ns: per_append_ns,
        p90_ns: per_append_ns,
        iters: records.len(),
        bytes: Some(body.len() + 16),
    });

    let entries = store.metas().len();
    let file_len = std::fs::metadata(dir.join("runs.fcr"))?.len() as usize;
    println!("store: {entries} entries, {file_len} B file");
    ctx.bench("store", "store_open_scan", Some(file_len), || {
        std::hint::black_box(RunStore::open(&dir).unwrap());
    });

    let key = records[0].key;
    ctx.bench("store", "store_get", Some(body.len() + 16), || {
        std::hint::black_box(store.get(key).unwrap().unwrap());
    });

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

// --- runtime --------------------------------------------------------------

/// PJRT entry-point latency — the dominant cost of a federated round.
/// Skips cleanly (zero rows, a `skipped` note) when AOT artifacts are
/// absent, mirroring the engine-gated test convention.
pub fn runtime(ctx: &mut SuiteCtx) -> Result<()> {
    use std::hint::black_box;
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_runtime: artifacts not built (run `make artifacts`)");
        ctx.note("skipped", Json::from(true));
        ctx.note("skip_reason", Json::str("artifacts not built"));
        return Ok(());
    }
    let engine = Engine::load(&dir)?;
    let mut rng = Rng::new(4);

    for dataset in ["cifar10", "speechcommands"] {
        let ds = engine.manifest.dataset(dataset)?.clone();
        let p = ds.spec.param_count;
        let (c, h, w) = ds.spec.input_shape;
        let b = engine.manifest.batch;
        let eb = engine.manifest.eval_batch;
        let c_max = engine.manifest.c_max;

        let theta = engine.init_theta(dataset)?;
        let mu: Vec<f32> = (0..c_max).map(|i| -0.5 + i as f32 / c_max as f32).collect();
        let mask: Vec<f32> = (0..c_max).map(|i| (i < 16) as u8 as f32).collect();
        let x: Vec<f32> = (0..b * c * h * w).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(ds.spec.num_classes) as i32).collect();
        let xe: Vec<f32> = (0..eb * c * h * w).map(|_| rng.normal()).collect();
        let ye: Vec<i32> = (0..eb).map(|_| rng.below(ds.spec.num_classes) as i32).collect();
        let teacher = theta.clone();

        engine.warmup(dataset)?;

        ctx.bench("runtime", &format!("{dataset}_train_step_p{p}"), None, || {
            let out = engine
                .run(
                    dataset,
                    "train_step",
                    &[
                        Arg::F32(&theta),
                        Arg::F32(&mu),
                        Arg::F32(&mask),
                        Arg::F32(&x),
                        Arg::I32(&y),
                        Arg::Scalar(0.05),
                        Arg::Scalar(0.5),
                    ],
                )
                .unwrap();
            black_box(out.len());
        });

        ctx.bench("runtime", &format!("{dataset}_distill_step_p{p}"), None, || {
            let out = engine
                .run(
                    dataset,
                    "distill_step",
                    &[
                        Arg::F32(&theta),
                        Arg::F32(&teacher),
                        Arg::F32(&mu),
                        Arg::F32(&mask),
                        Arg::F32(&x),
                        Arg::Scalar(0.05),
                        Arg::Scalar(0.5),
                        Arg::Scalar(2.0),
                    ],
                )
                .unwrap();
            black_box(out.len());
        });

        ctx.bench("runtime", &format!("{dataset}_eval_step"), None, || {
            let out = engine
                .run(
                    dataset,
                    "eval_step",
                    &[Arg::F32(&theta), Arg::F32(&xe), Arg::I32(&ye)],
                )
                .unwrap();
            black_box(out.len());
        });

        ctx.bench("runtime", &format!("{dataset}_embed"), None, || {
            let out = engine
                .run(dataset, "embed", &[Arg::F32(&theta), Arg::F32(&xe)])
                .unwrap();
            black_box(out.len());
        });

        ctx.bench("runtime", &format!("{dataset}_snap_hlo"), None, || {
            let out = engine
                .run(
                    dataset,
                    "snap",
                    &[Arg::F32(&theta), Arg::F32(&mu), Arg::F32(&mask)],
                )
                .unwrap();
            black_box(out.len());
        });
    }
    Ok(())
}

// --- rounds rollup --------------------------------------------------------

/// `bench run --area rounds`: roll the live-only `phase_timing`
/// events teed under `<store>/events/*.jsonl` into one document —
/// median / p10 / p90 ns per phase across every profiled round, plus
/// a synthetic `total` row summing each round's phases.
pub fn rounds_rollup(events_dir: &Path, quick: bool) -> Result<BenchDoc> {
    let mut files: Vec<std::path::PathBuf> = match std::fs::read_dir(events_dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "jsonl"))
            .collect(),
        Err(e) => bail!("reading events dir {}: {e}", events_dir.display()),
    };
    files.sort();

    let mut per_phase: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut rounds_seen = 0usize;
    for path in &files {
        let Ok(text) = std::fs::read_to_string(path) else { continue };
        let replay = parse_stream(&text);
        for ev in &replay.events {
            if let StreamEvent::PhaseTiming { ns, .. } = ev {
                rounds_seen += 1;
                let mut total = 0u64;
                for (phase, v) in ns {
                    per_phase.entry(phase.clone()).or_default().push(*v as f64);
                    total = total.saturating_add(*v);
                }
                per_phase.entry("total".to_string()).or_default().push(total as f64);
            }
        }
    }

    let mut doc = BenchDoc::new("rounds", quick);
    doc.extra.insert("stream_files".to_string(), Json::from(files.len()));
    doc.extra.insert("profiled_rounds".to_string(), Json::from(rounds_seen));
    for (phase, samples) in per_phase {
        let iters = samples.len();
        let (median, p10, p90) = percentiles(samples);
        doc.rows.push(BenchRow {
            suite: "rounds".to_string(),
            name: phase,
            median_ns: median,
            p10_ns: p10,
            p90_ns: p90,
            iters,
            bytes: None,
        });
    }
    Ok(doc)
}

/// (median, p10, p90) with the harness's index convention.
fn percentiles(mut samples: Vec<f64>) -> (f64, f64, f64) {
    if samples.is_empty() {
        return (f64::NAN, f64::NAN, f64::NAN);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    (samples[n / 2], samples[n / 10], samples[n * 9 / 10])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_collects_rows_with_byte_axis() {
        let mut ctx = SuiteCtx::new(true);
        ctx.bench("unit", "noop", Some(1024), || {
            std::hint::black_box(2u64 + 2);
        });
        ctx.bench("unit", "no_bytes", None, || {
            std::hint::black_box(1u64);
        });
        assert_eq!(ctx.rows().len(), 2);
        assert_eq!(ctx.rows()[0].id(), "unit/noop");
        assert_eq!(ctx.rows()[0].bytes, Some(1024));
        assert!(ctx.rows()[0].mib_s().is_some());
        assert!(ctx.rows()[1].mib_s().is_none());
    }

    #[test]
    fn registry_covers_the_cli_areas() {
        for name in ["codec", "net", "store", "aggregate", "runtime", "kernels"] {
            assert!(area(name).is_some(), "area {name} missing");
        }
        assert!(area("rounds").is_none(), "rounds is a rollup, not a suite");
        assert!(area("bogus").is_none());
    }

    #[test]
    fn percentiles_convention_matches_harness() {
        let (m, p10, p90) = percentiles((1..=15).map(|i| i as f64).collect());
        assert_eq!((m, p10, p90), (8.0, 2.0, 14.0));
        let (m, _, _) = percentiles(vec![]);
        assert!(m.is_nan());
    }

    #[test]
    fn rounds_rollup_aggregates_phase_events() {
        use crate::obs::stream::{render_stream, StreamHeader, SCHEMA_VERSION};
        let dir = std::env::temp_dir().join("fedcompress_bench_rounds_unit/events");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let events: Vec<StreamEvent> = (0..4)
            .map(|r| StreamEvent::PhaseTiming {
                round: r,
                ns: vec![
                    ("aggregate".to_string(), 10 + r as u64),
                    ("train".to_string(), 100 * (r as u64 + 1)),
                ],
            })
            .collect();
        let header = StreamHeader {
            schema: SCHEMA_VERSION,
            run: 1,
            fingerprint: 2,
            strategy: "unit".to_string(),
        };
        std::fs::write(dir.join("ab.jsonl"), render_stream(&header, &events)).unwrap();
        std::fs::write(dir.join("skip.txt"), "not a stream").unwrap();

        let doc = rounds_rollup(&dir, true).unwrap();
        assert_eq!(doc.bench, "rounds");
        assert_eq!(doc.extra.get("profiled_rounds").unwrap().as_usize().unwrap(), 4);
        let names: Vec<&str> = doc.rows.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["aggregate", "total", "train"]);
        let train = doc.rows.iter().find(|r| r.name == "train").unwrap();
        assert_eq!(train.iters, 4);
        assert_eq!(train.median_ns, 300.0);
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("fedcompress_bench_rounds_unit"));
    }

    #[test]
    fn rounds_rollup_missing_dir_is_an_error() {
        assert!(rounds_rollup(Path::new("/nonexistent/events"), true).is_err());
    }
}
