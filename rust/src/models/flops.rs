//! Per-layer FLOP and byte analysis, feeding the edge latency model.
//!
//! Spatial dims are propagated through the conv stack from the input
//! shape (SAME padding, the only mode the nets use); dense layers run
//! on pooled features. Counts are MACs*2 (the usual convention).

use super::spec::{LayerKind, ModelSpec};

#[derive(Clone, Debug)]
pub struct LayerCost {
    pub layer: String,
    pub flops: u64,
    /// bytes of weights streamed from memory (dense f32)
    pub weight_bytes: u64,
    /// activation bytes written
    pub activation_bytes: u64,
}

/// Batch-1 inference cost per weight-bearing layer.
pub fn inference_costs(spec: &ModelSpec) -> Vec<LayerCost> {
    let (_, mut h, mut w) = spec.input_shape;
    let mut costs = Vec::new();
    for l in spec.weight_entries() {
        match l.kind {
            LayerKind::Conv => {
                // shape = [cout, cin/groups, k, k]
                let (cout, cin_g, k) = (l.shape[0], l.shape[1], l.shape[2]);
                // ".skip" convs are parallel branches: they produce the
                // same output dims the main path already reached, so the
                // running dims must not be strided a second time
                let is_branch = l.layer.ends_with(".skip");
                if !is_branch {
                    h = h.div_ceil(l.stride);
                    w = w.div_ceil(l.stride);
                }
                let macs = (cout * cin_g * k * k * h * w) as u64;
                costs.push(LayerCost {
                    layer: l.layer.clone(),
                    flops: 2 * macs,
                    weight_bytes: (l.size * 4) as u64,
                    activation_bytes: (cout * h * w * 4) as u64,
                });
            }
            LayerKind::Dense => {
                let (din, dout) = (l.shape[0], l.shape[1]);
                costs.push(LayerCost {
                    layer: l.layer.clone(),
                    flops: 2 * (din * dout) as u64,
                    weight_bytes: (l.size * 4) as u64,
                    activation_bytes: (dout * 4) as u64,
                });
            }
        }
    }
    costs
}

pub fn total_flops(spec: &ModelSpec) -> u64 {
    inference_costs(spec).iter().map(|c| c.flops).sum()
}

pub fn total_weight_bytes(spec: &ModelSpec) -> u64 {
    inference_costs(spec).iter().map(|c| c.weight_bytes).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::spec::tests::demo_json;
    use crate::models::ModelSpec;

    #[test]
    fn demo_costs() {
        let spec = ModelSpec::from_manifest("demo", &demo_json()).unwrap();
        let costs = inference_costs(&spec);
        assert_eq!(costs.len(), 2);
        // conv: cout=2, cin=3, k=2, 16x16 SAME stride 1
        assert_eq!(costs[0].flops, 2 * (2 * 3 * 2 * 2) as u64 * 256);
        assert_eq!(costs[0].weight_bytes, 24 * 4);
        // dense 2x2
        assert_eq!(costs[1].flops, 8);
        assert!(total_flops(&spec) > 0);
        assert_eq!(total_weight_bytes(&spec), (24 + 4) * 4);
    }
}
