//! Model *specifications* mirrored from the python build side via the
//! AOT manifest: flat-parameter layout, per-layer shapes, and FLOP /
//! byte counts. The rust side never re-implements the networks — it
//! reads their structure to drive aggregation, codecs and the edge
//! latency model.

pub mod flops;
pub mod spec;

pub use spec::{LayerEntry, LayerKind, ModelSpec};
