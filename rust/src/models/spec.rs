//! Parsed model structure from `artifacts/manifest.json`.

use crate::util::json::Json;
use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Dense,
}

/// One flat-layout entry (a weight or bias tensor of one layer).
#[derive(Clone, Debug)]
pub struct LayerEntry {
    pub layer: String,
    pub kind: LayerKind,
    pub field: String, // "w" | "b"
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub stride: usize,
    pub groups: usize,
}

/// A model's full structural description for one dataset config.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub domain: String,
    pub num_classes: usize,
    pub input_shape: (usize, usize, usize),
    pub emb_dim: usize,
    pub param_count: usize,
    pub layers: Vec<LayerEntry>,
}

impl ModelSpec {
    pub fn from_manifest(name: &str, ds: &Json) -> Result<ModelSpec> {
        let shape = ds.get("input_shape")?.usize_array()?;
        if shape.len() != 3 {
            bail!("input_shape must be rank 3");
        }
        let layers = ds
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                let kind = match l.get("kind")?.as_str()? {
                    "conv" => LayerKind::Conv,
                    "dense" => LayerKind::Dense,
                    other => bail!("unknown layer kind '{other}'"),
                };
                Ok(LayerEntry {
                    layer: l.get("layer")?.as_str()?.to_string(),
                    kind,
                    field: l.get("field")?.as_str()?.to_string(),
                    shape: l.get("shape")?.usize_array()?,
                    offset: l.get("offset")?.as_usize()?,
                    size: l.get("size")?.as_usize()?,
                    stride: l.get("stride")?.as_usize()?,
                    groups: l.get("groups")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let spec = ModelSpec {
            name: name.to_string(),
            domain: ds.get("domain")?.as_str()?.to_string(),
            num_classes: ds.get("num_classes")?.as_usize()?,
            input_shape: (shape[0], shape[1], shape[2]),
            emb_dim: ds.get("emb_dim")?.as_usize()?,
            param_count: ds.get("param_count")?.as_usize()?,
            layers,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for l in &self.layers {
            if l.offset != off {
                bail!("layout hole at '{}': offset {} != {}", l.layer, l.offset, off);
            }
            let expect: usize = l.shape.iter().product();
            if expect != l.size {
                bail!("size mismatch at '{}'", l.layer);
            }
            off += l.size;
        }
        if off != self.param_count {
            bail!("param_count {} != layout total {}", self.param_count, off);
        }
        Ok(())
    }

    /// Weight-tensor entries only (biases excluded), e.g. for layer-wise
    /// statistics.
    pub fn weight_entries(&self) -> impl Iterator<Item = &LayerEntry> {
        self.layers.iter().filter(|l| l.field == "w")
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn demo_json() -> Json {
        Json::parse(
            r#"{
              "domain": "vision", "num_classes": 10,
              "input_shape": [3, 16, 16], "emb_dim": 32, "param_count": 30,
              "layers": [
                {"layer": "stem", "kind": "conv", "field": "w",
                 "shape": [2, 3, 2, 2], "offset": 0, "size": 24,
                 "stride": 1, "groups": 1},
                {"layer": "stem", "kind": "conv", "field": "b",
                 "shape": [2], "offset": 24, "size": 2,
                 "stride": 1, "groups": 1},
                {"layer": "fc", "kind": "dense", "field": "w",
                 "shape": [2, 2], "offset": 26, "size": 4,
                 "stride": 1, "groups": 1}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_demo() {
        let spec = ModelSpec::from_manifest("demo", &demo_json()).unwrap();
        assert_eq!(spec.param_count, 30);
        assert_eq!(spec.layers.len(), 3);
        assert_eq!(spec.layers[0].kind, LayerKind::Conv);
        assert_eq!(spec.weight_entries().count(), 2);
    }

    #[test]
    fn rejects_layout_holes() {
        let mut j = demo_json();
        if let Json::Obj(m) = &mut j {
            m.insert("param_count".into(), Json::Num(31.0));
        }
        assert!(ModelSpec::from_manifest("demo", &j).is_err());
    }
}
