//! Multiplexed connection layer: many logical clients per socket,
//! all sockets serviced by one readiness loop on the coordinator
//! thread.
//!
//! The first-generation TCP transport parked one OS thread per worker
//! connection in a stop-and-wait loop (dispatch every download, then
//! block on each upload in turn). That shape caps the fleet at the
//! thread budget and keeps every in-flight upload buffered until the
//! slowest worker reports. The mux replaces it:
//!
//! ```text
//!            ┌─────────────── readiness loop ────────────────┐
//!            │  for each conn:                               │
//!            │    write: drain outbox  ──► WouldBlock? next  │
//!            │    read:  fill FrameReader ─► frames? yield   │
//!            │  no progress anywhere ──► sleep ~1ms          │
//!            └───────────────────────────────────────────────┘
//!                 ▲                │
//!     enqueue(conn, frame)        ▼
//!      (bounded outboxes)   MuxEvent::{Frame, Closed}
//! ```
//!
//! Every socket is nonblocking; the loop makes one write pass and one
//! read pass per iteration and reports progress so the caller can
//! decide when to sleep and when to top off outboxes. Incoming bytes
//! accumulate in a per-connection [`FrameReader`] — an incremental
//! version of [`frame::read_frame`] with the identical validation
//! order (magic, version, length cap, CRC) and the identical typed
//! errors. A connection that fails — dead socket, malformed frame —
//! is closed and reported as [`MuxEvent::Closed`]; the other
//! connections are untouched.
//!
//! Memory contract: the caller bounds outboxes (top off below a
//! watermark instead of enqueueing the whole round up front) and the
//! reader only ever buffers partial frames, so coordinator memory is
//! constant in fleet size — uploads stream out of here straight into
//! the round's `StreamAccumulator`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::frame::{crc32, MAGIC, MAX_PAYLOAD, PROTO_VERSION};
use super::ProtoError;

/// Incremental frame parser: feed bytes with [`FrameReader::push`],
/// drain complete frames with [`FrameReader::next_frame`]. Mirrors
/// `frame::read_frame` exactly — same validation order, same typed
/// errors — but never blocks: a partial frame simply waits for more
/// bytes.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

/// Frame header size on the wire: magic(4) + version(2) + type(1) +
/// len(4).
const HEADER_LEN: usize = 11;

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader { buf: Vec::new() }
    }

    /// Bytes buffered but not yet consumed as frames (partial frame).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Append freshly read bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Try to parse one complete frame out of the buffer. `Ok(None)`
    /// means "need more bytes"; an error means the stream is
    /// unrecoverably out of sync (frame boundaries are lost) and the
    /// connection should be dropped.
    pub fn next_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>, ProtoError> {
        let Some(header) = self.buf.get(..HEADER_LEN) else {
            return Ok(None);
        };
        let short = || ProtoError::Truncated { what: "frame header" };
        let word = |i: usize| -> Result<u32, ProtoError> {
            let b: [u8; 4] = header
                .get(i..i + 4)
                .and_then(|s| s.try_into().ok())
                .ok_or_else(short)?;
            Ok(u32::from_le_bytes(b))
        };
        let magic = word(0)?;
        if magic != MAGIC {
            return Err(ProtoError::BadMagic { got: magic });
        }
        let vb: [u8; 2] = header
            .get(4..6)
            .and_then(|s| s.try_into().ok())
            .ok_or_else(short)?;
        let version = u16::from_le_bytes(vb);
        if version != PROTO_VERSION {
            return Err(ProtoError::BadVersion { got: version });
        }
        let msg_type = *header.get(6).ok_or_else(short)?;
        let len = word(7)?;
        if len > MAX_PAYLOAD {
            return Err(ProtoError::Oversized { len, max: MAX_PAYLOAD });
        }
        let total = HEADER_LEN + len as usize + 4;
        let Some(frame) = self.buf.get(..total) else {
            return Ok(None);
        };
        let payload_end = HEADER_LEN + len as usize;
        let payload = frame
            .get(HEADER_LEN..payload_end)
            .ok_or_else(short)?
            .to_vec();
        let cb: [u8; 4] = frame
            .get(payload_end..total)
            .and_then(|s| s.try_into().ok())
            .ok_or(ProtoError::Truncated { what: "frame checksum" })?;
        let stored = u32::from_le_bytes(cb);
        let computed = crc32(&payload);
        if stored != computed {
            return Err(ProtoError::CrcMismatch { stored, computed });
        }
        self.buf.drain(..total);
        Ok(Some((msg_type, payload)))
    }
}

/// What one readiness pass surfaced.
pub enum MuxEvent {
    /// A complete, validated frame from connection `conn`.
    Frame {
        conn: usize,
        msg_type: u8,
        payload: Vec<u8>,
    },
    /// Connection `conn` is gone: socket error, clean close mid-round,
    /// or a protocol violation that lost frame sync. The mux has
    /// already closed it; the caller decides what its in-flight
    /// clients become.
    Closed { conn: usize, error: ProtoError },
}

struct MuxConn {
    stream: TcpStream,
    reader: FrameReader,
    /// Pending outbound bytes; `sent` is the drained prefix. Compacted
    /// once fully flushed so a long round cannot grow it unboundedly.
    outbox: Vec<u8>,
    sent: usize,
    open: bool,
    /// Wall instant of the last successful read — drives the caller's
    /// inactivity timeout, never recorded in any deterministic output.
    last_rx: Instant,
}

/// The readiness loop's state: every worker connection, nonblocking.
pub struct Mux {
    conns: Vec<MuxConn>,
    read_buf: Vec<u8>,
}

impl Mux {
    /// Take ownership of handshaken streams and switch them to
    /// nonblocking mode. Connection indices are positions in `streams`.
    pub fn new(streams: Vec<TcpStream>) -> std::io::Result<Mux> {
        let now = crate::util::timer::now();
        let mut conns = Vec::with_capacity(streams.len());
        for stream in streams {
            stream.set_nonblocking(true)?;
            stream.set_nodelay(true)?;
            conns.push(MuxConn {
                stream,
                reader: FrameReader::new(),
                outbox: Vec::new(),
                sent: 0,
                open: true,
                last_rx: now,
            });
        }
        Ok(Mux {
            conns,
            read_buf: vec![0u8; 64 << 10],
        })
    }

    pub fn len(&self) -> usize {
        self.conns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conns.is_empty()
    }

    pub fn is_open(&self, conn: usize) -> bool {
        self.conns.get(conn).is_some_and(|c| c.open)
    }

    /// Bytes queued but not yet flushed on `conn` — the caller's
    /// backpressure signal (top off below a watermark).
    pub fn outbox_len(&self, conn: usize) -> usize {
        self.conns.get(conn).map_or(0, |c| c.outbox.len() - c.sent)
    }

    /// Queue already-framed bytes for `conn`. Silently ignored on a
    /// closed connection (the caller sees `Closed` and stops caring).
    pub fn enqueue(&mut self, conn: usize, frame: &[u8]) {
        if let Some(c) = self.conns.get_mut(conn) {
            if c.open {
                c.outbox.extend_from_slice(frame);
            }
        }
    }

    /// Reset the inactivity clock for `conn` — called when the caller
    /// hands it new work, so the timeout measures silence *since the
    /// last dispatch or read*, not since connection setup.
    pub fn mark_active(&mut self, conn: usize) {
        if let Some(c) = self.conns.get_mut(conn) {
            c.last_rx = crate::util::timer::now();
        }
    }

    /// How long `conn` has been silent (no bytes read, no
    /// `mark_active`). Closed/unknown connections report zero.
    pub fn idle_for(&self, conn: usize) -> Duration {
        match self.conns.get(conn) {
            Some(c) if c.open => c.last_rx.elapsed(),
            _ => Duration::ZERO,
        }
    }

    /// Close `conn` locally (protocol violation, timeout eviction).
    /// No further events will be reported for it.
    pub fn close(&mut self, conn: usize) {
        if let Some(c) = self.conns.get_mut(conn) {
            c.open = false;
            c.outbox.clear();
            c.sent = 0;
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Switch `conn` back to blocking mode and return the stream — the
    /// shutdown path writes its final frame synchronously.
    pub fn blocking_stream(&mut self, conn: usize) -> Option<&mut TcpStream> {
        let c = self.conns.get_mut(conn)?;
        if !c.open {
            return None;
        }
        c.stream.set_nonblocking(false).ok()?;
        Some(&mut c.stream)
    }

    /// One readiness pass: a write attempt and a read attempt on every
    /// open connection. Complete frames and closures are appended to
    /// `events`; returns true when any byte moved (the caller sleeps
    /// briefly when nothing does).
    pub fn poll(&mut self, events: &mut Vec<MuxEvent>) -> bool {
        let mut progress = false;
        for (i, c) in self.conns.iter_mut().enumerate() {
            if !c.open {
                continue;
            }

            // --- write pass: drain as much outbox as the socket takes
            while let Some(pending) = c.outbox.get(c.sent..).filter(|p| !p.is_empty()) {
                match c.stream.write(pending) {
                    Ok(0) => break,
                    Ok(n) => {
                        c.sent += n;
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        c.open = false;
                        let _ = c.stream.shutdown(std::net::Shutdown::Both);
                        events.push(MuxEvent::Closed { conn: i, error: ProtoError::Io(e) });
                        progress = true;
                        break;
                    }
                }
            }
            if !c.open {
                continue;
            }
            if c.sent == c.outbox.len() && c.sent > 0 {
                c.outbox.clear();
                c.sent = 0;
            }

            // --- read pass: pull whatever is ready, then parse
            loop {
                match c.stream.read(&mut self.read_buf) {
                    Ok(0) => {
                        c.open = false;
                        let _ = c.stream.shutdown(std::net::Shutdown::Both);
                        events.push(MuxEvent::Closed {
                            conn: i,
                            error: ProtoError::Truncated { what: "connection closed" },
                        });
                        progress = true;
                        break;
                    }
                    Ok(n) => {
                        progress = true;
                        c.reader.push(self.read_buf.get(..n).unwrap_or(&[]));
                        c.last_rx = crate::util::timer::now();
                        if n < self.read_buf.len() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        c.open = false;
                        let _ = c.stream.shutdown(std::net::Shutdown::Both);
                        events.push(MuxEvent::Closed { conn: i, error: ProtoError::Io(e) });
                        progress = true;
                        break;
                    }
                }
            }
            if !c.open {
                continue;
            }

            // --- parse pass: yield every complete frame buffered
            loop {
                match c.reader.next_frame() {
                    Ok(Some((msg_type, payload))) => {
                        progress = true;
                        events.push(MuxEvent::Frame { conn: i, msg_type, payload });
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // frame sync is lost: everything after this
                        // byte is garbage, so the connection dies
                        c.open = false;
                        let _ = c.stream.shutdown(std::net::Shutdown::Both);
                        events.push(MuxEvent::Closed { conn: i, error: e });
                        progress = true;
                        break;
                    }
                }
            }
        }
        progress
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::frame::encode_frame;

    #[test]
    fn reader_reassembles_frames_from_arbitrary_chunks() {
        let frames = [
            encode_frame(1, b"hello"),
            encode_frame(2, &[]),
            encode_frame(3, &vec![7u8; 10_000]),
        ];
        let wire: Vec<u8> = frames.iter().flatten().copied().collect();
        for chunk in [1usize, 2, 7, 11, 64, 4096] {
            let mut r = FrameReader::new();
            let mut got = Vec::new();
            for part in wire.chunks(chunk) {
                r.push(part);
                while let Some((ty, payload)) = r.next_frame().unwrap() {
                    got.push((ty, payload));
                }
            }
            assert_eq!(got.len(), 3, "chunk={chunk}");
            assert_eq!(got[0], (1, b"hello".to_vec()));
            assert_eq!(got[1], (2, Vec::new()));
            assert_eq!(got[2].0, 3);
            assert_eq!(got[2].1.len(), 10_000);
            assert_eq!(r.pending(), 0);
        }
    }

    #[test]
    fn reader_rejects_bad_magic() {
        let mut r = FrameReader::new();
        r.push(b"GARBAGE-NOT-A-FRAME");
        assert!(matches!(r.next_frame(), Err(ProtoError::BadMagic { .. })));
    }

    #[test]
    fn reader_rejects_corrupt_payload() {
        let mut frame = encode_frame(1, b"payload");
        frame[HEADER_LEN] ^= 0xFF; // flip a payload byte, CRC now wrong
        let mut r = FrameReader::new();
        r.push(&frame);
        assert!(matches!(r.next_frame(), Err(ProtoError::CrcMismatch { .. })));
    }

    #[test]
    fn reader_waits_on_partial_frames() {
        let frame = encode_frame(4, b"0123456789");
        let mut r = FrameReader::new();
        for &b in &frame[..frame.len() - 1] {
            r.push(&[b]);
            assert!(r.next_frame().unwrap().is_none());
        }
        r.push(&frame[frame.len() - 1..]);
        let (ty, payload) = r.next_frame().unwrap().unwrap();
        assert_eq!(ty, 4);
        assert_eq!(payload, b"0123456789");
    }
}
