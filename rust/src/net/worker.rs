//! The worker runtime behind `fedcompress worker --connect ADDR`.
//!
//! A worker is a client host: it connects to the coordinator, learns
//! at handshake which client ids it owns plus the full experiment
//! image (strategy name + config), and rebuilds everything else
//! locally — engine, data shards, strategy plugin, RNG streams — from
//! that image. Only models cross the wire, so a loopback run's bytes
//! and metrics match the in-process run exactly.
//!
//! Round loop: `RoundOpen` (centroid table + train flags), then one
//! `Download` per owned selected client — each answered with an
//! `Upload` before the next `Download` is read — then `RoundClose`.
//! `Shutdown` (or a clean EOF in its place) ends the process.

use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::baselines::registry::StrategyRegistry;
use crate::client::trainer::train_local;
use crate::clustering::CentroidState;
use crate::codec::{CodecCache, CodecRegistry};
use crate::config::FedConfig;
use crate::coordinator::server::{build_data, client_stream, run_rng, FederatedData};
use crate::coordinator::strategy::{FedStrategy, RoundContext, UploadInput};
use crate::info;
use crate::runtime::Engine;
use crate::util::rng::Rng;

use super::proto::{Download, Hello, Msg, RoundOpen, Upload};
use super::{ProtoError, PROTO_VERSION};

/// Connect with retry so `worker` can be launched before `serve`.
fn connect(addr: &str, patience: Duration) -> Result<TcpStream> {
    // fedlint:allow(no-wallclock-state) -- connect retry pacing only, never recorded
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if t0.elapsed() < patience => {
                std::thread::sleep(Duration::from_millis(200));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connecting to coordinator at {addr}"))
            }
        }
    }
}

/// Run one worker process to completion: handshake, serve rounds until
/// `Shutdown`. Returns the number of uploads produced. Decodes
/// dispatches against the built-in codec registry; embedders with
/// custom codecs use [`run_worker_with_codecs`].
pub fn run_worker(addr: &str, artifacts: &Path) -> Result<usize> {
    run_worker_with_codecs(addr, artifacts, CodecRegistry::builtin())
}

/// [`run_worker`] with a caller-supplied codec registry, so custom
/// codecs registered on both ends cross the TCP transport end-to-end.
pub fn run_worker_with_codecs(
    addr: &str,
    artifacts: &Path,
    codecs: CodecRegistry,
) -> Result<usize> {
    let codecs = CodecCache::new(codecs);
    let stream = connect(addr, Duration::from_secs(10))?;
    stream.set_nodelay(true).ok();
    Msg::Hello(Hello {
        proto_version: PROTO_VERSION,
    })
    .write_to(&mut &stream)?;
    let ack = match Msg::read_from(&mut &stream)? {
        Msg::HelloAck(a) => a,
        other => bail!("expected HelloAck, coordinator sent {}", other.kind()),
    };
    let cfg = *ack.cfg;
    cfg.validate().context("coordinator sent an invalid config")?;
    let owned: Vec<usize> = ack.clients.iter().map(|&c| c as usize).collect();
    info!(
        "worker {}/{}: strategy={} dataset={} clients={owned:?}",
        ack.worker, ack.workers, ack.strategy, cfg.dataset
    );

    // rebuild the experiment locally from the config image
    let strategy = StrategyRegistry::builtin().build(&ack.strategy, &cfg)?;
    let engine = Engine::load(artifacts)?;
    let data = build_data(&engine, &cfg)?;
    let base = run_rng(&cfg);

    let mut uploads = 0usize;
    loop {
        match Msg::read_from(&mut &stream) {
            Ok(Msg::RoundOpen(open)) => {
                uploads += serve_round(
                    &stream,
                    &open,
                    &engine,
                    &cfg,
                    &data,
                    strategy.as_ref(),
                    &base,
                    &owned,
                    &codecs,
                )?;
            }
            Ok(Msg::RoundClose { .. }) => continue,
            Ok(Msg::Shutdown) => break,
            // EOF exactly at a frame boundary is a coordinator that hung
            // up cleanly-enough (ctrl-C between rounds); EOF *inside* a
            // frame is a mid-write crash and stays an error
            Err(ProtoError::Truncated {
                what: "frame header",
            }) => break,
            Ok(other) => bail!("unexpected {} outside a round", other.kind()),
            Err(e) => return Err(e.into()),
        }
    }
    info!("worker {}: done after {uploads} uploads", ack.worker);
    Ok(uploads)
}

/// Handle one `RoundOpen`: `n_downloads` train/encode/upload cycles.
#[allow(clippy::too_many_arguments)]
fn serve_round(
    stream: &TcpStream,
    open: &RoundOpen,
    engine: &Engine,
    cfg: &FedConfig,
    data: &FederatedData,
    strategy: &dyn FedStrategy,
    base: &Rng,
    owned: &[usize],
    codecs: &CodecCache,
) -> Result<usize> {
    let round = open.round as usize;
    // the server centroid table: mask rebuilt from the active count
    // (the prefix invariant the checkpoint format also relies on)
    let c_max = open.mu.len();
    let mut mask = vec![0.0f32; c_max];
    for m in mask.iter_mut().take(open.active as usize) {
        *m = 1.0;
    }
    let centroids = CentroidState {
        mu: open.mu.clone(),
        mask,
        c_max,
        active: open.active as usize,
    };
    let ctx = RoundContext {
        round,
        cfg,
        base,
        compressing: open.compressing,
        down_compressed: open.down_compressed,
    };

    for _ in 0..open.n_downloads {
        let dl: Download = match Msg::read_from(&mut &*stream)? {
            Msg::Download(d) => d,
            other => bail!("expected Download in round {round}, got {}", other.kind()),
        };
        anyhow::ensure!(
            dl.round as usize == round,
            "download for round {} inside round {round}",
            dl.round
        );
        let k = dl.client as usize;
        anyhow::ensure!(
            owned.contains(&k),
            "download for client {k} this worker does not own"
        );
        let theta = super::proto::decode_blob(codecs, &dl.spec, &dl.payload)?;

        let mut client_rng = base.fork(client_stream(round, cfg.clients, k));
        let outcome = train_local(
            engine,
            cfg,
            &data.labeled[k],
            &data.unlabeled[k],
            &theta,
            &centroids,
            open.weight_clustering,
            &mut client_rng,
        )?;
        // the client's learned centroids ride along for the snap
        let mut client_cents = centroids.clone();
        client_cents.mu.clone_from(&outcome.mu);
        let blob = strategy.encode_upload(
            &ctx,
            &UploadInput {
                client: k,
                theta: &outcome.theta,
                centroids: &client_cents,
            },
            &mut client_rng,
        )?;
        blob.ensure_payload()?;
        // zero-copy send: sidecars as the head, the encoded blob as the
        // streamed tail. Any codec the coordinator's registry resolves
        // crosses — the Opaque in-process-only carve-out is gone.
        super::proto::write_upload(
            &mut &*stream,
            &Upload {
                round: round as u32,
                client: k as u32,
                score: outcome.score,
                n: outcome.n as u32,
                mean_ce: outcome.mean_ce,
                mu: outcome.mu,
                stages: blob.stage_bytes,
                spec: blob.spec,
                payload: blob.payload,
            },
        )?;
    }
    info!("worker: round {round} served {} clients", open.n_downloads);
    Ok(open.n_downloads as usize)
}
