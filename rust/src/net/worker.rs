//! The worker runtime behind `fedcompress worker --connect ADDR`.
//!
//! A worker is a client host: it connects to the coordinator, learns
//! at handshake which client ids it owns plus the full experiment
//! image (strategy name + config), and rebuilds everything else
//! locally — engine, data shards, strategy plugin, RNG streams — from
//! that image. Only models cross the wire, so a loopback run's bytes
//! and metrics match the in-process run exactly.
//!
//! Round loop: `RoundOpen` (centroid table + train flags), then one
//! `Download` per owned selected client, then `RoundClose`. A leaf
//! worker answers every `Download` with an `Upload`; an edge
//! aggregator (`--edge-of N`) instead folds its sub-fleet's updates
//! locally — applying the same pure simulated deadline clock the
//! coordinator uses, so both tiers always agree on who was cut — and
//! answers the whole round with a single `EdgeUpload`: the
//! sample-weighted partial FedAvg plus per-member sidecars.
//! `Shutdown` (or a clean EOF in its place) ends the process.

use std::net::TcpStream;
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::baselines::registry::StrategyRegistry;
use crate::baselines::wire::WireBlob;
use crate::client::trainer::{train_local, ClientOutcome};
use crate::clustering::CentroidState;
use crate::codec::{CodecCache, CodecRegistry};
use crate::config::FedConfig;
use crate::coordinator::accumulate::{AggError, AggFold, FedAvgFold};
use crate::coordinator::server::{
    build_data, client_stream, run_rng, FederatedData, TRAIN_FLOPS_FACTOR,
};
use crate::coordinator::strategy::{ClientUpdate, FedStrategy, RoundContext, UploadInput};
use crate::info;
use crate::models::flops::total_flops;
use crate::runtime::Engine;
use crate::sim::FleetSim;
use crate::util::rng::Rng;

use super::proto::{
    Download, EdgeCutWire, EdgeMemberWire, EdgeUpload, Hello, Msg, RoundOpen, Upload,
};
use super::{ProtoError, PROTO_VERSION};

/// Connect with retry so `worker` can be launched before `serve`.
fn connect(addr: &str, patience: Duration) -> Result<TcpStream> {
    // connect retry pacing only, never recorded; clock from the
    // sanctioned timer
    let t0 = crate::util::timer::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if t0.elapsed() < patience => {
                std::thread::sleep(Duration::from_millis(200));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connecting to coordinator at {addr}"))
            }
        }
    }
}

/// Run one worker process to completion: handshake, serve rounds until
/// `Shutdown`. Returns the number of uploads produced. Decodes
/// dispatches against the built-in codec registry; embedders with
/// custom codecs use [`run_worker_with_codecs`].
pub fn run_worker(addr: &str, artifacts: &Path) -> Result<usize> {
    run_worker_opts(addr, artifacts, CodecRegistry::builtin(), 0)
}

/// [`run_worker`] with a caller-supplied codec registry, so custom
/// codecs registered on both ends cross the TCP transport end-to-end.
pub fn run_worker_with_codecs(
    addr: &str,
    artifacts: &Path,
    codecs: CodecRegistry,
) -> Result<usize> {
    run_worker_opts(addr, artifacts, codecs, 0)
}

/// The full-control worker entry point. `edge_of = 0` is a leaf worker
/// (one `Upload` per client); `edge_of = N > 0` announces an edge
/// aggregator that locally folds a sub-fleet of up to `N` clients per
/// round and ships one pre-aggregated `EdgeUpload` upstream.
pub fn run_worker_opts(
    addr: &str,
    artifacts: &Path,
    codecs: CodecRegistry,
    edge_of: usize,
) -> Result<usize> {
    let codecs = CodecCache::new(codecs);
    let stream = connect(addr, Duration::from_secs(10))?;
    stream.set_nodelay(true).ok();
    Msg::Hello(Hello {
        proto_version: PROTO_VERSION,
        edge_of: edge_of as u32,
    })
    .write_to(&mut &stream)?;
    let ack = match Msg::read_from(&mut &stream)? {
        Msg::HelloAck(a) => a,
        other => bail!("expected HelloAck, coordinator sent {}", other.kind()),
    };
    let cfg = *ack.cfg;
    cfg.validate().context("coordinator sent an invalid config")?;
    let owned: Vec<usize> = ack.clients.iter().map(|&c| c as usize).collect();
    if edge_of > 0 {
        anyhow::ensure!(
            owned.len() <= edge_of,
            "coordinator granted {} clients, over this worker's --edge-of {edge_of} capacity",
            owned.len()
        );
    }
    info!(
        "worker {}/{}: strategy={} dataset={} edge_of={edge_of} clients={owned:?}",
        ack.worker, ack.workers, ack.strategy, cfg.dataset
    );

    // rebuild the experiment locally from the config image
    let strategy = StrategyRegistry::builtin().build(&ack.strategy, &cfg)?;
    let engine = Engine::load(artifacts)?;
    let data = build_data(&engine, &cfg)?;
    let base = run_rng(&cfg);
    // an edge aggregator re-derives the coordinator's simulated
    // deadline clock from the config image: `FaultSchedule::fate` and
    // `client_time_s` are pure in (round, client), so both tiers reach
    // the same cut verdicts without exchanging any clock state
    let edge_sim = if edge_of > 0 {
        let spec = &engine.manifest.dataset(&cfg.dataset)?.spec;
        Some(FleetSim::new(
            &cfg.fleet,
            cfg.clients,
            cfg.seed,
            TRAIN_FLOPS_FACTOR * total_flops(spec) as f64,
        ))
    } else {
        None
    };

    let mut uploads = 0usize;
    loop {
        match Msg::read_from(&mut &stream) {
            Ok(Msg::RoundOpen(open)) => {
                let env = ServeEnv {
                    engine: &engine,
                    cfg: &cfg,
                    data: &data,
                    strategy: strategy.as_ref(),
                    base: &base,
                    owned: &owned,
                    codecs: &codecs,
                };
                uploads += match &edge_sim {
                    None => serve_round(&stream, &open, &env)?,
                    Some(sim) => serve_round_edge(&stream, &open, &env, sim)?,
                };
            }
            Ok(Msg::RoundClose { .. }) => continue,
            Ok(Msg::Shutdown) => break,
            // EOF exactly at a frame boundary is a coordinator that hung
            // up cleanly-enough (ctrl-C between rounds); EOF *inside* a
            // frame is a mid-write crash and stays an error
            Err(ProtoError::Truncated {
                what: "frame header",
            }) => break,
            Ok(other) => bail!("unexpected {} outside a round", other.kind()),
            Err(e) => return Err(e.into()),
        }
    }
    info!("worker {}: done after {uploads} uploads", ack.worker);
    Ok(uploads)
}

/// The per-round context a worker serves from — everything rebuilt at
/// handshake, bundled so the round loops stay readable.
struct ServeEnv<'a> {
    engine: &'a Engine,
    cfg: &'a FedConfig,
    data: &'a FederatedData,
    strategy: &'a dyn FedStrategy,
    base: &'a Rng,
    owned: &'a [usize],
    codecs: &'a CodecCache,
}

/// Rebuild the server centroid table from a `RoundOpen`: mask rebuilt
/// from the active count (the prefix invariant the checkpoint format
/// also relies on).
fn open_centroids(open: &RoundOpen) -> CentroidState {
    let c_max = open.mu.len();
    let mut mask = vec![0.0f32; c_max];
    for m in mask.iter_mut().take(open.active as usize) {
        *m = 1.0;
    }
    CentroidState {
        mu: open.mu.clone(),
        mask,
        c_max,
        active: open.active as usize,
    }
}

/// Read one `Download`, train its client, and encode the upload blob —
/// the per-client work both the leaf and edge paths share.
fn train_download(
    stream: &TcpStream,
    open: &RoundOpen,
    env: &ServeEnv<'_>,
    centroids: &CentroidState,
    ctx: &RoundContext<'_>,
) -> Result<(usize, Download, ClientOutcome, WireBlob)> {
    let round = open.round as usize;
    let dl: Download = match Msg::read_from(&mut &*stream)? {
        Msg::Download(d) => d,
        other => bail!("expected Download in round {round}, got {}", other.kind()),
    };
    anyhow::ensure!(
        dl.round as usize == round,
        "download for round {} inside round {round}",
        dl.round
    );
    let k = dl.client as usize;
    anyhow::ensure!(
        env.owned.contains(&k),
        "download for client {k} this worker does not own"
    );
    let theta = super::proto::decode_blob(env.codecs, &dl.spec, &dl.payload)?;

    let mut client_rng = env.base.fork(client_stream(round, env.cfg.clients, k));
    let outcome = train_local(
        env.engine,
        env.cfg,
        &env.data.labeled[k],
        &env.data.unlabeled[k],
        &theta,
        centroids,
        open.weight_clustering,
        &mut client_rng,
    )?;
    // the client's learned centroids ride along for the snap
    let mut client_cents = centroids.clone();
    client_cents.mu.clone_from(&outcome.mu);
    let blob = env.strategy.encode_upload(
        ctx,
        &UploadInput {
            client: k,
            theta: &outcome.theta,
            centroids: &client_cents,
        },
        &mut client_rng,
    )?;
    blob.ensure_payload()?;
    Ok((k, dl, outcome, blob))
}

/// Leaf round: `n_downloads` train/encode/upload cycles.
fn serve_round(stream: &TcpStream, open: &RoundOpen, env: &ServeEnv<'_>) -> Result<usize> {
    let round = open.round as usize;
    let centroids = open_centroids(open);
    let ctx = RoundContext {
        round,
        cfg: env.cfg,
        base: env.base,
        compressing: open.compressing,
        down_compressed: open.down_compressed,
    };

    for _ in 0..open.n_downloads {
        let (k, _dl, outcome, blob) = train_download(stream, open, env, &centroids, &ctx)?;
        // zero-copy send: sidecars as the head, the encoded blob as the
        // streamed tail. Any codec the coordinator's registry resolves
        // crosses — the Opaque in-process-only carve-out is gone.
        super::proto::write_upload(
            &mut &*stream,
            &Upload {
                round: round as u32,
                client: k as u32,
                score: outcome.score,
                n: outcome.n as u32,
                mean_ce: outcome.mean_ce,
                mu: outcome.mu,
                stages: blob.stage_bytes,
                spec: blob.spec,
                payload: blob.payload,
            },
        )?;
    }
    info!("worker: round {round} served {} clients", open.n_downloads);
    Ok(open.n_downloads as usize)
}

/// Edge round: train every sub-fleet member, apply the simulated
/// deadline locally, fold the survivors into one sample-weighted
/// partial FedAvg, and ship a single `EdgeUpload` upstream. Cut
/// members are reported with the upload bytes they *would* have sent,
/// so the coordinator re-derives the identical verdict from its own
/// clock and keeps its ledger flat-fleet-comparable.
fn serve_round_edge(
    stream: &TcpStream,
    open: &RoundOpen,
    env: &ServeEnv<'_>,
    sim: &FleetSim,
) -> Result<usize> {
    let round = open.round as usize;
    let centroids = open_centroids(open);
    let ctx = RoundContext {
        round,
        cfg: env.cfg,
        base: env.base,
        compressing: open.compressing,
        down_compressed: open.down_compressed,
    };

    let mut fold: Box<dyn AggFold> = Box::new(FedAvgFold::new());
    let mut members: Vec<EdgeMemberWire> = Vec::new();
    let mut cut: Vec<EdgeCutWire> = Vec::new();
    let mut params = 0usize;
    for _ in 0..open.n_downloads {
        let (k, dl, outcome, blob) = train_download(stream, open, env, &centroids, &ctx)?;
        params = blob.theta.len();
        // the same pure clock the coordinator runs: down is the shared
        // dispatch payload, up is what this member's upload would cost
        let sim_s = sim.client_time_s(
            k,
            dl.payload.len(),
            blob.bytes,
            env.data.labeled[k].len(),
            env.cfg.local_epochs,
            sim.fate(round, k).slowdown(),
        );
        if sim.clock().over_deadline(sim_s) {
            cut.push(EdgeCutWire {
                client: k as u32,
                up_bytes: blob.bytes as u64,
            });
            continue;
        }
        fold.fold(&ClientUpdate {
            client: k,
            theta: blob.theta,
            mu: outcome.mu,
            score: outcome.score,
            n: outcome.n,
        })
        .map_err(|e| anyhow::anyhow!("edge fold: {e}"))?;
        members.push(EdgeMemberWire {
            client: k as u32,
            n: outcome.n as u32,
            up_bytes: blob.bytes as u64,
            score: outcome.score,
            mean_ce: outcome.mean_ce,
        });
    }

    let (total_n, score, mu, payload) = if members.is_empty() {
        // every member cut: the coordinator only needs the cut report
        (0u64, 0.0f64, Vec::new(), Vec::new())
    } else {
        match fold.finish() {
            Ok(agg) => {
                let payload: Vec<u8> = agg.theta.iter().flat_map(|v| v.to_le_bytes()).collect();
                (agg.total_n as u64, agg.score, agg.mu, payload)
            }
            // surviving members with zero total sample weight: ship a
            // zero vector with zero weight — it folds to nothing
            Err(AggError::ZeroWeight) => (
                0u64,
                0.0f64,
                vec![0.0f32; open.mu.len()],
                vec![0u8; 4 * params],
            ),
            Err(e) => bail!("edge fold finish: {e}"),
        }
    };
    let survived = members.len();
    Msg::EdgeUpload(EdgeUpload {
        round: round as u32,
        total_n,
        score,
        members,
        cut,
        mu,
        payload,
    })
    .write_to(&mut &*stream)?;
    info!(
        "worker: round {round} edge-folded {survived}/{} clients",
        open.n_downloads
    );
    Ok(usize::from(open.n_downloads > 0))
}
