//! Coordinator-side TCP transport: accept worker connections, grant
//! deterministic client ids at handshake, dispatch each round's
//! downloads concurrently, and collect uploads under per-client
//! timeouts.
//!
//! Client ownership: worker `j` (by arrival order) of `W` hosts every
//! client `k` with `k % W == j`. The grant travels in `HelloAck`
//! together with the strategy name and the full config image, so a
//! worker rebuilds the exact experiment (data shards, RNG streams,
//! strategy plugin) locally — only models cross the wire.
//!
//! Fault surface: a sim-fated drop is never dispatched (mirroring the
//! in-process backend bit-for-bit); a dead or protocol-violating
//! worker turns its remaining clients into `Dropped(BeforeUpload)` and
//! is evicted for the rest of the run; a read timeout turns the
//! worker's outstanding clients into `TimedOut` (the driver logs
//! `Event::Deadline`) and also evicts it — a stream abandoned
//! mid-frame cannot be resynchronized. Real stragglers therefore feed
//! exactly the fault machinery the simulator models.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::codec::{CodecCache, CodecRegistry};
use crate::config::FedConfig;
use crate::coordinator::events::DropPhase;
use crate::coordinator::strategy::FedStrategy;
use crate::sim::ClientFate;
use crate::util::threadpool::parallel_map;

use super::proto::{self, HelloAck, Msg, RoundOpen, Upload};
use super::transport::{
    ClientResult, Participant, ReceivedUpload, RoundEnv, RoundSpec, Transport, TransportKind,
};

/// A bound listener that has not yet completed its handshakes. Split
/// from [`TcpTransport`] so callers (and the loopback tests) can learn
/// the actual address — e.g. after binding port 0 — before any worker
/// connects.
pub struct TcpServer {
    listener: TcpListener,
    expected_workers: usize,
    cfg: FedConfig,
    strategy: String,
    timeout: Option<Duration>,
    codecs: CodecRegistry,
}

impl TcpServer {
    /// Bind the coordinator socket. `timeout` bounds each per-client
    /// upload wait (`None` = wait forever; real deployments want a
    /// bound). Uploads decode against the built-in codec registry;
    /// embedders with custom codecs use [`TcpServer::bind_with_codecs`].
    pub fn bind(
        addr: &str,
        expected_workers: usize,
        cfg: &FedConfig,
        strategy: &str,
        timeout: Option<Duration>,
    ) -> Result<TcpServer> {
        TcpServer::bind_with_codecs(
            addr,
            expected_workers,
            cfg,
            strategy,
            timeout,
            CodecRegistry::builtin(),
        )
    }

    /// [`TcpServer::bind`] with a caller-supplied codec registry, so
    /// custom codecs registered on both ends cross the transport.
    pub fn bind_with_codecs(
        addr: &str,
        expected_workers: usize,
        cfg: &FedConfig,
        strategy: &str,
        timeout: Option<Duration>,
        codecs: CodecRegistry,
    ) -> Result<TcpServer> {
        anyhow::ensure!(expected_workers > 0, "need at least one worker");
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding coordinator on {addr}"))?;
        Ok(TcpServer {
            listener,
            expected_workers,
            cfg: cfg.clone(),
            strategy: strategy.to_string(),
            timeout,
            codecs,
        })
    }

    /// The bound address (the port is real even when bound as `:0`).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept `expected_workers` connections, handshake each, and
    /// return the ready transport. Worker `j` by arrival order hosts
    /// clients `{k : k % W == j}`.
    pub fn accept_workers(self) -> Result<TcpTransport> {
        let w = self.expected_workers;
        let mut conns = Vec::with_capacity(w);
        let mut control_bytes = 0usize;
        for j in 0..w {
            let (stream, peer) = self
                .listener
                .accept()
                .with_context(|| format!("accepting worker {j}/{w}"))?;
            stream.set_nodelay(true).ok();
            // a connection that sends nothing (port scanner, stalled
            // peer) must not wedge startup forever
            stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
            let hello = Msg::read_from(&mut &stream)
                .map_err(|e| anyhow::anyhow!("handshake with {peer}: {e}"))?;
            stream.set_read_timeout(None).ok();
            let h = match hello {
                Msg::Hello(h) => h,
                other => {
                    anyhow::bail!("worker {peer} opened with {} instead of Hello", other.kind())
                }
            };
            control_bytes += Msg::Hello(h.clone()).framed_len();
            let clients: Vec<u32> = (0..self.cfg.clients)
                .filter(|k| k % w == j)
                .map(|k| k as u32)
                .collect();
            let ack = Msg::HelloAck(HelloAck {
                worker: j as u32,
                workers: w as u32,
                clients: clients.clone(),
                strategy: self.strategy.clone(),
                cfg: Box::new(self.cfg.clone()),
            });
            control_bytes += ack.write_to(&mut &stream)?;
            crate::info!(
                "worker {j}/{w} connected from {peer} (proto v{}, {} clients)",
                h.proto_version,
                clients.len()
            );
            conns.push(WorkerConn {
                stream,
                alive: true,
            });
        }
        Ok(TcpTransport {
            conns,
            workers: w,
            timeout: self.timeout,
            control_bytes,
            codecs: CodecCache::new(self.codecs),
        })
    }
}

struct WorkerConn {
    stream: TcpStream,
    alive: bool,
}

/// The networked backend: one live connection per worker process.
pub struct TcpTransport {
    conns: Vec<WorkerConn>,
    workers: usize,
    timeout: Option<Duration>,
    /// Handshake, round-control, centroid-sidecar, codec-header, and
    /// stage-sidecar bytes — the wire traffic the per-client ledger
    /// does not attribute.
    control_bytes: usize,
    /// Spec -> pipeline, shared across rounds so stateful codecs
    /// (`delta`) keep their per-stream decode state.
    codecs: CodecCache,
}

/// What one worker's collection loop produced, per slot.
enum SlotOutcome {
    Upload(Box<ReceivedUpload>),
    TimedOut(f64),
    Dead,
}

/// One worker's whole-round result: per-slot outcomes, control bytes
/// spent, and whether the connection is still usable.
type WorkerRound = (Vec<(usize, SlotOutcome)>, usize, bool);

impl TcpTransport {
    /// Total control-plane bytes so far (both directions).
    pub fn control_bytes(&self) -> usize {
        self.control_bytes
    }

    /// Workers still answering.
    pub fn alive_workers(&self) -> usize {
        self.conns.iter().filter(|c| c.alive).count()
    }

    /// Dispatch + collect against one worker. Returns the per-slot
    /// outcomes plus the control bytes this exchange cost.
    fn round_with_worker(
        &self,
        conn: &WorkerConn,
        spec: &RoundSpec<'_>,
        expected_p: usize,
        owned: &[(usize, Participant)],
    ) -> (Vec<(usize, SlotOutcome)>, usize) {
        let mut control = 0usize;
        let mut out: Vec<(usize, SlotOutcome)> = Vec::with_capacity(owned.len());
        let stream = &conn.stream;

        // --- dispatch / collect, stop-and-wait ----------------------------
        // Strictly alternate: send one Download, then block for its
        // Upload. At any instant only one direction of the socket is
        // transferring (each side fully drains its read before it
        // writes), so neither peer can wedge on a full socket buffer no
        // matter how large the model is. Overlap comes from run_round's
        // one-thread-per-worker fan-out, not from pipelining one stream.
        let open = Msg::RoundOpen(RoundOpen {
            round: spec.round as u32,
            n_downloads: owned.len() as u32,
            weight_clustering: spec.opts.weight_clustering,
            compressing: spec.compressing,
            down_compressed: spec.down_compressed,
            active: spec.centroids.active as u32,
            mu: spec.centroids.mu.clone(),
        });
        // RoundOpen is control traffic; Downloads are the ledgered data
        // plane (the driver records framed_down per dispatch)
        match open.write_to(&mut &*stream) {
            Ok(n) => control += n,
            Err(e) => {
                crate::info!("worker send failed, evicting: {e}");
                let dead = owned.iter().map(|&(s, _)| (s, SlotOutcome::Dead)).collect();
                return (dead, control);
            }
        }

        let timeout_s = self.timeout.map(|d| d.as_secs_f64()).unwrap_or(0.0);
        let mut pending: Vec<(usize, Participant)> = owned.to_vec();
        for (_, part) in owned {
            // zero-copy dispatch: the shared round payload streams out
            // under this client's header. The self-describing codec
            // header beyond its 1-byte ledger baseline is control
            // traffic, like the centroid sidecar.
            control += proto::codec_header_surplus(&spec.down.spec);
            let sent = proto::write_download(
                &mut &*stream,
                spec.round as u32,
                part.client as u32,
                &spec.down.spec,
                &spec.down.payload,
            );
            if let Err(e) = sent {
                crate::info!("worker send failed, evicting: {e}");
                for &(slot, _) in &pending {
                    out.push((slot, SlotOutcome::Dead));
                }
                return (out, control);
            }
            let msg = match Msg::read_from(&mut &*stream) {
                Ok(m) => m,
                Err(e) if e.is_timeout() => {
                    // deadline fired: everything still outstanding is a
                    // straggler cut. The stream may be mid-frame now, so
                    // the worker is evicted (slots report TimedOut, the
                    // driver logs Event::Deadline).
                    crate::info!("worker timed out with {} uploads pending", pending.len());
                    for &(slot, _) in &pending {
                        out.push((slot, SlotOutcome::TimedOut(timeout_s)));
                    }
                    return (out, control);
                }
                Err(e) => {
                    crate::info!("worker read failed, evicting: {e}");
                    for &(slot, _) in &pending {
                        out.push((slot, SlotOutcome::Dead));
                    }
                    return (out, control);
                }
            };
            let up = match msg {
                Msg::Upload(u) => u,
                other => {
                    crate::info!("expected Upload, got {}; evicting worker", other.kind());
                    for &(slot, _) in &pending {
                        out.push((slot, SlotOutcome::Dead));
                    }
                    return (out, control);
                }
            };
            match self.receive_upload(up, spec.round, expected_p, &mut pending) {
                Ok((slot, received, sidecar)) => {
                    control += sidecar;
                    out.push((slot, SlotOutcome::Upload(received)));
                }
                Err(e) => {
                    crate::info!("rejecting upload: {e}; evicting worker");
                    for &(slot, _) in &pending {
                        out.push((slot, SlotOutcome::Dead));
                    }
                    return (out, control);
                }
            }
        }
        (out, control)
    }

    /// Validate one `Upload` against the round's outstanding set and
    /// decode it through the codec cache. Returns the slot, the
    /// decoded upload, and the control-plane size of its sidecars
    /// (centroid table + codec header surplus + stage bytes).
    fn receive_upload(
        &self,
        up: Upload,
        round: usize,
        expected_p: usize,
        pending: &mut Vec<(usize, Participant)>,
    ) -> Result<(usize, Box<ReceivedUpload>, usize)> {
        anyhow::ensure!(
            up.round as usize == round,
            "upload for round {} during round {round}",
            up.round
        );
        let client = up.client as usize;
        let pos = pending
            .iter()
            .position(|(_, p)| p.client == client)
            .with_context(|| format!("unexpected upload from client {client}"))?;
        let (slot, _) = pending.swap_remove(pos);
        let sidecar = 4
            + 4 * up.mu.len()
            + proto::codec_header_surplus(&up.spec)
            + proto::stages_sidecar_len(&up.stages);
        let blob = proto::blob_from_payload(&self.codecs, up.spec, up.stages, up.payload)?;
        blob.ensure_param_count(expected_p)?;
        Ok((
            slot,
            Box::new(ReceivedUpload {
                client,
                blob,
                mu: up.mu,
                score: up.score,
                n: up.n as usize,
                mean_ce: up.mean_ce,
            }),
            sidecar,
        ))
    }
}

impl Transport for TcpTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }

    fn run_round(
        &mut self,
        _env: &RoundEnv<'_>,
        _strategy: &dyn FedStrategy,
        spec: &RoundSpec<'_>,
    ) -> Result<Vec<ClientResult>> {
        let expected_p = spec.down.theta.len();
        // the wire carries the encoded payload; a blob whose payload
        // lies about its size would desynchronize the framed ledger.
        // (No opaque exemption: every blob carries a registry-
        // resolvable spec, so every blob can cross.)
        spec.down.ensure_payload()?;

        let mut results: Vec<Option<ClientResult>> =
            spec.participants.iter().map(|_| None).collect();

        // sim-fated drops never dispatch — identical to InProcess
        let mut per_worker: Vec<Vec<(usize, Participant)>> = vec![Vec::new(); self.workers];
        for (slot, part) in spec.participants.iter().enumerate() {
            match part.fate {
                ClientFate::DropBeforeTrain => {
                    results[slot] = Some(ClientResult::Dropped(DropPhase::BeforeTrain));
                }
                ClientFate::DropBeforeUpload => {
                    results[slot] = Some(ClientResult::Dropped(DropPhase::BeforeUpload));
                }
                ClientFate::Healthy { .. } => {
                    per_worker[part.client % self.workers].push((slot, *part));
                }
            }
        }

        if let Some(d) = self.timeout {
            for conn in &self.conns {
                // collect-phase read timeout; dispatch writes block
                conn.stream.set_read_timeout(Some(d)).ok();
            }
        }

        // one collection thread per worker connection: downloads go out
        // concurrently and slow workers do not serialize fast ones
        let per_worker_out: Vec<WorkerRound> =
            parallel_map(self.workers, self.workers, |j| {
                let conn = &self.conns[j];
                if per_worker[j].is_empty() {
                    return (Vec::new(), 0, conn.alive);
                }
                if !conn.alive {
                    let dead = per_worker[j]
                        .iter()
                        .map(|&(slot, _)| (slot, SlotOutcome::Dead))
                        .collect();
                    return (dead, 0, false);
                }
                let owned = &per_worker[j];
                let (out, control) = self.round_with_worker(conn, spec, expected_p, owned);
                let lost = out
                    .iter()
                    .any(|(_, o)| matches!(o, SlotOutcome::Dead | SlotOutcome::TimedOut(_)));
                (out, control, !lost)
            });

        let round_close = Msg::RoundClose {
            round: spec.round as u32,
        };
        for (j, (slots, control, still_alive)) in per_worker_out.into_iter().enumerate() {
            self.control_bytes += control;
            self.conns[j].alive = still_alive;
            if still_alive && !per_worker[j].is_empty() {
                match round_close.write_to(&mut &self.conns[j].stream) {
                    Ok(n) => self.control_bytes += n,
                    Err(_) => self.conns[j].alive = false,
                }
            }
            for (slot, outcome) in slots {
                results[slot] = Some(match outcome {
                    SlotOutcome::Upload(u) => ClientResult::Upload(u),
                    SlotOutcome::TimedOut(s) => ClientResult::TimedOut { elapsed_s: s },
                    SlotOutcome::Dead => ClientResult::Dropped(DropPhase::BeforeUpload),
                });
            }
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("every participant resolved"))
            .collect())
    }

    fn shutdown(&mut self) -> Result<()> {
        for conn in &mut self.conns {
            if conn.alive {
                if let Ok(n) = Msg::Shutdown.write_to(&mut &conn.stream) {
                    self.control_bytes += n;
                }
                conn.alive = false;
            }
        }
        Ok(())
    }
}
