//! Coordinator-side TCP transport: accept worker connections, grant
//! deterministic client ids at handshake, then drive every round
//! through the [`Mux`] readiness loop — all sockets nonblocking, all
//! serviced by the coordinator thread, uploads streaming into the
//! round's accumulator in whatever order they arrive.
//!
//! Client ownership: worker `j` (by successful-handshake order) of `W`
//! hosts every client `k` with `k % W == j`. The grant travels in
//! `HelloAck` together with the strategy name and the full config
//! image, so a worker rebuilds the exact experiment (data shards, RNG
//! streams, strategy plugin) locally — only models cross the wire.
//!
//! Accept robustness: a connection that fails its handshake — a port
//! scanner probing the socket, a stalled peer, a version-mismatched
//! build — is logged and dropped, and the listener keeps accepting
//! until `expected_workers` real workers are in. The handshake wait is
//! bounded by `FedConfig::handshake_timeout_s` (`--handshake-timeout-s`).
//!
//! Fault surface: a sim-fated drop is never dispatched (mirroring the
//! in-process backend bit-for-bit); a dead or protocol-violating
//! worker — including one shipping a ragged or otherwise hostile
//! upload — turns its outstanding clients into `Dropped(BeforeUpload)`
//! and is evicted for the rest of the run, while every other
//! connection's round continues undisturbed; a connection silent
//! beyond the round timeout turns its outstanding clients into
//! `TimedOut` (the driver logs `Event::Deadline`) and is evicted too.
//! Real stragglers therefore feed exactly the fault machinery the
//! simulator models.
//!
//! Edge tier: a worker that handshakes with `edge_of > 0` receives
//! its downloads like any other, but folds its sub-fleet locally and
//! answers with one `EdgeUpload` — the group's partial FedAvg plus
//! per-member sidecars — which `RoundIngest::resolve_edge` validates
//! against the coordinator's own deadline clock before committing.

use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::codec::{CodecCache, CodecRegistry};
use crate::config::FedConfig;
use crate::coordinator::events::DropPhase;
use crate::coordinator::server::{EdgeCutMember, EdgeMember, EdgePartial, RoundIngest};
use crate::coordinator::strategy::FedStrategy;
use crate::obs::stream::StreamEvent;
use crate::sim::ClientFate;

use super::mux::{Mux, MuxEvent};
use super::proto::{self, HelloAck, Msg, RoundOpen, Upload};
use super::transport::{
    ClientResult, Participant, ReceivedUpload, RoundEnv, RoundSpec, Transport, TransportKind,
};

/// Keep roughly this many unflushed bytes queued per connection before
/// materializing more `Download` frames — bounds coordinator memory at
/// (watermark + one frame) per connection instead of (round size).
const OUTBOX_WATERMARK: usize = 64 << 10;

/// A bound listener that has not yet completed its handshakes. Split
/// from [`TcpTransport`] so callers (and the loopback tests) can learn
/// the actual address — e.g. after binding port 0 — before any worker
/// connects.
pub struct TcpServer {
    listener: TcpListener,
    expected_workers: usize,
    cfg: FedConfig,
    strategy: String,
    timeout: Option<Duration>,
    codecs: CodecRegistry,
}

impl TcpServer {
    /// Bind the coordinator socket. `timeout` bounds each round's
    /// per-connection silence (`None` = wait forever; real deployments
    /// want a bound). Uploads decode against the built-in codec
    /// registry; embedders with custom codecs use
    /// [`TcpServer::bind_with_codecs`].
    pub fn bind(
        addr: &str,
        expected_workers: usize,
        cfg: &FedConfig,
        strategy: &str,
        timeout: Option<Duration>,
    ) -> Result<TcpServer> {
        TcpServer::bind_with_codecs(
            addr,
            expected_workers,
            cfg,
            strategy,
            timeout,
            CodecRegistry::builtin(),
        )
    }

    /// [`TcpServer::bind`] with a caller-supplied codec registry, so
    /// custom codecs registered on both ends cross the transport.
    pub fn bind_with_codecs(
        addr: &str,
        expected_workers: usize,
        cfg: &FedConfig,
        strategy: &str,
        timeout: Option<Duration>,
        codecs: CodecRegistry,
    ) -> Result<TcpServer> {
        anyhow::ensure!(expected_workers > 0, "need at least one worker");
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding coordinator on {addr}"))?;
        Ok(TcpServer {
            listener,
            expected_workers,
            cfg: cfg.clone(),
            strategy: strategy.to_string(),
            timeout,
            codecs,
        })
    }

    /// The bound address (the port is real even when bound as `:0`).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept connections until `expected_workers` have completed the
    /// handshake, then return the ready transport. Worker `j` by
    /// successful-handshake order hosts clients `{k : k % W == j}`.
    /// A connection that fails its handshake (port scanner, garbage
    /// bytes, stalled peer) is dropped and does not consume a worker
    /// slot — only a listener failure aborts startup.
    pub fn accept_workers(self) -> Result<TcpTransport> {
        let w = self.expected_workers;
        let handshake_timeout = if self.cfg.handshake_timeout_s > 0.0 {
            Some(Duration::from_secs_f64(self.cfg.handshake_timeout_s))
        } else {
            None
        };
        let mut streams = Vec::with_capacity(w);
        let mut edge = Vec::with_capacity(w);
        let mut control_bytes = 0usize;
        while streams.len() < w {
            let j = streams.len();
            let (stream, peer) = self
                .listener
                .accept()
                .with_context(|| format!("accepting worker {j}/{w}"))?;
            stream.set_nodelay(true).ok();
            // a connection that sends nothing (port scanner, stalled
            // peer) must not wedge startup forever
            stream.set_read_timeout(handshake_timeout).ok();
            let h = match Msg::read_from(&mut &stream) {
                Ok(Msg::Hello(h)) => h,
                Ok(other) => {
                    crate::info!(
                        "peer {peer} opened with {} instead of Hello; dropping it",
                        other.kind()
                    );
                    continue;
                }
                Err(e) => {
                    crate::info!("handshake with {peer} failed ({e}); dropping it");
                    continue;
                }
            };
            stream.set_read_timeout(None).ok();
            control_bytes += Msg::Hello(h.clone()).framed_len();
            let clients: Vec<u32> = (0..self.cfg.clients)
                .filter(|k| k % w == j)
                .map(|k| k as u32)
                .collect();
            let ack = Msg::HelloAck(HelloAck {
                worker: j as u32,
                workers: w as u32,
                clients: clients.clone(),
                strategy: self.strategy.clone(),
                cfg: Box::new(self.cfg.clone()),
            });
            match ack.write_to(&mut &stream) {
                Ok(n) => control_bytes += n,
                Err(e) => {
                    crate::info!("handshake ack to {peer} failed ({e}); dropping it");
                    continue;
                }
            }
            crate::info!(
                "worker {j}/{w} connected from {peer} (proto v{}, {} clients, edge_of={})",
                h.proto_version,
                clients.len(),
                h.edge_of
            );
            edge.push(h.edge_of as usize);
            streams.push(stream);
        }
        let mux = Mux::new(streams).context("switching worker sockets to nonblocking")?;
        Ok(TcpTransport {
            mux,
            edge,
            workers: w,
            timeout: self.timeout,
            control_bytes,
            codecs: CodecCache::new(self.codecs),
        })
    }
}

/// The networked backend: every worker connection multiplexed through
/// one readiness loop, uploads resolved on the round's ingest as they
/// arrive.
pub struct TcpTransport {
    mux: Mux,
    /// Per-connection edge-aggregator capacity (0 = leaf worker).
    edge: Vec<usize>,
    workers: usize,
    timeout: Option<Duration>,
    /// Handshake, round-control, centroid-sidecar, codec-header, and
    /// stage-sidecar bytes — the wire traffic the per-client ledger
    /// does not attribute. Edge blobs count here in full: the ledger
    /// records the *logical* member uploads instead, so CCR stays
    /// comparable with a flat fleet.
    control_bytes: usize,
    /// Spec -> pipeline, shared across rounds so stateful codecs
    /// (`delta`) keep their per-stream decode state.
    codecs: CodecCache,
}

impl TcpTransport {
    /// Total control-plane bytes so far (both directions).
    pub fn control_bytes(&self) -> usize {
        self.control_bytes
    }

    /// Workers still answering.
    pub fn alive_workers(&self) -> usize {
        (0..self.workers).filter(|&j| self.mux.is_open(j)).count()
    }

    /// Validate one `Upload` against the connection's outstanding set
    /// and decode it. On success the sidecar control bytes are
    /// accounted and `(slot, upload)` is returned; any `Err` is a
    /// protocol violation and the caller evicts the connection.
    fn accept_upload(
        &mut self,
        up: Upload,
        round: usize,
        expected_p: usize,
        expected_mu: usize,
        outstanding: &mut BTreeMap<usize, usize>,
    ) -> std::result::Result<(usize, Box<ReceivedUpload>), String> {
        if up.round as usize != round {
            return Err(format!("upload for round {} during round {round}", up.round));
        }
        let client = up.client as usize;
        let Some(slot) = outstanding.remove(&client) else {
            return Err(format!("unexpected upload from client {client}"));
        };
        if up.mu.len() != expected_mu {
            return Err(format!(
                "client {client} upload carries {} centroids, server table has {expected_mu}",
                up.mu.len()
            ));
        }
        let sidecar = 4
            + 4 * up.mu.len()
            + proto::codec_header_surplus(&up.spec)
            + proto::stages_sidecar_len(&up.stages);
        let blob = proto::blob_from_payload(&self.codecs, up.spec, up.stages, up.payload)
            .map_err(|e| format!("client {client} upload: {e}"))?;
        blob.ensure_param_count(expected_p)
            .map_err(|e| format!("client {client} upload: {e}"))?;
        self.control_bytes += sidecar;
        Ok((
            slot,
            Box::new(ReceivedUpload {
                client,
                blob,
                mu: up.mu,
                score: up.score,
                n: up.n as usize,
                mean_ce: up.mean_ce,
            }),
        ))
    }

    /// Validate one `EdgeUpload` against the connection's outstanding
    /// set and commit it on the ingest. Returns the number of slots it
    /// resolved; any `Err` is a protocol violation and the caller
    /// evicts the connection.
    fn accept_edge(
        edge_cap: usize,
        e: proto::EdgeUpload,
        round: usize,
        ingest: &mut RoundIngest<'_>,
        outstanding: &mut BTreeMap<usize, usize>,
    ) -> std::result::Result<usize, String> {
        if edge_cap == 0 {
            return Err("EdgeUpload from a worker that handshook as a leaf".to_string());
        }
        if e.round as usize != round {
            return Err(format!("edge upload for round {} during round {round}", e.round));
        }
        let reported = e.members.len() + e.cut.len();
        if reported > edge_cap {
            return Err(format!(
                "edge upload reports {reported} clients, over its edge_of={edge_cap} grant"
            ));
        }
        // ownership first: an edge worker may only speak for clients
        // this connection is still outstanding on — anything else
        // could poison another connection's slots
        for client in e
            .members
            .iter()
            .map(|m| m.client as usize)
            .chain(e.cut.iter().map(|c| c.client as usize))
        {
            if !outstanding.contains_key(&client) {
                return Err(format!(
                    "edge upload speaks for client {client} this connection does not own"
                ));
            }
        }
        let theta = e.theta().map_err(|err| format!("edge payload: {err}"))?;
        let partial = EdgePartial {
            theta,
            mu: e.mu,
            score: e.score,
            total_n: e.total_n as usize,
            members: e
                .members
                .iter()
                .map(|m| EdgeMember {
                    client: m.client as usize,
                    n: m.n as usize,
                    up_bytes: m.up_bytes as usize,
                    score: m.score,
                    mean_ce: m.mean_ce,
                })
                .collect(),
            cut: e
                .cut
                .iter()
                .map(|c| EdgeCutMember {
                    client: c.client as usize,
                    up_bytes: c.up_bytes as usize,
                })
                .collect(),
        };
        ingest.resolve_edge(partial)?;
        for client in e
            .members
            .iter()
            .map(|m| m.client as usize)
            .chain(e.cut.iter().map(|c| c.client as usize))
        {
            outstanding.remove(&client);
        }
        Ok(reported)
    }
}

/// Resolve every slot a dying connection still owes as
/// `Dropped(BeforeUpload)` and clear its queues. Returns how many
/// slots that was.
fn drop_outstanding(
    outstanding: &mut BTreeMap<usize, usize>,
    dispatch: &mut VecDeque<(usize, Participant)>,
    ingest: &mut RoundIngest<'_>,
) -> Result<usize> {
    let n = outstanding.len();
    for &slot in outstanding.values() {
        ingest.resolve(slot, ClientResult::Dropped(DropPhase::BeforeUpload))?;
    }
    outstanding.clear();
    dispatch.clear();
    Ok(n)
}

impl Transport for TcpTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }

    fn run_round(
        &mut self,
        _env: &RoundEnv<'_>,
        _strategy: &dyn FedStrategy,
        spec: &RoundSpec<'_>,
        ingest: &mut RoundIngest<'_>,
    ) -> Result<()> {
        let round = spec.round;
        let expected_p = spec.down.theta.len();
        let expected_mu = ingest.expected_mu();
        // the wire carries the encoded payload; a blob whose payload
        // lies about its size would desynchronize the framed ledger.
        spec.down.ensure_payload()?;

        // sim-fated drops never dispatch — identical to InProcess
        let mut owned: Vec<Vec<(usize, Participant)>> = vec![Vec::new(); self.workers];
        for (slot, part) in spec.participants.iter().enumerate() {
            match part.fate {
                ClientFate::DropBeforeTrain => {
                    ingest.resolve(slot, ClientResult::Dropped(DropPhase::BeforeTrain))?;
                }
                ClientFate::DropBeforeUpload => {
                    ingest.resolve(slot, ClientResult::Dropped(DropPhase::BeforeUpload))?;
                }
                ClientFate::Healthy { .. } => {
                    owned[part.client % self.workers].push((slot, *part));
                }
            }
        }

        // open the round on every live connection that has work
        let mut dispatch: Vec<VecDeque<(usize, Participant)>> =
            (0..self.workers).map(|_| VecDeque::new()).collect();
        let mut outstanding: Vec<BTreeMap<usize, usize>> =
            (0..self.workers).map(|_| BTreeMap::new()).collect();
        let mut had_work = vec![false; self.workers];
        let mut closed = vec![false; self.workers];
        let mut remaining = 0usize;
        for (j, slots) in owned.into_iter().enumerate() {
            if slots.is_empty() {
                continue;
            }
            if !self.mux.is_open(j) {
                for &(slot, _) in &slots {
                    ingest.resolve(slot, ClientResult::Dropped(DropPhase::BeforeUpload))?;
                }
                continue;
            }
            had_work[j] = true;
            let open = Msg::RoundOpen(RoundOpen {
                round: round as u32,
                n_downloads: slots.len() as u32,
                weight_clustering: spec.opts.weight_clustering,
                compressing: spec.compressing,
                down_compressed: spec.down_compressed,
                active: spec.centroids.active as u32,
                mu: spec.centroids.mu.clone(),
            });
            let mut buf = Vec::new();
            // RoundOpen is control traffic; Downloads are the ledgered
            // data plane (the driver records framed_down per dispatch)
            self.control_bytes += open.write_to(&mut buf)?;
            self.mux.enqueue(j, &buf);
            self.mux.mark_active(j);
            for &(slot, part) in &slots {
                outstanding[j].insert(part.client, slot);
            }
            remaining += slots.len();
            dispatch[j] = slots.into_iter().collect();
        }

        let timeout_s = self.timeout.map(|d| d.as_secs_f64()).unwrap_or(0.0);
        let mut events: Vec<MuxEvent> = Vec::new();
        loop {
            // --- top off outboxes with pending Downloads ------------------
            for j in 0..self.workers {
                if !self.mux.is_open(j) {
                    continue;
                }
                while let Some(&(_slot, part)) = dispatch[j].front() {
                    if self.mux.outbox_len(j) >= OUTBOX_WATERMARK {
                        break;
                    }
                    // zero-copy-spirit dispatch: the shared payload is
                    // framed per client, but only up to the watermark at
                    // a time, so memory stays flat in fleet size. The
                    // codec header beyond its 1-byte ledger baseline is
                    // control traffic, like the centroid sidecar.
                    self.control_bytes += proto::codec_header_surplus(&spec.down.spec);
                    let mut buf = Vec::with_capacity(64 + spec.down.payload.len());
                    proto::write_download(
                        &mut buf,
                        round as u32,
                        part.client as u32,
                        &spec.down.spec,
                        &spec.down.payload,
                    )?;
                    self.mux.enqueue(j, &buf);
                    dispatch[j].pop_front();
                }
            }

            // --- close the round on connections that finished it ----------
            for j in 0..self.workers {
                if had_work[j]
                    && !closed[j]
                    && self.mux.is_open(j)
                    && outstanding[j].is_empty()
                    && dispatch[j].is_empty()
                {
                    let mut buf = Vec::new();
                    self.control_bytes +=
                        Msg::RoundClose { round: round as u32 }.write_to(&mut buf)?;
                    self.mux.enqueue(j, &buf);
                    closed[j] = true;
                }
            }

            // --- one readiness pass ---------------------------------------
            events.clear();
            let progress = self.mux.poll(&mut events);
            for ev in events.drain(..) {
                match ev {
                    MuxEvent::Closed { conn, error } => {
                        if outstanding[conn].is_empty() {
                            crate::info!("worker {conn} connection closed ({error})");
                            continue;
                        }
                        crate::info!(
                            "worker {conn} connection lost ({error}); dropping {} clients",
                            outstanding[conn].len()
                        );
                        ingest.sink().emit(&StreamEvent::Evicted {
                            round,
                            conn,
                            cause: format!("connection_lost: {error}"),
                            dropped_clients: outstanding[conn].len(),
                        });
                        remaining -=
                            drop_outstanding(&mut outstanding[conn], &mut dispatch[conn], ingest)?;
                    }
                    MuxEvent::Frame { conn, msg_type, payload } => {
                        if outstanding[conn].is_empty() {
                            crate::info!("worker {conn} sent an unsolicited frame; evicting it");
                            ingest.sink().emit(&StreamEvent::Evicted {
                                round,
                                conn,
                                cause: "unsolicited_frame".to_string(),
                                dropped_clients: 0,
                            });
                            self.mux.close(conn);
                            continue;
                        }
                        let frame_len = super::frame::framed_len(payload.len());
                        let verdict = match Msg::decode(msg_type, &payload) {
                            Ok(Msg::Upload(up)) => self
                                .accept_upload(
                                    up,
                                    round,
                                    expected_p,
                                    expected_mu,
                                    &mut outstanding[conn],
                                )
                                .and_then(|(slot, received)| {
                                    ingest
                                        .resolve(slot, ClientResult::Upload(received))
                                        .map_err(|e| e.to_string())?;
                                    remaining -= 1;
                                    Ok(())
                                }),
                            Ok(Msg::EdgeUpload(e)) => {
                                // the edge blob is control traffic in
                                // full; the ledger records the logical
                                // member uploads instead (resolve_edge)
                                TcpTransport::accept_edge(
                                    self.edge[conn],
                                    e,
                                    round,
                                    ingest,
                                    &mut outstanding[conn],
                                )
                                .map(|n| {
                                    self.control_bytes += frame_len;
                                    remaining -= n;
                                })
                            }
                            Ok(other) => {
                                Err(format!("unexpected {} mid-round", other.kind()))
                            }
                            Err(e) => Err(format!("undecodable frame: {e}")),
                        };
                        match verdict {
                            Ok(()) => self.mux.mark_active(conn),
                            Err(reason) => {
                                crate::info!(
                                    "rejecting worker {conn} ({reason}); dropping {} clients",
                                    outstanding[conn].len()
                                );
                                ingest.sink().emit(&StreamEvent::Evicted {
                                    round,
                                    conn,
                                    cause: reason,
                                    dropped_clients: outstanding[conn].len(),
                                });
                                self.mux.close(conn);
                                remaining -= drop_outstanding(
                                    &mut outstanding[conn],
                                    &mut dispatch[conn],
                                    ingest,
                                )?;
                            }
                        }
                    }
                }
            }

            // --- round timeout: a silent connection is a straggler cut ----
            if let Some(t) = self.timeout {
                for j in 0..self.workers {
                    if !outstanding[j].is_empty()
                        && self.mux.is_open(j)
                        && self.mux.idle_for(j) > t
                    {
                        crate::info!(
                            "worker {j} timed out with {} uploads pending",
                            outstanding[j].len()
                        );
                        ingest.sink().emit(&StreamEvent::Evicted {
                            round,
                            conn: j,
                            cause: "round_timeout".to_string(),
                            dropped_clients: outstanding[j].len(),
                        });
                        for &slot in outstanding[j].values() {
                            ingest.resolve(slot, ClientResult::TimedOut { elapsed_s: timeout_s })?;
                        }
                        remaining -= outstanding[j].len();
                        outstanding[j].clear();
                        dispatch[j].clear();
                        self.mux.close(j);
                    }
                }
            }

            // --- done when everything is resolved and flushed -------------
            if remaining == 0 {
                let flushed = (0..self.workers).all(|j| {
                    !self.mux.is_open(j)
                        || (self.mux.outbox_len(j) == 0 && (!had_work[j] || closed[j]))
                });
                if flushed {
                    break;
                }
            }
            if !progress {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
        Ok(())
    }

    fn shutdown(&mut self) -> Result<()> {
        for j in 0..self.workers {
            let sent = match self.mux.blocking_stream(j) {
                Some(stream) => Msg::Shutdown.write_to(stream).ok(),
                None => None,
            };
            if let Some(n) = sent {
                self.control_bytes += n;
            }
            self.mux.close(j);
        }
        Ok(())
    }
}
