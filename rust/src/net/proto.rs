//! The coordinator/worker message vocabulary with explicit
//! little-endian serialization — no external serde, every byte
//! accounted for.
//!
//! ```text
//! worker                     coordinator
//!   │── Hello{version, edge} ──▶│   (one per connection)
//!   │◀─ HelloAck{ids, cfg} ─────│   deterministic client-id grant
//!   │                           │
//!   │◀─ RoundOpen{r, μ, flags} ─│   per round, per worker
//!   │◀─ Download{r, k, blob} ───│   per selected healthy client
//!   │── Upload{r, k, blob, …} ─▶│   training result + sidecars
//!   │── EdgeUpload{r, Σ, …} ───▶│   (edge workers: one pre-folded
//!   │                           │    blob for the whole sub-fleet)
//!   │◀─ RoundClose{r} ──────────│
//!   │        ⋮                  │
//!   │◀─ Shutdown ───────────────│   end of run
//! ```
//!
//! Byte accounting: the ledgered `framed_bytes` of a dispatch is
//! `bytes + DOWNLOAD_OVERHEAD` and of an upload `bytes +
//! UPLOAD_OVERHEAD` — both fixed constants (≤ 64 bytes, asserted in
//! tests) since the model payload crosses the wire in its *encoded*
//! form (`WireBlob::payload`), not as dense f32s. Metadata that rides
//! along — the per-round centroid table (`RoundOpen.mu` down,
//! `Upload.mu` up), the self-describing codec header beyond its 1-byte
//! accounting baseline, and the per-stage byte sidecar — is
//! control-plane traffic, tracked by `TcpTransport::control_bytes`
//! rather than the per-client ledger, so ledgers stay byte-identical
//! across transport backends and across the codec-API redesign.
//!
//! Codec header (versioned like the frame layer): every `Download` and
//! `Upload` carries `u8 version | u16 spec_len | spec` ahead of its
//! payload — the canonical codec spec string the receiver resolves
//! against its `codec::CodecRegistry`. Any codec registered on both
//! ends crosses the wire; the old closed 4-variant tag (and its
//! `Opaque` in-process-only carve-out) is gone.

use crate::baselines::wire::WireBlob;
use crate::clustering::ControllerConfig;
use crate::codec::{CodecCache, StageBytes};
use crate::config::FedConfig;
use crate::sim::{FleetConfig, FleetPreset};
use crate::util::cursor::ByteCursor;

use super::frame::FRAME_OVERHEAD;
use super::ProtoError;

/// Ledgered framing cost of one `Download`: frame overhead + round(4)
/// + client(4) + codec baseline(1). The self-describing codec header
/// is variable-length; the ledger accounts its 1-byte baseline here
/// and the rest as control traffic ([`codec_header_surplus`]).
pub const DOWNLOAD_OVERHEAD: usize = FRAME_OVERHEAD + 9;

/// Ledgered framing cost of one `Upload`, excluding the centroid-table
/// and stage-byte sidecars: frame overhead + round(4) + client(4) +
/// score(8) + n(4) + mean_ce(4) + codec baseline(1).
pub const UPLOAD_OVERHEAD: usize = FRAME_OVERHEAD + 25;

/// Version byte of the self-describing codec header.
pub const CODEC_HEADER_VERSION: u8 = 1;

/// Wire size of the codec header: version(1) + spec_len(2) + spec.
pub fn codec_header_len(spec: &str) -> usize {
    3 + spec.len()
}

/// Codec-header bytes beyond the 1-byte baseline the ledger accounts —
/// tracked as control-plane traffic like the centroid sidecar.
pub fn codec_header_surplus(spec: &str) -> usize {
    codec_header_len(spec) - 1
}

/// Wire size of an upload's per-stage byte sidecar: count(1) + per
/// stage name_len(1) + name + bytes(8). Control-plane traffic.
pub fn stages_sidecar_len(stages: &[StageBytes]) -> usize {
    1 + stages.iter().map(|s| 9 + s.stage.len()).sum::<usize>()
}

/// Framed wire size of a dispatch carrying `bytes` payload bytes.
pub fn framed_down(bytes: usize) -> usize {
    bytes + DOWNLOAD_OVERHEAD
}

/// Ledgered framed wire size of an upload carrying `bytes` payload
/// bytes (centroid/codec/stage sidecars accounted separately as
/// control traffic).
pub fn framed_up(bytes: usize) -> usize {
    bytes + UPLOAD_OVERHEAD
}

// --- message structs -------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
pub struct Hello {
    pub proto_version: u16,
    /// Edge-aggregator capacity: 0 for a leaf worker, otherwise the
    /// maximum sub-fleet size this connection folds locally before
    /// shipping one [`EdgeUpload`] upstream.
    pub edge_of: u32,
}

/// Handshake grant: which worker this connection is, the deterministic
/// client ids it hosts, and the full experiment image (strategy name +
/// config) it needs to rebuild data, model, and RNG streams locally.
/// The config is boxed so the `Msg` enum stays small.
#[derive(Clone, Debug)]
pub struct HelloAck {
    pub worker: u32,
    pub workers: u32,
    pub clients: Vec<u32>,
    pub strategy: String,
    pub cfg: Box<FedConfig>,
}

/// Per-round broadcast to one worker: the server centroid table and
/// the round's training flags, followed by `n_downloads` `Download`s.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundOpen {
    pub round: u32,
    pub n_downloads: u32,
    pub weight_clustering: bool,
    pub compressing: bool,
    pub down_compressed: bool,
    pub active: u32,
    pub mu: Vec<f32>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Download {
    pub round: u32,
    pub client: u32,
    /// self-describing codec spec that decodes `payload`
    pub spec: String,
    pub payload: Vec<u8>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Upload {
    pub round: u32,
    pub client: u32,
    pub score: f64,
    pub n: u32,
    pub mean_ce: f32,
    pub mu: Vec<f32>,
    /// per-stage wire-byte breakdown (ledger sidecar)
    pub stages: Vec<StageBytes>,
    /// self-describing codec spec that decodes `payload`
    pub spec: String,
    pub payload: Vec<u8>,
}

/// One surviving member of an edge worker's sub-fleet: the sidecar
/// facts the coordinator needs to keep its ledger and events
/// byte-identical to a flat fleet (`up_bytes` is what the member's
/// upload *would* have cost on the wire — it was folded locally
/// instead).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EdgeMemberWire {
    pub client: u32,
    pub n: u32,
    pub up_bytes: u64,
    pub score: f64,
    pub mean_ce: f32,
}

/// A sub-fleet member the edge worker cut for missing the sim
/// deadline; the coordinator re-derives the same verdict from its own
/// clock and records the usual `Deadline` event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EdgeCutWire {
    pub client: u32,
    pub up_bytes: u64,
}

/// An edge worker's whole round in one message: the sample-weighted
/// partial FedAvg of its surviving members (`payload` = raw
/// little-endian f32 theta, `mu` = the matching centroid-table fold),
/// plus the per-member sidecars.
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeUpload {
    pub round: u32,
    /// Σ member `n` — the fold's total FedAvg weight.
    pub total_n: u64,
    /// sample-weighted mean of member scores
    pub score: f64,
    pub members: Vec<EdgeMemberWire>,
    pub cut: Vec<EdgeCutWire>,
    pub mu: Vec<f32>,
    /// group-folded partial theta as raw little-endian f32s
    pub payload: Vec<u8>,
}

impl EdgeUpload {
    /// Decode the raw payload back into the folded theta.
    pub fn theta(&self) -> Result<Vec<f32>, ProtoError> {
        if self.payload.len() % 4 != 0 {
            return Err(malformed(format!(
                "edge payload is {} bytes, not a whole number of f32s",
                self.payload.len()
            )));
        }
        Ok(self
            .payload
            .chunks_exact(4)
            .map(|b| {
                // chunks_exact(4) guarantees the conversion succeeds
                let arr: [u8; 4] = b.try_into().unwrap_or_default();
                f32::from_le_bytes(arr)
            })
            .collect())
    }
}

#[derive(Clone, Debug)]
pub enum Msg {
    Hello(Hello),
    HelloAck(HelloAck),
    RoundOpen(RoundOpen),
    Download(Download),
    Upload(Upload),
    RoundClose { round: u32 },
    Shutdown,
    EdgeUpload(EdgeUpload),
}

impl Msg {
    pub fn msg_type(&self) -> u8 {
        match self {
            Msg::Hello(_) => 1,
            Msg::HelloAck(_) => 2,
            Msg::RoundOpen(_) => 3,
            Msg::Download(_) => 4,
            Msg::Upload(_) => 5,
            Msg::RoundClose { .. } => 6,
            Msg::Shutdown => 7,
            Msg::EdgeUpload(_) => 8,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Hello(_) => "Hello",
            Msg::HelloAck(_) => "HelloAck",
            Msg::RoundOpen(_) => "RoundOpen",
            Msg::Download(_) => "Download",
            Msg::Upload(_) => "Upload",
            Msg::RoundClose { .. } => "RoundClose",
            Msg::Shutdown => "Shutdown",
            Msg::EdgeUpload(_) => "EdgeUpload",
        }
    }

    /// Serialize the message payload (frame not included).
    pub fn encode_payload(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Msg::Hello(h) => {
                put_u16(&mut out, h.proto_version);
                put_u32(&mut out, h.edge_of);
            }
            Msg::HelloAck(a) => {
                put_u32(&mut out, a.worker);
                put_u32(&mut out, a.workers);
                put_u32(&mut out, a.clients.len() as u32);
                for &c in &a.clients {
                    put_u32(&mut out, c);
                }
                put_str(&mut out, &a.strategy);
                put_cfg(&mut out, &a.cfg);
            }
            Msg::RoundOpen(r) => {
                put_u32(&mut out, r.round);
                put_u32(&mut out, r.n_downloads);
                let flags = u8::from(r.weight_clustering)
                    | (u8::from(r.compressing) << 1)
                    | (u8::from(r.down_compressed) << 2);
                out.push(flags);
                put_u32(&mut out, r.active);
                put_f32s(&mut out, &r.mu);
            }
            Msg::Download(d) => {
                put_u32(&mut out, d.round);
                put_u32(&mut out, d.client);
                put_codec_header(&mut out, &d.spec);
                out.extend_from_slice(&d.payload);
            }
            Msg::Upload(u) => {
                put_u32(&mut out, u.round);
                put_u32(&mut out, u.client);
                put_f64(&mut out, u.score);
                put_u32(&mut out, u.n);
                put_f32(&mut out, u.mean_ce);
                put_f32s(&mut out, &u.mu);
                put_stages(&mut out, &u.stages);
                put_codec_header(&mut out, &u.spec);
                out.extend_from_slice(&u.payload);
            }
            Msg::RoundClose { round } => put_u32(&mut out, *round),
            Msg::Shutdown => {}
            Msg::EdgeUpload(e) => {
                put_u32(&mut out, e.round);
                put_u64(&mut out, e.total_n);
                put_f64(&mut out, e.score);
                put_u32(&mut out, e.members.len() as u32);
                for m in &e.members {
                    put_u32(&mut out, m.client);
                    put_u32(&mut out, m.n);
                    put_u64(&mut out, m.up_bytes);
                    put_f64(&mut out, m.score);
                    put_f32(&mut out, m.mean_ce);
                }
                put_u32(&mut out, e.cut.len() as u32);
                for c in &e.cut {
                    put_u32(&mut out, c.client);
                    put_u64(&mut out, c.up_bytes);
                }
                put_f32s(&mut out, &e.mu);
                out.extend_from_slice(&e.payload);
            }
        }
        out
    }

    /// Decode a frame body (`msg_type` from the frame header).
    pub fn decode(msg_type: u8, payload: &[u8]) -> Result<Msg, ProtoError> {
        let mut c = Cur::new(payload);
        let msg = match msg_type {
            1 => Msg::Hello(Hello {
                proto_version: c.u16("hello version")?,
                edge_of: c.u32("hello edge_of")?,
            }),
            2 => {
                let worker = c.u32("ack worker")?;
                let workers = c.u32("ack workers")?;
                let n = c.u32("ack client count")? as usize;
                if n > 1_000_000 {
                    return Err(malformed(format!("handshake grants {n} clients")));
                }
                let mut clients = Vec::with_capacity(n);
                for _ in 0..n {
                    clients.push(c.u32("ack client id")?);
                }
                let strategy = c.str("ack strategy")?;
                let cfg = Box::new(read_cfg(&mut c)?);
                Msg::HelloAck(HelloAck {
                    worker,
                    workers,
                    clients,
                    strategy,
                    cfg,
                })
            }
            3 => {
                let round = c.u32("open round")?;
                let n_downloads = c.u32("open download count")?;
                let flags = c.u8("open flags")?;
                let active = c.u32("open active")?;
                let mu = c.f32s("open centroids")?;
                if active as usize > mu.len() {
                    return Err(malformed(format!(
                        "round open claims {active} active of {} centroids",
                        mu.len()
                    )));
                }
                Msg::RoundOpen(RoundOpen {
                    round,
                    n_downloads,
                    weight_clustering: flags & 1 != 0,
                    compressing: flags & 2 != 0,
                    down_compressed: flags & 4 != 0,
                    active,
                    mu,
                })
            }
            4 => Msg::Download(Download {
                round: c.u32("download round")?,
                client: c.u32("download client")?,
                spec: c.codec_spec("download codec header")?,
                payload: c.rest(),
            }),
            5 => Msg::Upload(Upload {
                round: c.u32("upload round")?,
                client: c.u32("upload client")?,
                score: c.f64("upload score")?,
                n: c.u32("upload n")?,
                mean_ce: c.f32("upload mean_ce")?,
                mu: c.f32s("upload centroids")?,
                stages: c.stages("upload stage sidecar")?,
                spec: c.codec_spec("upload codec header")?,
                payload: c.rest(),
            }),
            6 => Msg::RoundClose {
                round: c.u32("close round")?,
            },
            7 => Msg::Shutdown,
            8 => {
                let round = c.u32("edge round")?;
                let total_n = c.u64("edge total_n")?;
                let score = c.f64("edge score")?;
                let n_members = c.u32("edge member count")? as usize;
                if n_members > 1_000_000 {
                    return Err(malformed(format!("edge upload lists {n_members} members")));
                }
                let mut members = Vec::with_capacity(n_members);
                for _ in 0..n_members {
                    members.push(EdgeMemberWire {
                        client: c.u32("edge member client")?,
                        n: c.u32("edge member n")?,
                        up_bytes: c.u64("edge member up_bytes")?,
                        score: c.f64("edge member score")?,
                        mean_ce: c.f32("edge member mean_ce")?,
                    });
                }
                let n_cut = c.u32("edge cut count")? as usize;
                if n_cut > 1_000_000 {
                    return Err(malformed(format!("edge upload lists {n_cut} cut members")));
                }
                let mut cut = Vec::with_capacity(n_cut);
                for _ in 0..n_cut {
                    cut.push(EdgeCutWire {
                        client: c.u32("edge cut client")?,
                        up_bytes: c.u64("edge cut up_bytes")?,
                    });
                }
                let mu = c.f32s("edge centroids")?;
                Msg::EdgeUpload(EdgeUpload {
                    round,
                    total_n,
                    score,
                    members,
                    cut,
                    mu,
                    payload: c.rest(),
                })
            }
            got => return Err(ProtoError::UnknownMsgType { got }),
        };
        if !c.done() {
            return Err(malformed(format!(
                "{} bytes of trailing garbage after {}",
                c.remaining(),
                msg.kind()
            )));
        }
        Ok(msg)
    }

    /// Write as one frame; returns the frame's wire size.
    pub fn write_to(&self, w: &mut impl std::io::Write) -> Result<usize, ProtoError> {
        super::frame::write_frame(w, self.msg_type(), &self.encode_payload())
    }

    /// Read one frame and decode it.
    pub fn read_from(r: &mut impl std::io::Read) -> Result<Msg, ProtoError> {
        let (ty, payload) = super::frame::read_frame(r)?;
        Msg::decode(ty, &payload)
    }

    /// Total wire size of this message as one frame.
    pub fn framed_len(&self) -> usize {
        super::frame::framed_len(self.encode_payload().len())
    }
}

/// Zero-copy download dispatch: stream the round's shared model
/// payload under a per-client header without cloning it into a `Msg`.
/// Byte-identical on the wire to `Msg::Download(..).write_to(w)`.
pub fn write_download(
    w: &mut impl std::io::Write,
    round: u32,
    client: u32,
    spec: &str,
    payload: &[u8],
) -> Result<usize, ProtoError> {
    let mut head = Vec::with_capacity(8 + codec_header_len(spec));
    put_u32(&mut head, round);
    put_u32(&mut head, client);
    put_codec_header(&mut head, spec);
    super::frame::write_frame_parts(w, 4, &head, payload)
}

/// Zero-copy upload send: the sidecars form the head, the encoded blob
/// streams as the tail. Byte-identical to `Msg::Upload(..).write_to`.
pub fn write_upload(w: &mut impl std::io::Write, up: &Upload) -> Result<usize, ProtoError> {
    let mut head = Vec::with_capacity(
        24 + 4 + 4 * up.mu.len() + stages_sidecar_len(&up.stages) + codec_header_len(&up.spec),
    );
    put_u32(&mut head, up.round);
    put_u32(&mut head, up.client);
    put_f64(&mut head, up.score);
    put_u32(&mut head, up.n);
    put_f32(&mut head, up.mean_ce);
    put_f32s(&mut head, &up.mu);
    put_stages(&mut head, &up.stages);
    put_codec_header(&mut head, &up.spec);
    super::frame::write_frame_parts(w, 5, &head, &up.payload)
}

/// Decode a blob payload back into the weight vector the sender holds
/// (bit-exact: every registered codec round-trips its quantized
/// model). The cache keeps one pipeline instance per spec so stateful
/// stages (`delta`) hold their cross-round stream state.
pub fn decode_blob(cache: &CodecCache, spec: &str, payload: &[u8]) -> Result<Vec<f32>, ProtoError> {
    cache
        .decode(spec, payload)
        .map_err(|e| malformed(format!("payload under codec '{spec}': {e}")))
}

/// Rebuild a [`WireBlob`] from a received (spec, stages, payload)
/// triple, decoding through `cache`.
pub fn blob_from_payload(
    cache: &CodecCache,
    spec: String,
    stages: Vec<StageBytes>,
    payload: Vec<u8>,
) -> Result<WireBlob, ProtoError> {
    let theta = decode_blob(cache, &spec, &payload)?;
    Ok(WireBlob {
        bytes: payload.len(),
        theta,
        spec,
        payload,
        stage_bytes: stages,
    })
}

fn malformed(what: String) -> ProtoError {
    ProtoError::Malformed { what }
}

// --- primitive little-endian writers ---------------------------------------

fn put_u16(v: &mut Vec<u8>, x: u16) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_f32(v: &mut Vec<u8>, x: f32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_f64(v: &mut Vec<u8>, x: f64) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_f32s(v: &mut Vec<u8>, xs: &[f32]) {
    put_u32(v, xs.len() as u32);
    for &x in xs {
        put_f32(v, x);
    }
}
fn put_str(v: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u16::MAX as usize);
    put_u16(v, s.len() as u16);
    v.extend_from_slice(s.as_bytes());
}
fn put_codec_header(v: &mut Vec<u8>, spec: &str) {
    v.push(CODEC_HEADER_VERSION);
    put_str(v, spec);
}
fn put_stages(v: &mut Vec<u8>, stages: &[StageBytes]) {
    // The sidecar is observability metadata, so an out-of-spec custom
    // codec (more stages than the cap, a name over 255 bytes) is
    // clamped rather than panicking the send path: registry-built
    // pipelines can never hit either bound (MAX_STAGES=8, validated
    // short names), and a clamped sidecar still frames identically on
    // both ends.
    let n = stages.len().min(MAX_STAGE_SIDECAR);
    v.push(n as u8);
    for s in stages.iter().take(n) {
        let mut cut = s.stage.len().min(u8::MAX as usize);
        while !s.stage.is_char_boundary(cut) {
            cut -= 1;
        }
        v.push(cut as u8);
        v.extend_from_slice(s.stage.as_bytes().get(..cut).unwrap_or_default());
        put_u64(v, s.bytes as u64);
    }
}

/// Cap on per-upload stage sidecar entries (pipelines are capped far
/// below this; a corrupt count must not loop long).
const MAX_STAGE_SIDECAR: usize = 32;

// --- cursor reader with typed truncation errors ----------------------------

/// Message-level cursor: [`ByteCursor`] plus the `what` labels that
/// turn an out-of-bytes read into a useful [`ProtoError::Truncated`].
struct Cur<'a> {
    c: ByteCursor<'a>,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { c: ByteCursor::new(b) }
    }
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], ProtoError> {
        self.c.take(n).ok_or(ProtoError::Truncated { what })
    }
    fn u8(&mut self, what: &'static str) -> Result<u8, ProtoError> {
        self.c.u8().ok_or(ProtoError::Truncated { what })
    }
    fn u16(&mut self, what: &'static str) -> Result<u16, ProtoError> {
        self.c.u16().ok_or(ProtoError::Truncated { what })
    }
    fn u32(&mut self, what: &'static str) -> Result<u32, ProtoError> {
        self.c.u32().ok_or(ProtoError::Truncated { what })
    }
    fn u64(&mut self, what: &'static str) -> Result<u64, ProtoError> {
        self.c.u64().ok_or(ProtoError::Truncated { what })
    }
    fn f32(&mut self, what: &'static str) -> Result<f32, ProtoError> {
        self.c.f32().ok_or(ProtoError::Truncated { what })
    }
    fn f64(&mut self, what: &'static str) -> Result<f64, ProtoError> {
        self.c.f64().ok_or(ProtoError::Truncated { what })
    }
    fn f32s(&mut self, what: &'static str) -> Result<Vec<f32>, ProtoError> {
        let n = self.u32(what)? as usize;
        if n > 16_000_000 {
            return Err(malformed(format!("{what}: {n} floats is over the cap")));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32(what)?);
        }
        Ok(out)
    }
    fn str(&mut self, what: &'static str) -> Result<String, ProtoError> {
        let n = self.u16(what)? as usize;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| malformed(format!("{what}: not utf-8")))
    }
    fn codec_spec(&mut self, what: &'static str) -> Result<String, ProtoError> {
        let version = self.u8(what)?;
        if version != CODEC_HEADER_VERSION {
            return Err(malformed(format!(
                "{what}: codec header version {version}, this build speaks v{CODEC_HEADER_VERSION}"
            )));
        }
        let spec = self.str(what)?;
        if spec.is_empty() {
            return Err(malformed(format!("{what}: empty codec spec")));
        }
        Ok(spec)
    }
    fn stages(&mut self, what: &'static str) -> Result<Vec<StageBytes>, ProtoError> {
        let n = self.u8(what)? as usize;
        if n > MAX_STAGE_SIDECAR {
            return Err(malformed(format!("{what}: {n} stages is over the cap")));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let len = self.u8(what)? as usize;
            let name = self.take(len, what)?;
            let stage = String::from_utf8(name.to_vec())
                .map_err(|_| malformed(format!("{what}: stage name is not utf-8")))?;
            let bytes = self.u64(what)? as usize;
            out.push(StageBytes { stage, bytes });
        }
        Ok(out)
    }
    fn rest(&mut self) -> Vec<u8> {
        self.c.rest().to_vec()
    }
    fn done(&self) -> bool {
        self.c.done()
    }
    fn remaining(&self) -> usize {
        self.c.remaining()
    }
}

// --- FedConfig image --------------------------------------------------------

/// The bit-exact serialized image of a [`FedConfig`] — the same bytes
/// the `HelloAck` handshake ships to workers. Because two configs
/// produce the same image iff every field (floats bit-for-bit) is
/// identical, this image is also the content-address material for the
/// run store's record keys (`store::run_key`).
pub fn config_image(cfg: &FedConfig) -> Vec<u8> {
    let mut out = Vec::new();
    put_cfg(&mut out, cfg);
    out
}

/// Inverse of [`config_image`]: rebuild the exact `FedConfig`.
/// Trailing garbage after the image is rejected.
pub fn parse_config_image(bytes: &[u8]) -> Result<FedConfig, ProtoError> {
    let mut c = Cur::new(bytes);
    let cfg = read_cfg(&mut c)?;
    if !c.done() {
        return Err(malformed(format!(
            "{} bytes of trailing garbage after config image",
            c.remaining()
        )));
    }
    Ok(cfg)
}

/// Serialize the full experiment config: the worker must reconstruct
/// the *exact* `FedConfig` (floats bit-for-bit) or data partitioning
/// and RNG streams diverge.
fn put_cfg(v: &mut Vec<u8>, cfg: &FedConfig) {
    put_str(v, &cfg.dataset);
    put_u64(v, cfg.rounds as u64);
    put_u64(v, cfg.clients as u64);
    put_f64(v, cfg.participation);
    put_u64(v, cfg.local_epochs as u64);
    put_u64(v, cfg.server_epochs as u64);
    put_u64(v, cfg.train_size as u64);
    put_u64(v, cfg.test_size as u64);
    put_u64(v, cfg.ood_size as u64);
    put_u64(v, cfg.unlabeled_per_client as u64);
    put_f64(v, cfg.sigma);
    put_f32(v, cfg.lr_client);
    put_f32(v, cfg.lr_server);
    put_f32(v, cfg.beta);
    put_u64(v, cfg.beta_warmup_epochs as u64);
    put_u64(v, cfg.warmup_rounds as u64);
    put_f32(v, cfg.temperature);
    put_u64(v, cfg.controller.c_min as u64);
    put_u64(v, cfg.controller.c_max as u64);
    put_u64(v, cfg.controller.window as u64);
    put_u64(v, cfg.controller.patience as u64);
    put_u64(v, cfg.controller.step as u64);
    put_u64(v, cfg.fedzip_clusters as u64);
    put_f64(v, cfg.fedzip_keep);
    put_f64(v, cfg.topk_keep);
    put_u64(v, cfg.upload_workers as u64);
    put_str(v, &cfg.codec);
    put_str(v, cfg.fleet.preset.name());
    put_f64(v, cfg.fleet.dropout);
    put_f64(v, cfg.fleet.deadline_s);
    put_u64(v, cfg.fleet.edge_of as u64);
    put_u64(v, cfg.seed);
    put_f64(v, cfg.handshake_timeout_s);
}

fn read_cfg(c: &mut Cur<'_>) -> Result<FedConfig, ProtoError> {
    let w = "config";
    Ok(FedConfig {
        dataset: c.str(w)?,
        rounds: c.u64(w)? as usize,
        clients: c.u64(w)? as usize,
        participation: c.f64(w)?,
        local_epochs: c.u64(w)? as usize,
        server_epochs: c.u64(w)? as usize,
        train_size: c.u64(w)? as usize,
        test_size: c.u64(w)? as usize,
        ood_size: c.u64(w)? as usize,
        unlabeled_per_client: c.u64(w)? as usize,
        sigma: c.f64(w)?,
        lr_client: c.f32(w)?,
        lr_server: c.f32(w)?,
        beta: c.f32(w)?,
        beta_warmup_epochs: c.u64(w)? as usize,
        warmup_rounds: c.u64(w)? as usize,
        temperature: c.f32(w)?,
        controller: ControllerConfig {
            c_min: c.u64(w)? as usize,
            c_max: c.u64(w)? as usize,
            window: c.u64(w)? as usize,
            patience: c.u64(w)? as usize,
            step: c.u64(w)? as usize,
        },
        fedzip_clusters: c.u64(w)? as usize,
        fedzip_keep: c.f64(w)?,
        topk_keep: c.f64(w)?,
        upload_workers: c.u64(w)? as usize,
        codec: c.str(w)?,
        fleet: FleetConfig {
            preset: FleetPreset::from_name(&c.str(w)?)
                .map_err(|e| malformed(e.to_string()))?,
            dropout: c.f64(w)?,
            deadline_s: c.f64(w)?,
            edge_of: c.u64(w)? as usize,
        },
        seed: c.u64(w)?,
        handshake_timeout_s: c.f64(w)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        let wrote = msg.write_to(&mut buf).unwrap();
        assert_eq!(wrote, buf.len());
        assert_eq!(wrote, msg.framed_len());
        Msg::read_from(&mut &buf[..]).unwrap()
    }

    fn cfg_eq(a: &FedConfig, b: &FedConfig) {
        // FedConfig has no PartialEq; the debug image covers every field
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn every_message_round_trips() {
        let mut rng = Rng::new(1);
        let mu: Vec<f32> = (0..32).map(|_| rng.normal()).collect();

        match roundtrip(&Msg::Hello(Hello { proto_version: 1, edge_of: 8 })) {
            Msg::Hello(h) => {
                assert_eq!(h.proto_version, 1);
                assert_eq!(h.edge_of, 8);
            }
            other => panic!("{}", other.kind()),
        }

        let cfg = FedConfig::quick("speechcommands");
        let ack = HelloAck {
            worker: 1,
            workers: 2,
            clients: vec![1, 3, 5],
            strategy: "fedcompress".into(),
            cfg: Box::new(cfg.clone()),
        };
        match roundtrip(&Msg::HelloAck(ack)) {
            Msg::HelloAck(a) => {
                assert_eq!(a.worker, 1);
                assert_eq!(a.workers, 2);
                assert_eq!(a.clients, vec![1, 3, 5]);
                assert_eq!(a.strategy, "fedcompress");
                cfg_eq(&a.cfg, &cfg);
            }
            other => panic!("{}", other.kind()),
        }

        let open = RoundOpen {
            round: 4,
            n_downloads: 3,
            weight_clustering: true,
            compressing: true,
            down_compressed: false,
            active: 16,
            mu: mu.clone(),
        };
        match roundtrip(&Msg::RoundOpen(open.clone())) {
            Msg::RoundOpen(r) => assert_eq!(r, open),
            other => panic!("{}", other.kind()),
        }

        let dl = Download {
            round: 4,
            client: 5,
            spec: "codebook|huffman".to_string(),
            payload: vec![9u8; 777],
        };
        match roundtrip(&Msg::Download(dl.clone())) {
            Msg::Download(d) => assert_eq!(d, dl),
            other => panic!("{}", other.kind()),
        }

        let up = Upload {
            round: 4,
            client: 5,
            score: 3.25,
            n: 96,
            mean_ce: 1.5,
            mu,
            stages: vec![
                StageBytes {
                    stage: "topk".to_string(),
                    bytes: 4000,
                },
                StageBytes {
                    stage: "huffman".to_string(),
                    bytes: 3,
                },
            ],
            spec: "topk(keep=0.1)|kmeans(c=15,iters=25)|huffman".to_string(),
            payload: vec![1, 2, 3],
        };
        match roundtrip(&Msg::Upload(up.clone())) {
            Msg::Upload(u) => assert_eq!(u, up),
            other => panic!("{}", other.kind()),
        }

        match roundtrip(&Msg::RoundClose { round: 9 }) {
            Msg::RoundClose { round } => assert_eq!(round, 9),
            other => panic!("{}", other.kind()),
        }
        assert!(matches!(roundtrip(&Msg::Shutdown), Msg::Shutdown));

        let theta = [0.5f32, -1.25, 3.0];
        let edge = EdgeUpload {
            round: 4,
            total_n: 160,
            score: 2.75,
            members: vec![
                EdgeMemberWire {
                    client: 1,
                    n: 96,
                    up_bytes: 4096,
                    score: 3.0,
                    mean_ce: 1.25,
                },
                EdgeMemberWire {
                    client: 3,
                    n: 64,
                    up_bytes: 2048,
                    score: 2.5,
                    mean_ce: 0.75,
                },
            ],
            cut: vec![EdgeCutWire {
                client: 5,
                up_bytes: 4096,
            }],
            mu: vec![0.5, -0.5],
            payload: theta.iter().flat_map(|x| x.to_le_bytes()).collect(),
        };
        match roundtrip(&Msg::EdgeUpload(edge.clone())) {
            Msg::EdgeUpload(e) => {
                assert_eq!(e, edge);
                assert_eq!(e.theta().unwrap(), theta);
            }
            other => panic!("{}", other.kind()),
        }
    }

    /// A ragged edge payload (not a multiple of 4 bytes) is a typed
    /// error, not a panic or a silent truncation.
    #[test]
    fn ragged_edge_payload_is_rejected() {
        let edge = EdgeUpload {
            round: 0,
            total_n: 1,
            score: 0.0,
            members: Vec::new(),
            cut: Vec::new(),
            mu: Vec::new(),
            payload: vec![1, 2, 3],
        };
        assert!(matches!(edge.theta(), Err(ProtoError::Malformed { .. })));
    }

    /// The paper-facing config must survive the wire bit-for-bit —
    /// a single differing float silently desynchronizes worker RNG
    /// streams from the coordinator's.
    #[test]
    fn config_image_is_bit_exact() {
        let mut cfg = FedConfig::paper("voxforge");
        cfg.sigma = 0.24999999999999997; // awkward float on purpose
        cfg.lr_client = 0.049999997;
        cfg.set("fleet", "hostile").unwrap();
        cfg.set("dropout", "0.125").unwrap();
        cfg.set("codec", "topk(keep=0.25)|kmeans(c=9)|huffman").unwrap();
        let mut buf = Vec::new();
        put_cfg(&mut buf, &cfg);
        let mut cur = Cur::new(&buf);
        let back = read_cfg(&mut cur).unwrap();
        assert!(cur.done());
        cfg_eq(&back, &cfg);
        assert_eq!(back.sigma.to_bits(), cfg.sigma.to_bits());
        assert_eq!(back.lr_client.to_bits(), cfg.lr_client.to_bits());
    }

    /// The public image helpers are the exact handshake bytes, and the
    /// parser rejects trailing garbage (a config image is a complete
    /// value, not a stream prefix).
    #[test]
    fn config_image_helpers_round_trip() {
        let cfg = FedConfig::quick("pathmnist");
        let img = config_image(&cfg);
        let mut handshake = Vec::new();
        put_cfg(&mut handshake, &cfg);
        assert_eq!(img, handshake);
        cfg_eq(&parse_config_image(&img).unwrap(), &cfg);
        let mut padded = img.clone();
        padded.push(0);
        assert!(parse_config_image(&padded).is_err());
        assert!(parse_config_image(&img[..img.len() - 1]).is_err());
    }

    /// Acceptance bound: the per-message framing overhead the ledger
    /// records is a constant and stays under 64 bytes each way; the
    /// variable codec header and stage sidecar are accounted exactly
    /// by the control-plane helpers.
    #[test]
    fn ledgered_overheads_are_constant_and_small() {
        assert!(DOWNLOAD_OVERHEAD <= 64, "{DOWNLOAD_OVERHEAD}");
        assert!(UPLOAD_OVERHEAD <= 64, "{UPLOAD_OVERHEAD}");
        // ...and they match the real encoders: a Download frame is
        // exactly framed_down(payload) plus the codec header's control
        // surplus; an Upload adds its centroid + stage sidecars too.
        let spec = "codebook|huffman";
        let dl = Msg::Download(Download {
            round: 0,
            client: 0,
            spec: spec.to_string(),
            payload: vec![0u8; 1000],
        });
        assert_eq!(dl.framed_len(), framed_down(1000) + codec_header_surplus(spec));
        let mu = vec![0.0f32; 32];
        let stages = vec![
            StageBytes {
                stage: "codebook".to_string(),
                bytes: 700,
            },
            StageBytes {
                stage: "huffman".to_string(),
                bytes: 500,
            },
        ];
        let up = Msg::Upload(Upload {
            round: 0,
            client: 0,
            score: 0.0,
            n: 1,
            mean_ce: 0.0,
            mu: mu.clone(),
            stages: stages.clone(),
            spec: spec.to_string(),
            payload: vec![0u8; 500],
        });
        assert_eq!(
            up.framed_len(),
            framed_up(500)
                + 4
                + 4 * mu.len()
                + stages_sidecar_len(&stages)
                + codec_header_surplus(spec)
        );
    }

    /// The zero-copy writers must put the exact same bytes on the wire
    /// as the owning `Msg` encoders they bypass.
    #[test]
    fn zero_copy_writers_match_msg_encoders() {
        let mut rng = Rng::new(3);
        let payload: Vec<u8> = (0..5000).map(|_| rng.below(256) as u8).collect();

        let spec = "codebook|huffman";
        let mut via_helper = Vec::new();
        let n = write_download(&mut via_helper, 6, 2, spec, &payload).unwrap();
        let mut via_msg = Vec::new();
        Msg::Download(Download {
            round: 6,
            client: 2,
            spec: spec.to_string(),
            payload: payload.clone(),
        })
        .write_to(&mut via_msg)
        .unwrap();
        assert_eq!(via_helper, via_msg);
        assert_eq!(n, via_msg.len());

        let up = Upload {
            round: 6,
            client: 2,
            score: -1.25,
            n: 64,
            mean_ce: 0.5,
            mu: (0..32).map(|_| rng.normal()).collect(),
            stages: vec![StageBytes {
                stage: "topk".to_string(),
                bytes: 5000,
            }],
            spec: "topk(keep=0.1)".to_string(),
            payload,
        };
        let mut via_helper = Vec::new();
        let n = write_upload(&mut via_helper, &up).unwrap();
        let mut via_msg = Vec::new();
        Msg::Upload(up.clone()).write_to(&mut via_msg).unwrap();
        assert_eq!(via_helper, via_msg);
        assert_eq!(n, via_msg.len());
    }

    #[test]
    fn blob_payloads_decode_bit_exactly() {
        use crate::baselines::wire::{codebook_blob, kmeans_blob};
        use crate::clustering::CentroidState;

        let mut rng = Rng::new(7);
        let theta: Vec<f32> = (0..4000).map(|_| rng.normal() * 0.2).collect();
        let cents = CentroidState::init_from_weights(&theta, 16, 32, &mut rng);

        let cache = CodecCache::builtin();
        let blobs = [
            WireBlob::dense(&theta),
            kmeans_blob(&theta, 15, 0.6, &mut rng).unwrap(),
            codebook_blob(&theta, &cents).unwrap(),
        ];
        for blob in blobs {
            let back = blob_from_payload(
                &cache,
                blob.spec.clone(),
                blob.stage_bytes.clone(),
                blob.payload.clone(),
            )
            .unwrap();
            assert_eq!(back.theta, blob.theta, "{}", blob.spec);
            assert_eq!(back.bytes, blob.bytes);
            assert_eq!(back.stage_bytes, blob.stage_bytes);
        }
        // an unregistered codec is rejected with the typed error, not
        // mis-decoded (the old Opaque carve-out is gone — anything the
        // registry resolves crosses; anything else fails loudly)
        let err = decode_blob(&cache, "opaque", &[1, 2, 3]).unwrap_err().to_string();
        assert!(err.contains("unknown codec 'opaque'"), "{err}");
    }
}
