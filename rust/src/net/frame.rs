//! The byte-level frame codec: every protocol message travels as
//!
//! ```text
//! u32 magic "FCN1" | u16 version | u8 msg_type | u32 payload_len |
//! payload bytes    | u32 crc32(payload)
//! ```
//!
//! little-endian throughout, `FRAME_OVERHEAD` = 15 bytes per message.
//! Reading validates magic, version, the length cap, and the CRC before
//! a single payload byte reaches the message decoder; every failure is
//! a typed [`ProtoError`]. No external dependencies — the CRC32 (IEEE
//! 802.3 polynomial) lives here.

use std::io::{Read, Write};

use super::ProtoError;
use crate::util::cursor::ByteCursor;

/// Frame magic, "FCN1" as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FCN1");

/// Protocol version this build speaks. Bump on any wire change.
/// v2: self-describing codec headers + stage sidecars on
/// Download/Upload, and the `codec` field in the config image.
/// v3: `edge_of` in Hello, the `EdgeUpload` message, and
/// `handshake_timeout_s` in the config image.
pub const PROTO_VERSION: u16 = 3;

/// Fixed per-frame cost: magic(4) + version(2) + type(1) + len(4) +
/// crc32(4).
pub const FRAME_OVERHEAD: usize = 15;

/// Refuse frames above this payload size (a corrupt length prefix must
/// not become a multi-gigabyte allocation).
pub const MAX_PAYLOAD: u32 = 256 << 20;

const fn crc_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 == 1 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        // fedlint:allow(no-panic-decode) -- const-eval table build, i < 256 by the loop bound
        t[i] = c;
        i += 1;
    }
    t
}

const CRC_TABLE: [u32; 256] = crc_table();

/// Streaming CRC32 (IEEE, reflected, init/xorout 0xFFFFFFFF) — the
/// standard zlib/ethernet checksum. The streaming form lets a frame be
/// checksummed across multiple payload parts without concatenation.
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            // fedlint:allow(no-panic-decode) -- index is masked to 8 bits, always in range
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Total on-the-wire size of a frame carrying `payload_len` bytes.
pub fn framed_len(payload_len: usize) -> usize {
    FRAME_OVERHEAD + payload_len
}

/// Serialize one frame into a buffer (the whole frame is materialized
/// so the caller can issue a single `write_all` — no partial frames on
/// the socket).
pub fn encode_frame(msg_type: u8, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() as u64 <= MAX_PAYLOAD as u64, "frame payload over cap");
    let mut out = Vec::with_capacity(framed_len(payload.len()));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    out.push(msg_type);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out
}

/// Write one frame; returns the number of bytes put on the wire.
pub fn write_frame(w: &mut impl Write, msg_type: u8, payload: &[u8]) -> Result<usize, ProtoError> {
    let frame = encode_frame(msg_type, payload);
    w.write_all(&frame)?;
    Ok(frame.len())
}

/// Write one frame whose payload is `head ++ tail` without ever
/// concatenating them — the zero-copy path for dispatching a large
/// shared payload (the model blob) under a small per-client header.
/// Byte-identical on the wire to `write_frame(w, ty, head ++ tail)`.
pub fn write_frame_parts(
    w: &mut impl Write,
    msg_type: u8,
    head: &[u8],
    tail: &[u8],
) -> Result<usize, ProtoError> {
    let len = head.len() + tail.len();
    assert!(len as u64 <= MAX_PAYLOAD as u64, "frame payload over cap");
    // frame header + head in one small buffer, then the borrowed tail,
    // then the checksum — three writes, zero payload copies
    let mut lead = Vec::with_capacity(11 + head.len());
    lead.extend_from_slice(&MAGIC.to_le_bytes());
    lead.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    lead.push(msg_type);
    lead.extend_from_slice(&(len as u32).to_le_bytes());
    lead.extend_from_slice(head);
    let mut crc = Crc32::new();
    crc.update(head);
    crc.update(tail);
    w.write_all(&lead)?;
    w.write_all(tail)?;
    w.write_all(&crc.finish().to_le_bytes())?;
    Ok(framed_len(len))
}

fn read_exact_or(
    r: &mut impl Read,
    buf: &mut [u8],
    what: &'static str,
) -> Result<(), ProtoError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            ProtoError::Truncated { what }
        } else {
            ProtoError::Io(e)
        }
    })
}

/// Read and validate one frame; returns `(msg_type, payload)`.
///
/// Validation order: magic, version, length cap, payload, CRC. A
/// stream that ends mid-frame returns [`ProtoError::Truncated`]; a
/// socket read timeout surfaces as [`ProtoError::Io`] (see
/// [`ProtoError::is_timeout`]). Nothing here blocks beyond what the
/// underlying reader's own timeout allows.
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>), ProtoError> {
    let mut header = [0u8; 11];
    read_exact_or(r, &mut header, "frame header")?;
    // the cursor cannot actually run out of an 11-byte header, but the
    // decode path stays panic-free on principle (fedlint: no-panic-decode)
    let short = || ProtoError::Truncated { what: "frame header" };
    let mut c = ByteCursor::new(&header);
    let magic = c.u32().ok_or_else(short)?;
    if magic != MAGIC {
        return Err(ProtoError::BadMagic { got: magic });
    }
    let version = c.u16().ok_or_else(short)?;
    if version != PROTO_VERSION {
        return Err(ProtoError::BadVersion { got: version });
    }
    let msg_type = c.u8().ok_or_else(short)?;
    let len = c.u32().ok_or_else(short)?;
    if len > MAX_PAYLOAD {
        return Err(ProtoError::Oversized { len, max: MAX_PAYLOAD });
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload, "frame payload")?;
    let mut crc_bytes = [0u8; 4];
    read_exact_or(r, &mut crc_bytes, "frame checksum")?;
    let stored = u32::from_le_bytes(crc_bytes);
    let computed = crc32(&payload);
    if stored != computed {
        return Err(ProtoError::CrcMismatch { stored, computed });
    }
    Ok((msg_type, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // standard test vectors for the IEEE polynomial
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn frame_round_trips() {
        for payload in [&b""[..], &b"x"[..], &[0u8; 10_000][..]] {
            let frame = encode_frame(7, payload);
            assert_eq!(frame.len(), framed_len(payload.len()));
            let (ty, body) = read_frame(&mut &frame[..]).unwrap();
            assert_eq!(ty, 7);
            assert_eq!(body, payload);
        }
    }

    #[test]
    fn overhead_is_exactly_fifteen_bytes() {
        assert_eq!(encode_frame(1, b"").len(), FRAME_OVERHEAD);
        assert_eq!(encode_frame(1, &[0u8; 123]).len(), FRAME_OVERHEAD + 123);
    }

    /// The zero-copy split writer must be indistinguishable on the wire
    /// from the single-buffer encoder, at every split point.
    #[test]
    fn split_writer_matches_single_buffer_encoder() {
        let payload: Vec<u8> = (0..300u32).map(|i| (i * 7) as u8).collect();
        let whole = encode_frame(4, &payload);
        for split in [0, 1, 9, 150, payload.len()] {
            let mut out = Vec::new();
            let n = write_frame_parts(&mut out, 4, &payload[..split], &payload[split..]).unwrap();
            assert_eq!(n, whole.len(), "split at {split}");
            assert_eq!(out, whole, "split at {split}");
        }
    }

    #[test]
    fn streaming_crc_matches_one_shot() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(97) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }
}
