//! Networked transport: a framed TCP coordinator/worker protocol
//! behind the [`Transport`] abstraction the round loop drives.
//!
//! Until this layer existed, every byte the communication ledger
//! counted travelled through an in-process function call. Here the
//! coordinator can speak to worker processes over real sockets — same
//! seed, same metrics — while the ledger's `framed_bytes` column
//! reports what the wire actually carries.
//!
//! Layout:
//!
//! * [`frame`] — the byte-level frame codec: magic + version + message
//!   type + length prefix + CRC32, `std::net`/`std::io` only. Corrupt
//!   input surfaces as a typed [`ProtoError`], never a panic or a hang.
//! * [`proto`] — the message vocabulary (`Hello`/`HelloAck`/
//!   `RoundOpen`/`Download`/`Upload`/`RoundClose`/`Shutdown`) with
//!   explicit little-endian serialization, including a full
//!   `FedConfig` image so workers reconstruct the exact experiment.
//! * [`transport`] — the [`Transport`] trait extracted from the round
//!   loop's dispatch/collect path, plus the default [`InProcess`]
//!   backend (byte-identical to the pre-transport coordinator).
//! * [`mux`] — the multiplexed connection layer: every worker socket
//!   nonblocking, serviced by one readiness loop on the coordinator
//!   thread, with incremental frame reassembly per connection. Many
//!   logical clients share one socket; a failing connection is
//!   evicted without disturbing the rest.
//! * [`tcp`] — the coordinator-side [`TcpTransport`]: accepts worker
//!   connections (surviving failed handshakes), assigns deterministic
//!   client ids at handshake, then drives rounds through the mux —
//!   uploads stream into the round's accumulator in whatever order
//!   they arrive, under a per-connection inactivity timeout that
//!   feeds the existing dropout/deadline fault machinery.
//! * [`worker`] — the worker runtime behind `fedcompress worker`,
//!   including the `--edge-of` aggregator mode that folds a sub-fleet
//!   locally and ships one pre-aggregated upload.
//!
//! Determinism contract: client ids are assigned at handshake by
//! arrival order (worker `j` of `W` hosts every client `k` with
//! `k % W == j`), but a client's behavior depends only on its id —
//! data shard, RNG streams (`10_000 + round*clients + k`), fault fates
//! — never on which socket hosts it; and the coordinator canonicalizes
//! uploads by client id before folding (`coordinator::accumulate`), so
//! a loopback run reproduces the in-process run bit-exactly for any
//! worker arrival order and any upload interleaving.

pub mod frame;
pub mod mux;
pub mod proto;
pub mod tcp;
pub mod transport;
pub mod worker;

pub use frame::{read_frame, write_frame, FRAME_OVERHEAD, PROTO_VERSION};
pub use mux::{FrameReader, Mux, MuxEvent};
pub use proto::Msg;
pub use tcp::{TcpServer, TcpTransport};
pub use transport::{
    ClientResult, InProcess, Participant, ReceivedUpload, RoundEnv, RoundSpec, Transport,
    TransportKind,
};

use std::fmt;

/// Typed protocol failure. Every malformed, truncated, or corrupt
/// input the frame/message codecs can see maps to one of these —
/// the decoders never panic and never block forever on bad bytes.
#[derive(Debug)]
pub enum ProtoError {
    /// Underlying socket/stream failure (includes read timeouts).
    Io(std::io::Error),
    /// Frame does not start with the protocol magic.
    BadMagic { got: u32 },
    /// Peer speaks a different protocol version.
    BadVersion { got: u16 },
    /// Frame type byte not in the message vocabulary.
    UnknownMsgType { got: u8 },
    /// Length prefix exceeds the sanity cap (refuse to allocate).
    Oversized { len: u32, max: u32 },
    /// Payload checksum does not match the stored CRC32.
    CrcMismatch { stored: u32, computed: u32 },
    /// Stream ended mid-structure.
    Truncated { what: &'static str },
    /// Structurally invalid message payload.
    Malformed { what: String },
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "transport i/o error: {e}"),
            ProtoError::BadMagic { got } => {
                write!(f, "bad frame magic 0x{got:08x} (not a fedcompress peer?)")
            }
            ProtoError::BadVersion { got } => write!(
                f,
                "protocol version mismatch: peer speaks v{got}, this build speaks v{}",
                frame::PROTO_VERSION
            ),
            ProtoError::UnknownMsgType { got } => write!(f, "unknown message type {got}"),
            ProtoError::Oversized { len, max } => {
                write!(f, "frame length {len} exceeds the {max}-byte cap")
            }
            ProtoError::CrcMismatch { stored, computed } => write!(
                f,
                "frame CRC mismatch: stored 0x{stored:08x}, computed 0x{computed:08x}"
            ),
            ProtoError::Truncated { what } => write!(f, "truncated frame: {what}"),
            ProtoError::Malformed { what } => write!(f, "malformed message: {what}"),
        }
    }
}

impl std::error::Error for ProtoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ProtoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> ProtoError {
        ProtoError::Io(e)
    }
}

impl ProtoError {
    /// True when the error is a socket read timeout (the per-client
    /// deadline firing), as opposed to a dead or misbehaving peer.
    pub fn is_timeout(&self) -> bool {
        matches!(
            self,
            ProtoError::Io(e) if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        )
    }
}
