//! The [`Transport`] abstraction: how a round's dispatch reaches the
//! selected clients and how their uploads come back.
//!
//! The round loop (`coordinator::server::run_with_strategy_opts`)
//! stays the owner of selection, fault fates, the ledger, the sim
//! deadline clock, events, and aggregation; a transport only answers
//! one question per round — *given this dispatch, what did each
//! participant send back?* Results stream into the round's
//! [`RoundIngest`]: the transport resolves each participant slot as
//! its outcome is known (any arrival order), and the ingest folds
//! surviving uploads into the strategy's aggregate immediately, so
//! coordinator memory stays constant in fleet size. Two backends:
//!
//! * [`InProcess`] (default) — trains and encodes in this process,
//!   exactly as the pre-transport coordinator did: engine-bound
//!   training serially on the coordinator thread, pure-CPU upload
//!   encoding fanned out over `util::threadpool::parallel_map` with
//!   per-client RNG forks. Byte-identical to the historical loop.
//! * [`TcpTransport`](super::tcp::TcpTransport) — ships the same
//!   dispatch over framed TCP to worker processes and collects their
//!   uploads under per-client timeouts.
//!
//! Both backends report sim-scheduled faults the same way (a
//! fault-dropped participant never trains), so ledgers, events, and
//! metrics are backend-independent; the TCP backend can additionally
//! report *real* losses ([`ClientResult::TimedOut`] and transport-level
//! drops), which the driver folds into the existing
//! `Event::Dropout`/`Event::Deadline` machinery.

use anyhow::Result;

use crate::baselines::wire::WireBlob;
use crate::client::trainer::{train_local, ClientOutcome};
use crate::clustering::CentroidState;
use crate::config::FedConfig;
use crate::coordinator::accumulate::{AggError, AggFold, FedAvgFold};
use crate::coordinator::events::DropPhase;
use crate::coordinator::server::{
    client_stream, EdgeCutMember, EdgeMember, EdgePartial, FederatedData, RoundIngest,
};
use crate::coordinator::strategy::{
    ClientTrainOpts, ClientUpdate, FedStrategy, RoundContext, UploadInput,
};
use crate::runtime::Engine;
use crate::sim::ClientFate;
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;
use crate::util::timer::Stopwatch;

/// Which transport a run used — recorded in checkpoints so a resume
/// under a different backend can warn (`Event::ResumeMismatch`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    InProcess,
    Tcp,
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Coordinator-side resources a transport may use to fulfill a round.
/// The TCP backend ignores the engine/data (workers own their own);
/// the in-process backend is exactly the old train/encode path.
pub struct RoundEnv<'a> {
    pub engine: &'a Engine,
    pub cfg: &'a FedConfig,
    pub data: &'a FederatedData,
    /// Root RNG of the run (`seed ^ 0xFEDC`); client streams fork from
    /// it with the protocol-fixed ids (`10_000 + round*clients + k`).
    pub base: &'a Rng,
    /// Worker threads for the in-process encode fan-out.
    pub encode_workers: usize,
}

/// One selected client and its sim-scheduled fate, in selection order.
#[derive(Clone, Copy, Debug)]
pub struct Participant {
    pub client: usize,
    pub fate: ClientFate,
}

/// Everything one round dispatches, independent of backend.
pub struct RoundSpec<'a> {
    pub round: usize,
    pub down: &'a WireBlob,
    /// Server centroid table *after* `round_start` (what clients train
    /// against this round).
    pub centroids: &'a CentroidState,
    pub opts: ClientTrainOpts,
    pub compressing: bool,
    pub down_compressed: bool,
    pub participants: &'a [Participant],
}

/// One client's upload as the server receives it: the decoded wire
/// blob plus the sidecar values that ride along.
pub struct ReceivedUpload {
    pub client: usize,
    pub blob: WireBlob,
    /// client-learned centroid table (control-plane sidecar)
    pub mu: Vec<f32>,
    pub score: f64,
    pub n: usize,
    pub mean_ce: f32,
}

/// Outcome for one participant, aligned with `RoundSpec::participants`.
pub enum ClientResult {
    Upload(Box<ReceivedUpload>),
    /// Lost to a sim-scheduled fault (both backends) or a transport
    /// fault — dead socket, protocol violation (TCP only).
    Dropped(DropPhase),
    /// The upload did not arrive within the transport's per-client
    /// timeout (TCP only); `elapsed_s` is the deadline that fired.
    TimedOut { elapsed_s: f64 },
}

/// A backend for the round loop's dispatch/collect path.
pub trait Transport {
    fn kind(&self) -> TransportKind;

    /// Execute one round: deliver the dispatch to every healthy
    /// participant, run their local updates, and resolve every
    /// participant slot on `ingest` exactly once — in any arrival
    /// order; the ingest canonicalizes. Sim-fated drops must be
    /// resolved as `Dropped` without training (their work would be
    /// discarded; every client owns an independent RNG fork, so
    /// skipping perturbs nothing).
    fn run_round(
        &mut self,
        env: &RoundEnv<'_>,
        strategy: &dyn FedStrategy,
        spec: &RoundSpec<'_>,
        ingest: &mut RoundIngest<'_>,
    ) -> Result<()>;

    /// Release transport resources (TCP: send `Shutdown` to workers).
    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }
}

/// The default backend: the pre-transport coordinator's train/encode
/// path, verbatim — engine-bound training serially on the coordinator
/// thread, upload encoding on the worker pool.
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcess;

/// One trained client awaiting upload encoding: the training outcome,
/// the client's RNG positioned exactly where training left it, and its
/// slot in the participant list.
struct TrainedClient {
    slot: usize,
    client: usize,
    outcome: ClientOutcome,
    rng: Rng,
}

impl Transport for InProcess {
    fn kind(&self) -> TransportKind {
        TransportKind::InProcess
    }

    fn run_round(
        &mut self,
        env: &RoundEnv<'_>,
        strategy: &dyn FedStrategy,
        spec: &RoundSpec<'_>,
        ingest: &mut RoundIngest<'_>,
    ) -> Result<()> {
        let cfg = env.cfg;
        let ctx = RoundContext {
            round: spec.round,
            cfg,
            base: env.base,
            compressing: spec.compressing,
            down_compressed: spec.down_compressed,
        };

        // --- client updates (engine-bound, coordinator thread) ------------
        let mut phase_sw = Stopwatch::start();
        let mut trained = Vec::with_capacity(spec.participants.len());
        for (slot, part) in spec.participants.iter().enumerate() {
            let phase = match part.fate {
                ClientFate::Healthy { .. } => None,
                ClientFate::DropBeforeTrain => Some(DropPhase::BeforeTrain),
                ClientFate::DropBeforeUpload => Some(DropPhase::BeforeUpload),
            };
            if let Some(phase) = phase {
                ingest.resolve(slot, ClientResult::Dropped(phase))?;
                continue;
            }
            let k = part.client;
            let mut client_rng = env.base.fork(client_stream(spec.round, cfg.clients, k));
            let outcome = train_local(
                env.engine,
                cfg,
                &env.data.labeled[k],
                &env.data.unlabeled[k],
                &spec.down.theta,
                spec.centroids,
                spec.opts.weight_clustering,
                &mut client_rng,
            )?;
            trained.push(TrainedClient {
                slot,
                client: k,
                outcome,
                rng: client_rng,
            });
        }
        ingest.add_phase_ns("train", phase_sw.lap_ns());

        // --- upload encoding (pure CPU, worker pool) ----------------------
        let blobs: Vec<Result<WireBlob>> = {
            let centroids = spec.centroids;
            let ctx = &ctx;
            parallel_map(trained.len(), env.encode_workers.max(1), |i| {
                let t = &trained[i];
                // the client's learned centroids ride along for the snap
                let mut client_cents = centroids.clone();
                client_cents.mu.clone_from(&t.outcome.mu);
                let mut rng = t.rng.clone();
                strategy.encode_upload(
                    ctx,
                    &UploadInput {
                        client: t.client,
                        theta: &t.outcome.theta,
                        centroids: &client_cents,
                    },
                    &mut rng,
                )
            })
        };
        ingest.add_phase_ns("encode_up", phase_sw.lap_ns());

        if cfg.fleet.edge_of > 0 {
            return resolve_edge_groups(cfg.fleet.edge_of, trained, blobs, ingest);
        }

        // slot order here is already canonical, so the streaming fold
        // never needs to park an in-process upload
        for (t, blob) in trained.into_iter().zip(blobs) {
            let up = ReceivedUpload {
                client: t.client,
                blob: blob?,
                mu: t.outcome.mu,
                score: t.outcome.score,
                n: t.outcome.n,
                mean_ce: t.outcome.mean_ce,
            };
            ingest.resolve(t.slot, ClientResult::Upload(Box::new(up)))?;
        }
        Ok(())
    }
}

/// In-process emulation of the edge tier (`fleet.edge_of > 0`): every
/// `edge_of` consecutive participant slots share one aggregator, which
/// deadline-cuts each member with the same pure clock
/// [`RoundIngest::resolve_edge`] re-derives, folds the survivors into
/// one sample-weighted partial, and commits the group through a single
/// `resolve_edge` call — the semantics `net::worker::serve_round_edge`
/// ships over TCP, so a sweep over `edge_of` agrees with a real edge
/// fleet. Fault-dropped slots were resolved individually before
/// training and never reach their group; a group losing every member
/// that way has nothing to say and is skipped.
fn resolve_edge_groups(
    edge_of: usize,
    trained: Vec<TrainedClient>,
    blobs: Vec<Result<WireBlob>>,
    ingest: &mut RoundIngest<'_>,
) -> Result<()> {
    let n_groups = ingest.slots().div_ceil(edge_of);
    let mut groups: Vec<Vec<(TrainedClient, WireBlob)>> =
        (0..n_groups).map(|_| Vec::new()).collect();
    for (t, blob) in trained.into_iter().zip(blobs) {
        let g = t.slot / edge_of;
        groups[g].push((t, blob?));
    }
    for group in groups {
        if group.is_empty() {
            continue;
        }
        let partial = fold_edge_group(group, ingest)?;
        ingest.resolve_edge(partial).map_err(|e| anyhow::anyhow!("in-process edge: {e}"))?;
    }
    Ok(())
}

/// Deadline-cut and fold one edge group into the partial its aggregator
/// would ship. Mirrors `serve_round_edge` exactly, including the
/// zero-weight case: survivors whose sample counts sum to zero fold to
/// a zero vector with zero weight, which aggregates to nothing.
fn fold_edge_group(
    group: Vec<(TrainedClient, WireBlob)>,
    ingest: &RoundIngest<'_>,
) -> Result<EdgePartial> {
    let mut fold: Box<dyn AggFold> = Box::new(FedAvgFold::new());
    let mut members = Vec::new();
    let mut cut = Vec::new();
    for (t, blob) in group {
        let up_bytes = blob.bytes;
        if ingest.member_over_deadline(t.slot, up_bytes) {
            cut.push(EdgeCutMember {
                client: t.client,
                up_bytes,
            });
            continue;
        }
        fold.fold(&ClientUpdate {
            client: t.client,
            theta: blob.theta,
            mu: t.outcome.mu,
            score: t.outcome.score,
            n: t.outcome.n,
        })
        .map_err(|e| anyhow::anyhow!("edge fold: {e}"))?;
        members.push(EdgeMember {
            client: t.client,
            n: t.outcome.n,
            up_bytes,
            score: t.outcome.score,
            mean_ce: t.outcome.mean_ce,
        });
    }
    if members.is_empty() {
        // every member cut: the coordinator only needs the cut report
        return Ok(EdgePartial {
            theta: Vec::new(),
            mu: Vec::new(),
            score: 0.0,
            total_n: 0,
            members,
            cut,
        });
    }
    match fold.finish() {
        Ok(agg) => Ok(EdgePartial {
            theta: agg.theta,
            mu: agg.mu,
            score: agg.score,
            total_n: agg.total_n,
            members,
            cut,
        }),
        // survivors with zero total sample weight fold to nothing
        Err(AggError::ZeroWeight) => Ok(EdgePartial {
            theta: vec![0.0; ingest.expected_params()],
            mu: vec![0.0; ingest.expected_mu()],
            score: 0.0,
            total_n: 0,
            members,
            cut,
        }),
        Err(e) => anyhow::bail!("edge fold finish: {e}"),
    }
}
