//! Centroid table management around the static C_max AOT interface.
//!
//! The HLO artifacts take a fixed-size `mu[C_max]` plus an activity
//! `mask[C_max]`; the dynamic cluster count C only toggles mask
//! entries, so one compiled executable serves the whole C schedule.
//! This module owns the (mu, mask) pair: k-means++ (re)initialization
//! from a weight vector, mask updates when the controller grows C, and
//! padding inactive slots harmlessly.

use crate::compression::kmeans::kmeans_pp_init;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct CentroidState {
    pub mu: Vec<f32>,
    pub mask: Vec<f32>,
    pub c_max: usize,
    pub active: usize,
}

impl CentroidState {
    /// Initialize `active` centroids from the weight distribution via
    /// k-means++; inactive slots park far outside the weight range so a
    /// buggy consumer would fail loudly rather than silently.
    pub fn init_from_weights(
        weights: &[f32],
        active: usize,
        c_max: usize,
        rng: &mut Rng,
    ) -> Self {
        assert!(active >= 1 && active <= c_max);
        let mut mu = kmeans_pp_init(weights, active, rng);
        let sentinel = 1e4;
        mu.resize(c_max, sentinel);
        let mut mask = vec![0.0f32; c_max];
        for m in mask.iter_mut().take(active) {
            *m = 1.0;
        }
        CentroidState {
            mu,
            mask,
            c_max,
            active,
        }
    }

    /// Grow the active count, seeding new slots by splitting the widest
    /// gaps in the current codebook (cheap, keeps existing structure).
    pub fn grow_to(&mut self, new_active: usize) {
        assert!(new_active <= self.c_max);
        while self.active < new_active {
            let act = &mut self.mu[..self.active];
            act.sort_by(|a, b| a.partial_cmp(b).unwrap());
            // widest gap
            let mut best = (0usize, f32::MIN);
            for i in 0..self.active - 1 {
                let gap = act[i + 1] - act[i];
                if gap > best.1 {
                    best = (i, gap);
                }
            }
            let new_c = if self.active == 1 {
                act[0] + 1e-3
            } else {
                0.5 * (act[best.0] + act[best.0 + 1])
            };
            self.mu[self.active] = new_c;
            self.mask[self.active] = 1.0;
            self.active += 1;
        }
    }

    /// Active slice of the codebook, sorted ascending.
    pub fn active_codebook(&self) -> Vec<f32> {
        let mut v = self.mu[..self.active].to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// Replace the active codebook (e.g. after a server-side k-means
    /// refresh), preserving mask/sentinel structure.
    pub fn set_active_codebook(&mut self, codebook: &[f32]) {
        assert!(codebook.len() <= self.c_max);
        self.active = codebook.len();
        for (i, m) in self.mu.iter_mut().enumerate() {
            *m = if i < codebook.len() { codebook[i] } else { 1e4 };
        }
        for (i, m) in self.mask.iter_mut().enumerate() {
            *m = if i < codebook.len() { 1.0 } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights() -> Vec<f32> {
        let mut rng = Rng::new(1);
        (0..2000).map(|_| rng.normal()).collect()
    }

    #[test]
    fn init_shapes_and_mask() {
        let mut rng = Rng::new(2);
        let s = CentroidState::init_from_weights(&weights(), 8, 32, &mut rng);
        assert_eq!(s.mu.len(), 32);
        assert_eq!(s.mask.len(), 32);
        assert_eq!(s.mask.iter().filter(|&&m| m == 1.0).count(), 8);
        // active centroids inside the data range, sentinels way out
        for i in 0..8 {
            assert!(s.mu[i].abs() < 10.0);
        }
        for i in 8..32 {
            assert!(s.mu[i] > 100.0);
        }
    }

    #[test]
    fn grow_adds_centroids_in_gaps() {
        let mut rng = Rng::new(3);
        let mut s = CentroidState::init_from_weights(&weights(), 8, 32, &mut rng);
        s.grow_to(16);
        assert_eq!(s.active, 16);
        assert_eq!(s.mask.iter().filter(|&&m| m == 1.0).count(), 16);
        let cb = s.active_codebook();
        assert_eq!(cb.len(), 16);
        // still within data range
        assert!(cb.iter().all(|c| c.abs() < 10.0));
    }

    #[test]
    fn set_active_codebook_roundtrip() {
        let mut rng = Rng::new(4);
        let mut s = CentroidState::init_from_weights(&weights(), 8, 32, &mut rng);
        let cb = vec![-1.0f32, 0.0, 1.0];
        s.set_active_codebook(&cb);
        assert_eq!(s.active, 3);
        assert_eq!(s.active_codebook(), cb);
        assert_eq!(s.mask.iter().filter(|&&m| m == 1.0).count(), 3);
    }

    #[test]
    fn grow_from_single() {
        let mut rng = Rng::new(5);
        let mut s = CentroidState::init_from_weights(&weights(), 1, 8, &mut rng);
        s.grow_to(4);
        assert_eq!(s.active_codebook().len(), 4);
    }
}
