//! Dynamic cluster-count controller (paper Algorithm 1, line 9).
//!
//! Start at C_min; after each round push the aggregated representation
//! score E into a moving average (window W). When MA(E) fails to improve
//! on the best MA of the previous P rounds, grow C (the model needs
//! more representational headroom than the current codebook affords),
//! clamped to [C_min, C_max]. W = P = 3 per the paper.

use crate::util::stats::MovingAverage;

#[derive(Clone, Debug)]
pub struct ControllerConfig {
    pub c_min: usize,
    pub c_max: usize,
    /// moving-average window W
    pub window: usize,
    /// patience P (rounds of no MA improvement before growing C)
    pub patience: usize,
    /// additive growth step when a plateau is detected
    pub step: usize,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            // C_min=16 keeps the early clustered rounds learnable on the
            // ~20k-param testbed models; the paper leaves C_min unstated
            c_min: 16,
            c_max: 32,
            window: 3,
            patience: 3,
            step: 8,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ClusterController {
    cfg: ControllerConfig,
    ma: MovingAverage,
    c: usize,
    /// rounds since the last growth (growth resets the plateau clock)
    since_growth: usize,
    history: Vec<(f64, usize)>, // (score, C after update)
}

impl ClusterController {
    pub fn new(cfg: ControllerConfig) -> Self {
        assert!(cfg.c_min >= 1 && cfg.c_min <= cfg.c_max);
        assert!(cfg.window >= 1 && cfg.patience >= 1 && cfg.step >= 1);
        let c = cfg.c_min;
        ClusterController {
            ma: MovingAverage::new(cfg.window),
            cfg,
            c,
            since_growth: 0,
            history: Vec::new(),
        }
    }

    pub fn current_c(&self) -> usize {
        self.c
    }

    /// Feed the round's aggregated score; returns the C to use next round.
    pub fn observe(&mut self, score: f64) -> usize {
        self.ma.push(score);
        self.since_growth += 1;

        let t = self.ma.len() - 1;
        // need at least patience+1 MA points since the last growth to judge
        if self.since_growth > self.cfg.patience && t >= self.cfg.patience {
            let current = self.ma.at(t).unwrap();
            let mut best_prev = f64::NEG_INFINITY;
            for j in 1..=self.cfg.patience {
                if let Some(v) = self.ma.at(t - j) {
                    best_prev = best_prev.max(v);
                }
            }
            // no improvement over the recent best -> grow the codebook
            if current <= best_prev && self.c < self.cfg.c_max {
                self.c = (self.c + self.cfg.step).min(self.cfg.c_max);
                self.since_growth = 0;
            }
        }
        self.history.push((score, self.c));
        self.c
    }

    pub fn history(&self) -> &[(f64, usize)] {
        &self.history
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControllerConfig {
        ControllerConfig {
            c_min: 8,
            c_max: 32,
            window: 3,
            patience: 3,
            step: 8,
        }
    }

    #[test]
    fn starts_at_c_min() {
        let c = ClusterController::new(cfg());
        assert_eq!(c.current_c(), 8);
    }

    #[test]
    fn improving_scores_keep_c_fixed() {
        let mut ctl = ClusterController::new(cfg());
        for i in 0..12 {
            ctl.observe(1.0 + i as f64 * 0.5);
        }
        assert_eq!(ctl.current_c(), 8);
    }

    #[test]
    fn plateau_grows_c() {
        let mut ctl = ClusterController::new(cfg());
        for _ in 0..3 {
            ctl.observe(5.0); // warmup
        }
        let mut grew_at = None;
        for i in 0..6 {
            let c = ctl.observe(5.0); // flat
            if c > 8 && grew_at.is_none() {
                grew_at = Some(i);
            }
        }
        assert!(grew_at.is_some(), "plateau never triggered growth");
        // a persistent plateau keeps growing after each patience window
        assert!(ctl.current_c() >= 16 && ctl.current_c() <= 32);
    }

    #[test]
    fn growth_is_clamped_at_c_max() {
        let mut ctl = ClusterController::new(cfg());
        for _ in 0..60 {
            ctl.observe(3.0);
        }
        assert_eq!(ctl.current_c(), 32);
    }

    #[test]
    fn growth_resets_patience_clock() {
        let mut ctl = ClusterController::new(cfg());
        // force one growth
        for _ in 0..8 {
            ctl.observe(2.0);
        }
        let c_after = ctl.current_c();
        assert!(c_after > 8);
        // the very next flat observation must NOT immediately grow again
        let c_next = ctl.observe(2.0);
        assert_eq!(c_next, c_after);
    }

    #[test]
    fn noisy_but_rising_scores_do_not_grow() {
        let mut ctl = ClusterController::new(cfg());
        let scores = [1.0, 1.4, 1.2, 1.8, 1.6, 2.2, 2.0, 2.6, 2.4, 3.0];
        for s in scores {
            ctl.observe(s);
        }
        assert_eq!(ctl.current_c(), 8, "rising trend misread as plateau");
    }

    #[test]
    fn history_records_everything() {
        let mut ctl = ClusterController::new(cfg());
        for i in 0..5 {
            ctl.observe(i as f64);
        }
        assert_eq!(ctl.history().len(), 5);
        assert_eq!(ctl.history()[2].0, 2.0);
    }
}
