//! Representation quality score (paper §1.2, "Dynamic Weight-Clustering").
//!
//! E = exp(-sum_j r_j log r_j) with r_j = sigma_j / ||sigma||_1 the
//! normalized singular values of the embedding matrix Z (N x d) — the
//! *effective rank* of the embeddings. E in [1, min(N, d)]; higher
//! means richer representations. Computed client-side on the unlabeled
//! shard D_u with no labels.

use crate::linalg::{singular_values, Matrix};

/// Numerical-stability epsilon (the paper adds 1e-7 to r_j).
const EPS: f64 = 1e-7;

/// Score from a row-major f32 embedding buffer (n rows x d cols).
pub fn representation_score(embeddings: &[f32], n: usize, d: usize) -> f64 {
    assert_eq!(embeddings.len(), n * d, "embedding buffer shape mismatch");
    if n == 0 || d == 0 {
        return 1.0;
    }
    let z = Matrix::from_f32_rows(embeddings, n, d);
    let sigma = singular_values(&z);
    effective_rank(&sigma)
}

/// exp(entropy) of the normalized singular-value distribution.
pub fn effective_rank(sigma: &[f64]) -> f64 {
    let total: f64 = sigma.iter().sum();
    if total <= 0.0 {
        return 1.0; // all-zero embeddings: rank collapses to 1 by convention
    }
    let mut h = 0.0;
    for &s in sigma {
        let r = s / total + EPS;
        h -= r * r.ln();
    }
    h.exp().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_like_embeddings_have_full_rank() {
        // orthogonal rows with equal norms -> E ~ d
        let d = 8;
        let mut buf = vec![0.0f32; d * d];
        for i in 0..d {
            buf[i * d + i] = 1.0;
        }
        let e = representation_score(&buf, d, d);
        assert!((e - d as f64).abs() < 0.01, "{e}");
    }

    #[test]
    fn rank_one_embeddings_score_one() {
        // every row identical -> single singular direction
        let d = 16;
        let n = 32;
        let row: Vec<f32> = (0..d).map(|j| (j as f32) * 0.1 + 1.0).collect();
        let mut buf = Vec::with_capacity(n * d);
        for _ in 0..n {
            buf.extend_from_slice(&row);
        }
        let e = representation_score(&buf, n, d);
        assert!(e < 1.1, "{e}");
    }

    #[test]
    fn score_monotone_in_spectrum_spread() {
        // flatter spectra -> higher effective rank
        let flat = vec![1.0f64; 10];
        let spiky = {
            let mut v = vec![0.01f64; 10];
            v[0] = 10.0;
            v
        };
        assert!(effective_rank(&flat) > effective_rank(&spiky));
        assert!((effective_rank(&flat) - 10.0).abs() < 0.01);
    }

    #[test]
    fn random_embeddings_between_one_and_d() {
        let mut rng = Rng::new(3);
        let (n, d) = (64, 32);
        let buf: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let e = representation_score(&buf, n, d);
        assert!(e > 1.0 && e <= d as f64 + 1e-9, "{e}");
        // gaussian embeddings are nearly full rank
        assert!(e > d as f64 * 0.7, "{e}");
    }

    #[test]
    fn zero_embeddings_convention() {
        let buf = vec![0.0f32; 10 * 4];
        assert_eq!(representation_score(&buf, 10, 4), 1.0);
    }

    #[test]
    fn score_is_scale_invariant() {
        let mut rng = Rng::new(5);
        let buf: Vec<f32> = (0..20 * 8).map(|_| rng.normal()).collect();
        let scaled: Vec<f32> = buf.iter().map(|x| x * 37.5).collect();
        let a = representation_score(&buf, 20, 8);
        let b = representation_score(&scaled, 20, 8);
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
}
