//! The paper's adaptive-clustering machinery:
//! * `score`      — representation quality score E (effective rank of
//!                  penultimate embeddings, Roy & Vetterli 2007)
//! * `controller` — dynamic cluster-count schedule driven by MA(E)
//! * `centroids`  — codebook/mask management around the AOT C_max table

pub mod centroids;
pub mod controller;
pub mod score;

pub use centroids::CentroidState;
pub use controller::{ClusterController, ControllerConfig};
pub use score::representation_score;
