//! Model-compression substrate: everything that turns a flat f32
//! parameter vector into bytes on the (simulated) wire and back.
//!
//! * `kmeans`    — 1-D Lloyd's algorithm + k-means++ init (codebook fit)
//! * `codec`     — clustered-weight wire format: codebook + bit-packed
//!                 indices (FedCompress's transport)
//! * `huffman`   — canonical Huffman coder over index streams (FedZip's
//!                 extra entropy stage)
//! * `sparsify`  — magnitude pruning (FedZip's first stage)
//! * `accounting`— byte-exact bidirectional communication ledger (CCR)

pub mod accounting;
pub mod codec;
pub mod delta;
pub mod huffman;
pub mod kmeans;
pub mod sparsify;

pub use accounting::CommLedger;
pub use codec::{decode, encode, EncodedModel};
pub use kmeans::{kmeans_1d, kmeans_pp_init};
