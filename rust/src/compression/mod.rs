//! Model-compression substrate: everything that turns a flat f32
//! parameter vector into bytes on the (simulated) wire and back. These
//! primitives surface as registered, composable stages in the
//! first-class codec layer ([`crate::codec`]) — strategies declare
//! pipelines like `topk|kmeans|huffman` instead of calling this module
//! directly.
//!
//! * `kmeans`    — 1-D Lloyd's algorithm + k-means++ init (codebook
//!                 fit; the `kmeans`/`codebook` stages)
//! * `codec`     — clustered-weight wire container: codebook +
//!                 bit-packed or entropy-coded indices
//! * `huffman`   — canonical Huffman coder over index streams (the
//!                 `huffman` stage)
//! * `sparsify`  — magnitude pruning (the `topk` stage)
//! * `delta`     — cross-round residual coding of index streams (the
//!                 `delta` stage)
//! * `accounting`— byte-exact bidirectional communication ledger (CCR)
//!                 with per-codec-stage totals

pub mod accounting;
pub mod codec;
pub mod delta;
pub mod huffman;
pub mod kmeans;
pub mod sparsify;

pub use accounting::CommLedger;
pub use codec::{decode, encode, EncodedModel};
pub use kmeans::{kmeans_1d, kmeans_pp_init};
