//! 1-D k-means (Lloyd) with k-means++ seeding, over flat weight vectors.
//!
//! Used for (a) server-side centroid (re-)initialization each round,
//! (b) FedZip's fixed-C clustering, (c) the final model quantization
//! that MCR measures. Weights are 1-D, so assignment against a *sorted*
//! codebook is a binary search over midpoints — O(P log C).

/// k-means++ seeding over scalar weights. Returns `c` centroids
/// (sorted ascending). Deterministic given the rng.
pub fn kmeans_pp_init(weights: &[f32], c: usize, rng: &mut crate::util::rng::Rng) -> Vec<f32> {
    assert!(c >= 1 && !weights.is_empty());
    let mut centroids = Vec::with_capacity(c);
    centroids.push(weights[rng.below(weights.len())]);
    let mut d2: Vec<f64> = weights
        .iter()
        .map(|&w| {
            let d = (w - centroids[0]) as f64;
            d * d
        })
        .collect();
    while centroids.len() < c {
        let total: f64 = d2.iter().sum();
        let new = if total <= 0.0 {
            // all mass covered (fewer distinct values than c): jitter off
            // an existing centroid so the codebook keeps c distinct slots
            // fedlint:allow(float-order) -- cast of a small integer count, exact in f32
            centroids[rng.below(centroids.len())] + 1e-6 * (centroids.len() as f32)
        } else {
            let mut r = rng.f64() * total;
            let mut pick = weights.len() - 1;
            for (i, &d) in d2.iter().enumerate() {
                r -= d;
                if r <= 0.0 {
                    pick = i;
                    break;
                }
            }
            weights[pick]
        };
        centroids.push(new);
        for (i, &w) in weights.iter().enumerate() {
            let d = (w - new) as f64;
            d2[i] = d2[i].min(d * d);
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centroids
}

/// Assign each weight to the nearest centroid of a *sorted* codebook.
///
/// Single-element form; batch call sites go through
/// [`crate::kernels::assign_nearest`], which is bit-identical to this
/// search on every backend (see the kernels module docs).
#[inline]
pub fn assign_sorted(w: f32, sorted: &[f32]) -> usize {
    // binary search over centroid midpoints
    let mut lo = 0usize;
    let mut hi = sorted.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        let boundary = 0.5 * (sorted[mid] + sorted[mid + 1]);
        if w <= boundary {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Full Lloyd iteration until convergence (or `max_iter`).
/// Returns (sorted centroids, assignments, inertia).
///
/// 1-D fast path (perf pass, EXPERIMENTS.md §Perf): weights are sorted
/// once with prefix sums; each Lloyd iteration then only binary-searches
/// the C-1 cluster *boundaries* in the sorted array and reads segment
/// means off the prefix sums — O(C log P) per iteration instead of
/// O(P log C). ~50-100x faster at federated model sizes, bit-identical
/// assignments.
pub fn kmeans_1d(
    weights: &[f32],
    c: usize,
    max_iter: usize,
    rng: &mut crate::util::rng::Rng,
) -> (Vec<f32>, Vec<u32>, f64) {
    let p = weights.len();
    let mut centroids = kmeans_pp_init(weights, c, rng);

    // sort weights once; prefix sums of w and w^2 over the sorted order
    let mut sorted = weights.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut pre_w = vec![0.0f64; p + 1];
    let mut pre_w2 = vec![0.0f64; p + 1];
    for (i, &w) in sorted.iter().enumerate() {
        pre_w[i + 1] = pre_w[i] + w as f64;
        pre_w2[i + 1] = pre_w2[i] + (w as f64) * (w as f64);
    }
    // segment start index for each cluster (cluster j owns [seg[j], seg[j+1]))
    let mut seg = vec![0usize; c + 1];
    seg[c] = p;

    let mut inertia = f64::MAX;
    for _ in 0..max_iter {
        // boundaries: first sorted index whose value exceeds the midpoint
        for j in 1..c {
            let boundary = 0.5 * (centroids[j - 1] + centroids[j]);
            seg[j] = sorted.partition_point(|&w| w <= boundary);
        }
        // segment means + inertia via prefix sums
        let mut new_inertia = 0.0f64;
        for j in 0..c {
            let (lo, hi) = (seg[j], seg[j + 1]);
            if hi > lo {
                let n = (hi - lo) as f64;
                let s = pre_w[hi] - pre_w[lo];
                let s2 = pre_w2[hi] - pre_w2[lo];
                let mean = s / n;
                // fedlint:allow(float-order) -- deliberate single narrowing: means accumulate in f64, land in the f32 codebook
                centroids[j] = mean as f32;
                new_inertia += s2 - 2.0 * mean * s + n * mean * mean;
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let converged = (inertia - new_inertia).abs() <= 1e-12 * (1.0 + inertia.abs());
        inertia = new_inertia;
        if converged {
            break;
        }
    }

    // final assignment of the ORIGINAL (unsorted) weights
    let mut assignments = vec![0u32; p];
    crate::kernels::assign_nearest(weights, &centroids, &mut assignments);
    let mut final_inertia = 0.0;
    for (&w, &j) in weights.iter().zip(&assignments) {
        let d = (w - centroids[j as usize]) as f64;
        final_inertia += d * d;
    }
    (centroids, assignments, final_inertia)
}

/// Quantize weights in place against a sorted codebook; returns indices.
pub fn snap(weights: &mut [f32], sorted_codebook: &[f32]) -> Vec<u32> {
    crate::kernels::snap_to_codebook(weights, sorted_codebook)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn assign_sorted_picks_nearest() {
        let cb = [-1.0f32, 0.0, 2.0];
        assert_eq!(assign_sorted(-3.0, &cb), 0);
        assert_eq!(assign_sorted(-0.6, &cb), 0);
        assert_eq!(assign_sorted(-0.49, &cb), 1);
        assert_eq!(assign_sorted(-0.4, &cb), 1);
        assert_eq!(assign_sorted(0.9, &cb), 1);
        assert_eq!(assign_sorted(1.1, &cb), 2);
        assert_eq!(assign_sorted(9.0, &cb), 2);
    }

    #[test]
    fn exact_clusters_recovered() {
        // three tight blobs -> centroids land on blob means
        let mut rng = Rng::new(5);
        let mut w = Vec::new();
        for &center in &[-2.0f32, 0.5, 3.0] {
            for _ in 0..200 {
                w.push(center + rng.normal() * 0.01);
            }
        }
        let (cb, asg, inertia) = kmeans_1d(&w, 3, 50, &mut rng);
        assert!((cb[0] + 2.0).abs() < 0.01, "{cb:?}");
        assert!((cb[1] - 0.5).abs() < 0.01);
        assert!((cb[2] - 3.0).abs() < 0.01);
        assert!(inertia / (w.len() as f64) < 1e-3);
        assert_eq!(asg.len(), w.len());
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let mut rng = Rng::new(6);
        let w: Vec<f32> = (0..2000).map(|_| rng.normal()).collect();
        let mut last = f64::MAX;
        for c in [2usize, 4, 8, 16, 32] {
            let (_, _, inertia) = kmeans_1d(&w, c, 30, &mut rng);
            assert!(inertia < last, "c={c}: {inertia} !< {last}");
            last = inertia;
        }
    }

    #[test]
    fn assignment_is_optimal_property() {
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..500).map(|_| rng.normal() * 2.0).collect();
        let (cb, asg, _) = kmeans_1d(&w, 8, 30, &mut rng);
        for (i, &wi) in w.iter().enumerate() {
            let d_assigned = (wi - cb[asg[i] as usize]).abs();
            for &c in &cb {
                assert!(d_assigned <= (wi - c).abs() + 1e-6);
            }
        }
    }

    #[test]
    fn degenerate_fewer_distinct_values_than_clusters() {
        let w = vec![1.0f32; 100];
        let mut rng = Rng::new(8);
        let (cb, asg, inertia) = kmeans_1d(&w, 4, 10, &mut rng);
        assert_eq!(cb.len(), 4);
        assert!(inertia < 1e-9);
        // all assigned to some centroid equal to 1.0 (+jitter)
        assert!(asg.iter().all(|&j| (cb[j as usize] - 1.0).abs() < 1e-3));
    }

    #[test]
    fn snap_is_idempotent() {
        let mut rng = Rng::new(9);
        let mut w: Vec<f32> = (0..300).map(|_| rng.normal()).collect();
        let (cb, _, _) = kmeans_1d(&w, 8, 30, &mut rng);
        let idx1 = snap(&mut w, &cb);
        let w1 = w.clone();
        let idx2 = snap(&mut w, &cb);
        assert_eq!(idx1, idx2);
        assert_eq!(w, w1);
    }
}
