//! Magnitude sparsification — FedZip's first stage (Malekijoo 2021
//! prunes with top-z magnitude selection before clustering).

use crate::kernels;

/// Zero out all but the top `keep_fraction` of weights by |magnitude|.
/// Returns the number of survivors. Deterministic tie handling.
///
/// Magnitudes are ordered by [`kernels::magnitude_key`] — the total
/// order `f32::total_cmp` induces on `|w|` — so non-finite input never
/// panics: infinities and NaNs rank as the largest magnitudes and
/// survive pruning. For finite weights the order (and therefore the
/// survivor set and wire bytes) is identical to the old
/// `partial_cmp`-based selection.
pub fn magnitude_prune(weights: &mut [f32], keep_fraction: f64) -> usize {
    assert!((0.0..=1.0).contains(&keep_fraction));
    let n = weights.len();
    let keep = ((n as f64) * keep_fraction).round() as usize;
    if keep >= n {
        return n;
    }
    if keep == 0 {
        weights.iter_mut().for_each(|w| *w = 0.0);
        return 0;
    }
    // threshold = keep-th largest magnitude key via select_nth on a copy
    let keys = kernels::magnitude_keys(weights);
    let mut sorted_keys = keys.clone();
    let kth = n - keep;
    sorted_keys.select_nth_unstable(kth);
    let threshold = sorted_keys[kth];

    // keep strictly-above first, then fill ties deterministically
    let survivors = kernels::threshold_count(&keys, threshold);
    let mut ties_to_keep = keep.saturating_sub(survivors);
    for (w, &k) in weights.iter_mut().zip(&keys) {
        if k > threshold {
            continue;
        }
        if k == threshold && ties_to_keep > 0 {
            ties_to_keep -= 1;
            continue;
        }
        *w = 0.0;
    }
    weights.iter().filter(|w| **w != 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_exactly_the_fraction() {
        let mut rng = Rng::new(1);
        let mut w: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let kept = magnitude_prune(&mut w, 0.3);
        let nonzero = w.iter().filter(|x| **x != 0.0).count();
        assert_eq!(kept, nonzero);
        assert!((295..=305).contains(&kept), "{kept}");
    }

    #[test]
    fn keeps_the_largest() {
        let mut w = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        magnitude_prune(&mut w, 0.5);
        assert_eq!(w, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn extremes() {
        let mut w = vec![1.0f32, 2.0, 3.0];
        assert_eq!(magnitude_prune(&mut w, 1.0), 3);
        assert_eq!(w, vec![1.0, 2.0, 3.0]);
        assert_eq!(magnitude_prune(&mut w, 0.0), 0);
        assert_eq!(w, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn ties_handled_deterministically() {
        let mut w = vec![1.0f32; 10];
        let kept = magnitude_prune(&mut w, 0.5);
        assert_eq!(kept, 5);
        assert_eq!(w.iter().filter(|x| **x != 0.0).count(), 5);
    }

    #[test]
    fn all_equal_magnitudes_keep_the_budget_exactly() {
        // mixed signs, same |w|: the whole slice is one tie class
        let mut w: Vec<f32> = (0..12).map(|i| if i % 2 == 0 { 2.5 } else { -2.5 }).collect();
        let kept = magnitude_prune(&mut w, 0.25);
        assert_eq!(kept, 3);
        // survivors keep their original signed values
        assert!(w.iter().filter(|x| **x != 0.0).all(|x| x.abs() == 2.5));
    }

    #[test]
    fn empty_and_exact_fraction_boundaries() {
        let mut empty: Vec<f32> = vec![];
        assert_eq!(magnitude_prune(&mut empty, 0.5), 0);
        let mut w = vec![3.0f32, 1.0, 2.0, 4.0];
        assert_eq!(magnitude_prune(&mut w, 1.0), 4);
        assert_eq!(w, vec![3.0, 1.0, 2.0, 4.0]);
    }

    #[test]
    fn non_finite_weights_never_panic_and_rank_largest() {
        // total_cmp magnitude order: NaN and inf outrank every finite
        // weight, so they survive; the smallest finite ones are cut
        let mut w = vec![1.0f32, f32::NAN, -2.0, f32::INFINITY, 0.5, -0.25];
        let kept = magnitude_prune(&mut w, 0.5);
        assert_eq!(kept, 3);
        assert!(w[1].is_nan());
        assert_eq!(w[3], f32::INFINITY);
        assert_eq!(w[2], -2.0);
        assert_eq!((w[0], w[4], w[5]), (0.0, 0.0, 0.0));

        let mut all_nan = vec![f32::NAN; 4];
        assert_eq!(magnitude_prune(&mut all_nan, 0.5), 2);
        assert_eq!(all_nan.iter().filter(|x| x.is_nan()).count(), 2);
    }
}
