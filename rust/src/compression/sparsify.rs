//! Magnitude sparsification — FedZip's first stage (Malekijoo 2021
//! prunes with top-z magnitude selection before clustering).

/// Zero out all but the top `keep_fraction` of weights by |magnitude|.
/// Returns the number of survivors. Deterministic tie handling.
pub fn magnitude_prune(weights: &mut [f32], keep_fraction: f64) -> usize {
    assert!((0.0..=1.0).contains(&keep_fraction));
    let n = weights.len();
    let keep = ((n as f64) * keep_fraction).round() as usize;
    if keep >= n {
        return n;
    }
    if keep == 0 {
        weights.iter_mut().for_each(|w| *w = 0.0);
        return 0;
    }
    // threshold = keep-th largest |w| via select_nth on a copy
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    let kth = n - keep;
    mags.select_nth_unstable_by(kth, |a, b| a.partial_cmp(b).unwrap());
    let threshold = mags[kth];

    // keep strictly-above first, then fill ties deterministically
    let mut survivors = 0usize;
    for w in weights.iter() {
        if w.abs() > threshold {
            survivors += 1;
        }
    }
    let mut ties_to_keep = keep.saturating_sub(survivors);
    for w in weights.iter_mut() {
        let m = w.abs();
        if m > threshold {
            continue;
        }
        if m == threshold && ties_to_keep > 0 {
            ties_to_keep -= 1;
            continue;
        }
        *w = 0.0;
    }
    weights.iter().filter(|w| **w != 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_exactly_the_fraction() {
        let mut rng = Rng::new(1);
        let mut w: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let kept = magnitude_prune(&mut w, 0.3);
        let nonzero = w.iter().filter(|x| **x != 0.0).count();
        assert_eq!(kept, nonzero);
        assert!((295..=305).contains(&kept), "{kept}");
    }

    #[test]
    fn keeps_the_largest() {
        let mut w = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        magnitude_prune(&mut w, 0.5);
        assert_eq!(w, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn extremes() {
        let mut w = vec![1.0f32, 2.0, 3.0];
        assert_eq!(magnitude_prune(&mut w, 1.0), 3);
        assert_eq!(w, vec![1.0, 2.0, 3.0]);
        assert_eq!(magnitude_prune(&mut w, 0.0), 0);
        assert_eq!(w, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn ties_handled_deterministically() {
        let mut w = vec![1.0f32; 10];
        let kept = magnitude_prune(&mut w, 0.5);
        assert_eq!(kept, 5);
        assert_eq!(w.iter().filter(|x| **x != 0.0).count(), 5);
    }
}
