//! Byte-exact bidirectional communication ledger.
//!
//! CCR in Table 1 is `total_bytes(FedAvg) / total_bytes(method)` over a
//! full training run, counting every server->client dispatch and every
//! client->server upload. The ledger records each transfer with its
//! direction and round so experiment drivers can reproduce both the
//! totals and per-round traces.
//!
//! Each transfer carries two byte counts:
//!
//! * `bytes` — the *ideal* payload size (what the paper's accounting
//!   counts, and what CCR/MCR are computed from);
//! * `framed_bytes` — what the framed TCP protocol (`net`) actually
//!   puts on the socket for that transfer: payload plus the per-message
//!   protocol overhead (frame header + message header + fixed
//!   sidecars). The in-process transport records the same number, so
//!   ledgers are backend-independent; round-control and centroid-table
//!   traffic is tracked separately by the TCP transport
//!   (`net::TcpTransport::control_bytes`).

use std::collections::BTreeMap;

use crate::codec::StageBytes;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// server -> client (model dispatch)
    Down,
    /// client -> server (update upload)
    Up,
}

#[derive(Clone, Debug)]
pub struct Transfer {
    pub round: usize,
    pub direction: Direction,
    /// ideal payload bytes (CCR numerator/denominator material)
    pub bytes: usize,
    /// payload + protocol overhead on the framed wire
    pub framed_bytes: usize,
}

/// Per-stage byte totals across a run, one per direction. `bytes[i]`
/// of a stage is "what the stream would have cost had the pipeline
/// stopped there", so totals read as a compression trace, not an
/// additive decomposition (the *last* stage's total equals the
/// direction's ideal bytes for pipeline-encoded transfers).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageTotal {
    pub down: usize,
    pub up: usize,
}

#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    transfers: Vec<Transfer>,
    /// Codec-stage breakdown (runtime observability; not persisted in
    /// run records — the record carries the codec spec instead).
    stage_totals: BTreeMap<String, StageTotal>,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, round: usize, direction: Direction, bytes: usize, framed: usize) {
        debug_assert!(framed >= bytes, "framed bytes cannot undercut the payload");
        self.transfers.push(Transfer {
            round,
            direction,
            bytes,
            framed_bytes: framed,
        });
    }

    /// Fold one blob's per-stage breakdown into the run totals.
    pub fn record_stages(&mut self, direction: Direction, stages: &[StageBytes]) {
        for s in stages {
            let t = self.stage_totals.entry(s.stage.clone()).or_default();
            match direction {
                Direction::Down => t.down += s.bytes,
                Direction::Up => t.up += s.bytes,
            }
        }
    }

    /// Per-stage byte totals, keyed by stage name.
    pub fn stage_totals(&self) -> &BTreeMap<String, StageTotal> {
        &self.stage_totals
    }

    /// One-line per-stage summary (empty string when nothing was
    /// pipeline-encoded).
    pub fn render_stage_totals(&self) -> String {
        let mut parts = Vec::with_capacity(self.stage_totals.len());
        for (stage, t) in &self.stage_totals {
            parts.push(format!("{stage}: down {} B / up {} B", t.down, t.up));
        }
        parts.join(", ")
    }

    pub fn transfers(&self) -> &[Transfer] {
        &self.transfers
    }

    pub fn total_bytes(&self) -> usize {
        self.transfers.iter().map(|t| t.bytes).sum()
    }

    /// Total bytes on the framed wire (payload + protocol overhead).
    pub fn total_framed_bytes(&self) -> usize {
        self.transfers.iter().map(|t| t.framed_bytes).sum()
    }

    pub fn bytes_in(&self, direction: Direction) -> usize {
        self.transfers
            .iter()
            .filter(|t| t.direction == direction)
            .map(|t| t.bytes)
            .sum()
    }

    pub fn framed_in(&self, direction: Direction) -> usize {
        self.transfers
            .iter()
            .filter(|t| t.direction == direction)
            .map(|t| t.framed_bytes)
            .sum()
    }

    pub fn round_bytes(&self, round: usize) -> usize {
        self.transfers
            .iter()
            .filter(|t| t.round == round)
            .map(|t| t.bytes)
            .sum()
    }

    pub fn transfer_count(&self) -> usize {
        self.transfers.len()
    }

    /// Per-round byte totals as a series (for the communication trace).
    pub fn per_round(&self, rounds: usize) -> Vec<usize> {
        let mut v = vec![0usize; rounds];
        for t in &self.transfers {
            if t.round < rounds {
                v[t.round] += t.bytes;
            }
        }
        v
    }
}

/// CCR versus a baseline ledger (paper's headline metric).
pub fn ccr(baseline: &CommLedger, method: &CommLedger) -> f64 {
    baseline.total_bytes() as f64 / method.total_bytes().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_directions() {
        let mut l = CommLedger::new();
        l.record(0, Direction::Down, 100, 124);
        l.record(0, Direction::Up, 40, 80);
        l.record(1, Direction::Down, 100, 124);
        l.record(1, Direction::Up, 30, 70);
        assert_eq!(l.total_bytes(), 270);
        assert_eq!(l.bytes_in(Direction::Down), 200);
        assert_eq!(l.bytes_in(Direction::Up), 70);
        assert_eq!(l.round_bytes(1), 130);
        assert_eq!(l.per_round(2), vec![140, 130]);
    }

    #[test]
    fn framed_totals_ride_alongside_ideal_bytes() {
        let mut l = CommLedger::new();
        l.record(0, Direction::Down, 1000, 1024);
        l.record(0, Direction::Up, 250, 290);
        assert_eq!(l.total_framed_bytes(), 1314);
        assert_eq!(l.framed_in(Direction::Down), 1024);
        assert_eq!(l.framed_in(Direction::Up), 290);
        // framed >= ideal on every entry, and the overhead is visible
        for t in l.transfers() {
            assert!(t.framed_bytes >= t.bytes);
            assert!(t.framed_bytes - t.bytes <= 64);
        }
        // the ideal totals are untouched by framing
        assert_eq!(l.total_bytes(), 1250);
    }

    #[test]
    fn stage_totals_accumulate_per_direction() {
        let mut l = CommLedger::new();
        let stages = |a: usize, b: usize| {
            vec![
                StageBytes {
                    stage: "topk".to_string(),
                    bytes: a,
                },
                StageBytes {
                    stage: "huffman".to_string(),
                    bytes: b,
                },
            ]
        };
        l.record_stages(Direction::Up, &stages(100, 40));
        l.record_stages(Direction::Up, &stages(110, 42));
        l.record_stages(Direction::Down, &stages(50, 20));
        let t = l.stage_totals();
        assert_eq!(t["topk"], StageTotal { down: 50, up: 210 });
        assert_eq!(t["huffman"], StageTotal { down: 20, up: 82 });
        let rendered = l.render_stage_totals();
        assert!(rendered.contains("topk: down 50 B / up 210 B"), "{rendered}");
        assert_eq!(CommLedger::new().render_stage_totals(), "");
    }

    #[test]
    fn ccr_ratio() {
        let mut base = CommLedger::new();
        base.record(0, Direction::Down, 1000, 1000);
        let mut m = CommLedger::new();
        m.record(0, Direction::Down, 250, 250);
        assert!((ccr(&base, &m) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_method_ledger_does_not_divide_by_zero() {
        let mut base = CommLedger::new();
        base.record(0, Direction::Down, 10, 10);
        let m = CommLedger::new();
        assert!(ccr(&base, &m).is_finite());
    }
}
