//! Canonical Huffman coder over small alphabets (cluster indices).
//!
//! FedZip's entropy stage: after pruning + clustering, index streams are
//! heavily skewed (the zero cluster dominates), so Huffman beats flat
//! bit-packing. Canonical form keeps the serialized table tiny: one
//! code length per symbol.

use crate::util::bitio::{BitReader, BitWriter};
use anyhow::{bail, Result};

/// Build canonical code lengths for `freqs` (package-merge-free simple
/// Huffman; alphabet <= 256 so the O(n^2) heapless build is fine).
/// Symbols with zero frequency get length 0 (absent).
pub fn code_lengths(freqs: &[u64]) -> Vec<u8> {
    let n = freqs.len();
    let present: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    let mut lengths = vec![0u8; n];
    match present.len() {
        0 => return lengths,
        1 => {
            lengths[present[0]] = 1;
            return lengths;
        }
        _ => {}
    }

    // nodes: (weight, id); internal nodes get ids >= n
    #[derive(Clone)]
    struct Node {
        weight: u64,
        left: Option<usize>,
        right: Option<usize>,
        symbol: Option<usize>,
    }
    let mut nodes: Vec<Node> = present
        .iter()
        .map(|&s| Node {
            weight: freqs[s],
            left: None,
            right: None,
            symbol: Some(s),
        })
        .collect();
    let mut heap: Vec<usize> = (0..nodes.len()).collect();

    while heap.len() > 1 {
        // pick two smallest (linear scan; alphabet tiny)
        heap.sort_by_key(|&i| std::cmp::Reverse(nodes[i].weight));
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        let parent = Node {
            weight: nodes[a].weight + nodes[b].weight,
            left: Some(a),
            right: Some(b),
            symbol: None,
        };
        nodes.push(parent);
        heap.push(nodes.len() - 1);
    }

    // DFS to get depths
    let root = heap[0];
    let mut stack = vec![(root, 0u8)];
    while let Some((i, depth)) = stack.pop() {
        if let Some(s) = nodes[i].symbol {
            lengths[s] = depth.max(1);
        } else {
            stack.push((nodes[i].left.unwrap(), depth + 1));
            stack.push((nodes[i].right.unwrap(), depth + 1));
        }
    }
    lengths
}

/// Canonical codes from lengths: symbols sorted by (length, symbol).
/// Returns (code, length) per symbol; length 0 = absent.
pub fn canonical_codes(lengths: &[u8]) -> Vec<(u32, u8)> {
    let mut order: Vec<usize> = (0..lengths.len()).filter(|&i| lengths[i] > 0).collect();
    order.sort_by_key(|&i| (lengths[i], i));
    let mut codes = vec![(0u32, 0u8); lengths.len()];
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &s in &order {
        code <<= lengths[s] - prev_len;
        codes[s] = (code, lengths[s]);
        prev_len = lengths[s];
        code += 1;
    }
    codes
}

/// Encoded stream: canonical table (lengths) + MSB-first code bits.
pub struct HuffmanEncoded {
    pub lengths: Vec<u8>,
    pub payload: Vec<u8>,
    pub n_symbols: usize,
    pub payload_bits: usize,
}

impl HuffmanEncoded {
    /// Wire size in bytes: 1 length byte per alphabet symbol + payload.
    pub fn wire_bytes(&self) -> usize {
        self.lengths.len() + self.payload_bits.div_ceil(8) + 8 // + u64 count
    }
}

pub fn huffman_encode(symbols: &[u32], alphabet: usize) -> HuffmanEncoded {
    // frequency pass through the kernel waist; the variable-width bit
    // emission below is order-dependent and stays scalar
    let freqs = crate::kernels::histogram_u32(symbols, alphabet);
    let lengths = code_lengths(&freqs);
    let codes = canonical_codes(&lengths);
    // Precompute bit-reversed codes so each symbol is ONE BitWriter
    // call: the writer is LSB-first, canonical decoding reads MSB-first,
    // and reversing the code bridges the two (perf pass §Perf).
    let rev: Vec<(u32, u32)> = codes
        .iter()
        .map(|&(code, len)| {
            if len == 0 {
                (0, 0)
            } else {
                (code.reverse_bits() >> (32 - len as u32), len as u32)
            }
        })
        .collect();
    let mut w = BitWriter::new();
    for &s in symbols {
        let (code, len) = rev[s as usize];
        w.write(code, len);
    }
    let payload_bits = w.bit_len();
    HuffmanEncoded {
        lengths,
        payload: w.into_bytes(),
        n_symbols: symbols.len(),
        payload_bits,
    }
}

pub fn huffman_decode(enc: &HuffmanEncoded) -> Result<Vec<u32>> {
    // Canonical limit/base decoding (perf pass, EXPERIMENTS.md §Perf):
    // per code length L keep the largest canonical code (`limit[L]`) and
    // the symbol-table offset of the first code of that length
    // (`base[L]`); decoding a symbol is then one compare per bit and one
    // array index at the end — O(code length), no table scan.
    let max_len = *enc.lengths.iter().max().unwrap_or(&0) as usize;
    if max_len == 0 {
        if enc.n_symbols == 0 {
            return Ok(Vec::new());
        }
        bail!("empty code table with nonempty stream");
    }
    if max_len > 32 {
        bail!("code length overflow (corrupt table)");
    }

    // symbols ordered canonically: by (length, symbol id)
    let mut order: Vec<usize> = (0..enc.lengths.len())
        .filter(|&s| enc.lengths[s] > 0)
        .collect();
    order.sort_by_key(|&s| (enc.lengths[s], s));

    // first_code[l], limit[l] (largest code of length l), base[l]
    // (index into `order` of the first symbol of length l)
    let mut count = vec![0u32; max_len + 1];
    for &s in &order {
        count[enc.lengths[s] as usize] += 1;
    }
    let mut first_code = vec![0u32; max_len + 2];
    let mut base = vec![0u32; max_len + 1];
    let mut code = 0u32;
    let mut idx = 0u32;
    for l in 1..=max_len {
        first_code[l] = code;
        base[l] = idx;
        code = code.wrapping_add(count[l]);
        idx += count[l];
        code <<= 1;
    }

    let mut r = BitReader::new(&enc.payload);
    let mut out = Vec::with_capacity(enc.n_symbols);
    for _ in 0..enc.n_symbols {
        let mut code = 0u32;
        let mut len = 0usize;
        loop {
            let bit = match r.read_bit() {
                Some(b) => b,
                None => bail!("truncated huffman stream"),
            };
            code = (code << 1) | bit as u32;
            len += 1;
            if len > max_len {
                bail!("invalid code (corrupt stream)");
            }
            // valid iff code falls inside this length's canonical range
            let offset = code.wrapping_sub(first_code[len]);
            if count[len] > 0 && offset < count[len] {
                out.push(order[(base[len] + offset) as usize] as u32);
                break;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_skewed() {
        let mut rng = Rng::new(1);
        let symbols: Vec<u32> = (0..5000)
            .map(|_| rng.categorical(&[80.0, 10.0, 5.0, 3.0, 2.0]) as u32)
            .collect();
        let enc = huffman_encode(&symbols, 5);
        let dec = huffman_decode(&enc).unwrap();
        assert_eq!(symbols, dec);
    }

    #[test]
    fn skewed_beats_flat_packing() {
        let mut rng = Rng::new(2);
        let symbols: Vec<u32> = (0..20_000)
            .map(|_| {
                rng.categorical(&[900.0, 30.0, 20.0, 15.0, 10.0, 10.0, 10.0, 5.0]) as u32
            })
            .collect();
        let enc = huffman_encode(&symbols, 8);
        let flat_bits = symbols.len() * 3; // log2(8)
        assert!(
            enc.payload_bits < flat_bits / 2,
            "{} vs {}",
            enc.payload_bits,
            flat_bits
        );
        assert_eq!(huffman_decode(&enc).unwrap(), symbols);
    }

    #[test]
    fn uniform_close_to_flat() {
        let mut rng = Rng::new(3);
        let symbols: Vec<u32> = (0..8192).map(|_| rng.below(16) as u32).collect();
        let enc = huffman_encode(&symbols, 16);
        let flat_bits = symbols.len() * 4;
        assert!(enc.payload_bits <= flat_bits + flat_bits / 10);
        assert_eq!(huffman_decode(&enc).unwrap(), symbols);
    }

    #[test]
    fn single_symbol_alphabet() {
        let symbols = vec![3u32; 100];
        let enc = huffman_encode(&symbols, 8);
        assert_eq!(enc.payload_bits, 100); // 1 bit per symbol minimum
        assert_eq!(huffman_decode(&enc).unwrap(), symbols);
    }

    /// Edge case: a stream whose alphabet has exactly one *distinct*
    /// symbol present must round-trip cleanly — the canonical table
    /// degenerates to a single length-1 code, never a panic — and an
    /// absent-symbol table row stays 0 (no phantom codes).
    #[test]
    fn single_distinct_symbol_stream_round_trips() {
        for n in [1usize, 7, 4096] {
            let symbols = vec![0u32; n];
            let enc = huffman_encode(&symbols, 16);
            assert_eq!(enc.lengths[0], 1, "present symbol gets a real code");
            assert!(enc.lengths[1..].iter().all(|&l| l == 0), "absent = 0");
            assert_eq!(enc.payload_bits, n);
            assert_eq!(huffman_decode(&enc).unwrap(), symbols);
        }
    }

    #[test]
    fn empty_stream() {
        let enc = huffman_encode(&[], 4);
        assert_eq!(huffman_decode(&enc).unwrap(), Vec::<u32>::new());
    }

    /// Edge case: empty input over an empty alphabet is a 0-byte table
    /// and a 0-bit payload — encode and decode both succeed, and a
    /// *nonempty* claimed stream over an empty table is a typed error,
    /// never a panic or a bogus decode.
    #[test]
    fn empty_input_is_zero_byte_table_not_a_panic() {
        let enc = huffman_encode(&[], 0);
        assert!(enc.lengths.is_empty());
        assert_eq!(enc.payload_bits, 0);
        assert_eq!(enc.wire_bytes(), 8); // just the u64 count slot
        assert_eq!(huffman_decode(&enc).unwrap(), Vec::<u32>::new());

        let lying = HuffmanEncoded {
            lengths: Vec::new(),
            payload: Vec::new(),
            n_symbols: 5,
            payload_bits: 0,
        };
        let err = huffman_decode(&lying).unwrap_err().to_string();
        assert!(err.contains("empty code table"), "{err}");
    }

    #[test]
    fn kraft_inequality_holds() {
        // property: sum(2^-len) <= 1 for every generated code
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let k = 2 + rng.below(30);
            let freqs: Vec<u64> = (0..k).map(|_| rng.below(1000) as u64).collect();
            let lengths = code_lengths(&freqs);
            let kraft: f64 = lengths
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 2.0f64.powi(-(l as i32)))
                .sum();
            assert!(kraft <= 1.0 + 1e-12, "kraft {kraft}");
        }
    }

    #[test]
    fn prefix_free_property() {
        let freqs = [50u64, 20, 10, 8, 6, 4, 2];
        let codes = canonical_codes(&code_lengths(&freqs));
        for (i, &(ci, li)) in codes.iter().enumerate() {
            for (j, &(cj, lj)) in codes.iter().enumerate() {
                if i == j || li == 0 || lj == 0 || li > lj {
                    continue;
                }
                assert_ne!(cj >> (lj - li), ci, "code {i} prefixes {j}");
            }
        }
    }

    #[test]
    fn truncated_stream_errors() {
        let symbols: Vec<u32> = (0..100).map(|i| (i % 4) as u32).collect();
        let mut enc = huffman_encode(&symbols, 4);
        enc.payload.truncate(enc.payload.len() / 2);
        assert!(huffman_decode(&enc).is_err());
    }
}
