//! Delta transport (extension beyond the paper, DESIGN.md §4 A-series):
//! when consecutive rounds share most cluster assignments, sending only
//! the *changed* indices (position-delta + new index) beats re-sending
//! the full stream. The encoder picks whichever is smaller and flags it,
//! so the receiver is format-agnostic. This is the natural next step the
//! paper's conclusion gestures at for the downstream channel.
//!
//! Wired into the codec layer as the registered `delta` stage
//! ([`crate::codec::stages::DeltaStage`]): `codebook|delta` ships
//! residuals against the previous round's blob on the same stream and
//! crosses the TCP transport like any other registered codec.

use anyhow::{bail, Result};

use crate::util::bitio::{BitReader, BitWriter};

/// Encode the difference between two assignment streams of equal length
/// over a `c`-symbol alphabet. Returns None when delta would not beat
/// the dense stream (caller then ships the dense encoding).
pub fn delta_encode(prev: &[u32], cur: &[u32], c: usize) -> Option<Vec<u8>> {
    assert_eq!(prev.len(), cur.len());
    let idx_bits = crate::compression::codec::index_bits(c);
    // positions are gap-coded with a fixed width chosen from the largest gap
    let changes: Vec<(usize, u32)> = prev
        .iter()
        .zip(cur)
        .enumerate()
        .filter(|(_, (a, b))| a != b)
        .map(|(i, (_, &b))| (i, b))
        .collect();
    if changes.is_empty() {
        // header-only blob
        let mut w = BitWriter::new();
        w.write(0, 32);
        return Some(w.into_bytes());
    }
    let mut max_gap = changes[0].0;
    for win in changes.windows(2) {
        max_gap = max_gap.max(win[1].0 - win[0].0);
    }
    let gap_bits = (usize::BITS - max_gap.max(1).leading_zeros()).max(1);
    let total_bits =
        32 + 8 + changes.len() * (gap_bits as usize + idx_bits as usize);
    let dense_bits = cur.len() * idx_bits as usize;
    if total_bits >= dense_bits {
        return None;
    }

    let mut w = BitWriter::new();
    w.write(changes.len() as u32, 32);
    w.write(gap_bits, 8);
    let mut last = 0usize;
    for (i, (pos, val)) in changes.iter().enumerate() {
        let gap = if i == 0 { *pos } else { pos - last };
        w.write(gap as u32, gap_bits);
        w.write(*val, idx_bits);
        last = *pos;
    }
    Some(w.into_bytes())
}

/// Apply a delta blob on top of the previous stream.
pub fn delta_decode(prev: &[u32], blob: &[u8], c: usize) -> Result<Vec<u32>> {
    let idx_bits = crate::compression::codec::index_bits(c);
    let mut r = BitReader::new(blob);
    let n_changes = match r.read(32) {
        Some(n) => n as usize,
        None => bail!("truncated delta header"),
    };
    let mut cur = prev.to_vec();
    if n_changes == 0 {
        return Ok(cur);
    }
    let gap_bits = match r.read(8) {
        Some(g) if (1..=32).contains(&g) => g,
        _ => bail!("bad gap width"),
    };
    let mut pos = 0usize;
    for i in 0..n_changes {
        let gap = r
            .read(gap_bits)
            .ok_or_else(|| anyhow::anyhow!("truncated gaps"))? as usize;
        let val = r
            .read(idx_bits)
            .ok_or_else(|| anyhow::anyhow!("truncated values"))?;
        pos = if i == 0 { gap } else { pos + gap };
        if pos >= cur.len() {
            bail!("delta position {pos} out of range");
        }
        if val as usize >= c {
            bail!("delta value out of alphabet");
        }
        cur[pos] = val;
    }
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_streams_cost_header_only() {
        let s: Vec<u32> = (0..1000).map(|i| (i % 16) as u32).collect();
        let blob = delta_encode(&s, &s, 16).unwrap();
        assert!(blob.len() <= 4);
        assert_eq!(delta_decode(&s, &blob, 16).unwrap(), s);
    }

    #[test]
    fn sparse_changes_beat_dense() {
        let mut rng = Rng::new(1);
        let prev: Vec<u32> = (0..20_000).map(|_| rng.below(16) as u32).collect();
        let mut cur = prev.clone();
        for _ in 0..200 {
            let i = rng.below(cur.len());
            cur[i] = rng.below(16) as u32;
        }
        let blob = delta_encode(&prev, &cur, 16).expect("should beat dense");
        let dense_bytes = 20_000 * 4 / 8;
        assert!(blob.len() < dense_bytes / 4, "{}", blob.len());
        assert_eq!(delta_decode(&prev, &blob, 16).unwrap(), cur);
    }

    #[test]
    fn dense_changes_fall_back() {
        let mut rng = Rng::new(2);
        let prev: Vec<u32> = (0..1000).map(|_| rng.below(16) as u32).collect();
        let cur: Vec<u32> = (0..1000).map(|_| rng.below(16) as u32).collect();
        // ~94% positions differ: delta must decline
        assert!(delta_encode(&prev, &cur, 16).is_none());
    }

    #[test]
    fn random_roundtrip_property() {
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let n = 1 + rng.below(5000);
            let c = 2 + rng.below(31);
            let prev: Vec<u32> = (0..n).map(|_| rng.below(c) as u32).collect();
            let mut cur = prev.clone();
            let flips = rng.below(n / 4 + 1);
            for _ in 0..flips {
                let i = rng.below(n);
                cur[i] = rng.below(c) as u32;
            }
            if let Some(blob) = delta_encode(&prev, &cur, c) {
                assert_eq!(delta_decode(&prev, &blob, c).unwrap(), cur);
            }
        }
    }

    #[test]
    fn corrupt_blob_rejected() {
        let prev: Vec<u32> = (0..100).map(|i| (i % 8) as u32).collect();
        let mut cur = prev.clone();
        cur[50] = 7;
        let mut blob = delta_encode(&prev, &cur, 8).unwrap();
        blob.truncate(4); // header claims 1 change, body gone
        assert!(delta_decode(&prev, &blob, 8).is_err());
    }
}
