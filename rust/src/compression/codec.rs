//! Wire format for clustered model updates (FedCompress transport).
//!
//! Layout (little-endian):
//!   u32 magic 'FCW1' | u32 param_count | u16 codebook_len | u8 bits |
//!   u8 flags (1 = huffman payload) | codebook f32[C] |
//!   u64 payload_bit_or_symbol_count | payload bytes
//!
//! `encode` never loses information about the *quantized* model: decode
//! reproduces exactly `codebook[idx[i]]` for every weight. The encoder
//! picks Huffman when it beats flat packing (skewed assignments), flat
//! bit-packing otherwise — both are counted byte-exactly for CCR.

use super::huffman::{huffman_decode, huffman_encode, HuffmanEncoded};
use anyhow::{bail, Result};

const MAGIC: u32 = 0x4643_5731; // "FCW1"

/// An encoded model update plus the exact wire size.
pub struct EncodedModel {
    pub bytes: Vec<u8>,
    pub param_count: usize,
    pub codebook_len: usize,
}

impl EncodedModel {
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }
}

fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u16(v: &mut Vec<u8>, x: u16) {
    v.extend_from_slice(&x.to_le_bytes());
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}
impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated encoded model");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into()?))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into()?))
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into()?))
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into()?))
    }
}

/// Bits needed for a flat index into a `c`-entry codebook.
pub fn index_bits(c: usize) -> u32 {
    (usize::BITS - (c.max(2) - 1).leading_zeros()).max(1)
}

/// Exact wire size of a *flat-packed* `encode_flat` blob: 12-byte
/// header + codebook + u64 bit count + bit-packed indices. The
/// `kmeans`/`codebook` codec stages ledger intermediate streams with
/// this formula, so it must stay in lockstep with the encoder layout.
pub fn flat_wire_bytes(c: usize, n: usize) -> usize {
    12 + 4 * c + 8 + (n * index_bits(c) as usize).div_ceil(8)
}

/// Encode quantized weights as (codebook, indices), always flat
/// bit-packing (no entropy stage) — the terminal form of a pipeline
/// that stops at a clustering stage.
pub fn encode_flat(codebook: &[f32], indices: &[u32]) -> EncodedModel {
    encode_inner(codebook, indices, true)
}

/// Encode quantized weights as (codebook, indices).
/// `indices[i]` must reference `codebook`; panics on out-of-range.
pub fn encode(codebook: &[f32], indices: &[u32]) -> EncodedModel {
    encode_inner(codebook, indices, false)
}

fn encode_inner(codebook: &[f32], indices: &[u32], force_flat: bool) -> EncodedModel {
    assert!(!codebook.is_empty() && codebook.len() <= u16::MAX as usize);
    let c = codebook.len();
    let bits = index_bits(c);

    // candidate 1: flat packing
    let flat_bits = indices.len() * bits as usize;
    // candidate 2: huffman (skipped entirely when flat is forced)
    let huff: Option<HuffmanEncoded> =
        (!force_flat).then(|| huffman_encode(indices, c));

    let use_huffman = huff
        .as_ref()
        .is_some_and(|h| h.wire_bytes() < flat_bits.div_ceil(8));

    let mut out = Vec::new();
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, indices.len() as u32);
    put_u16(&mut out, c as u16);
    out.push(bits as u8);
    out.push(use_huffman as u8);
    for &v in codebook {
        out.extend_from_slice(&v.to_le_bytes());
    }
    if use_huffman {
        let huff = huff.expect("use_huffman implies candidate built");
        out.extend_from_slice(&huff.lengths);
        put_u64(&mut out, huff.payload_bits as u64);
        out.extend_from_slice(&huff.payload);
    } else {
        for &i in indices {
            debug_assert!((i as usize) < c);
        }
        put_u64(&mut out, flat_bits as u64);
        out.extend_from_slice(&crate::kernels::pack_bits(indices, bits));
    }
    EncodedModel {
        bytes: out,
        param_count: indices.len(),
        codebook_len: c,
    }
}

/// Decode back to the quantized flat weight vector (+ indices).
pub fn decode(bytes: &[u8]) -> Result<(Vec<f32>, Vec<u32>, Vec<f32>)> {
    let mut cur = Cursor { b: bytes, i: 0 };
    if cur.u32()? != MAGIC {
        bail!("bad magic");
    }
    let n = cur.u32()? as usize;
    let c = cur.u16()? as usize;
    let bits = cur.u8()? as u32;
    let flags = cur.u8()?;
    let mut codebook = Vec::with_capacity(c);
    for _ in 0..c {
        codebook.push(cur.f32()?);
    }
    let indices: Vec<u32> = if flags & 1 == 1 {
        let lengths = cur.take(c)?.to_vec();
        let payload_bits = cur.u64()? as usize;
        let payload = cur.take(payload_bits.div_ceil(8))?.to_vec();
        let enc = HuffmanEncoded {
            lengths,
            payload,
            n_symbols: n,
            payload_bits,
        };
        huffman_decode(&enc)?
    } else {
        let payload_bits = cur.u64()? as usize;
        if payload_bits != n * bits as usize {
            bail!("bit count mismatch");
        }
        let payload = cur.take(payload_bits.div_ceil(8))?;
        let Some(v) = crate::kernels::unpack_bits(payload, bits, n) else {
            bail!("truncated index stream");
        };
        for &x in &v {
            if x as usize >= c {
                bail!("index {x} out of codebook range {c}");
            }
        }
        v
    };
    let weights = indices.iter().map(|&i| codebook[i as usize]).collect();
    Ok((weights, indices, codebook))
}

/// Convenience: quantize a dense vector against a sorted codebook and
/// encode; returns the wire blob and the quantized weights.
pub fn quantize_and_encode(weights: &[f32], sorted_codebook: &[f32]) -> (EncodedModel, Vec<f32>) {
    let mut q = weights.to_vec();
    let idx = super::kmeans::snap(&mut q, sorted_codebook);
    (encode(sorted_codebook, &idx), q)
}

/// Dense (uncompressed) wire size for a model of `p` parameters — the
/// FedAvg baseline both directions, and FedZip's downstream.
pub fn dense_bytes(p: usize) -> usize {
    4 * p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::kmeans::kmeans_1d;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_flat_and_huffman() {
        let mut rng = Rng::new(1);
        // near-uniform indices -> flat; skewed -> huffman. Both decode.
        for skew in [false, true] {
            let weights: Vec<f32> = (0..4000)
                .map(|_| if skew && rng.f32() < 0.9 { 0.0 } else { rng.normal() })
                .collect();
            let (cb, _, _) = kmeans_1d(&weights, 16, 20, &mut rng);
            let (enc, q) = quantize_and_encode(&weights, &cb);
            let (dec, idx, cb2) = decode(&enc.bytes).unwrap();
            assert_eq!(dec, q);
            assert_eq!(cb2, cb);
            assert_eq!(idx.len(), weights.len());
        }
    }

    #[test]
    fn wire_size_beats_dense_substantially() {
        let mut rng = Rng::new(2);
        let weights: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let (cb, _, _) = kmeans_1d(&weights, 16, 20, &mut rng);
        let (enc, _) = quantize_and_encode(&weights, &cb);
        let ratio = dense_bytes(weights.len()) as f64 / enc.wire_bytes() as f64;
        // 4 bits/param + header vs 32 bits/param ~ 7-8x
        assert!(ratio > 6.0, "ratio {ratio}");
    }

    #[test]
    fn decode_rejects_corruption() {
        let mut rng = Rng::new(3);
        let weights: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let (cb, _, _) = kmeans_1d(&weights, 4, 10, &mut rng);
        let (enc, _) = quantize_and_encode(&weights, &cb);
        let mut bad = enc.bytes.clone();
        bad[0] ^= 0xff; // magic
        assert!(decode(&bad).is_err());
        let mut short = enc.bytes.clone();
        short.truncate(10);
        assert!(decode(&short).is_err());
    }

    /// `encode_flat` must match the formula the codec stages ledger
    /// intermediate streams with, and decode like any other container.
    #[test]
    fn forced_flat_matches_the_size_formula() {
        let mut rng = Rng::new(5);
        for &(n, c) in &[(1usize, 2usize), (100, 3), (4096, 16), (777, 31)] {
            let cb: Vec<f32> = {
                let mut v: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            };
            let idx: Vec<u32> = (0..n).map(|_| rng.below(c) as u32).collect();
            let enc = encode_flat(&cb, &idx);
            assert_eq!(enc.wire_bytes(), flat_wire_bytes(c, n), "n={n} c={c}");
            let (_, idx2, cb2) = decode(&enc.bytes).unwrap();
            assert_eq!(idx2, idx);
            assert_eq!(cb2, cb);
            // forced flat is never larger than needed: the adaptive
            // encoder may only beat it
            assert!(encode(&cb, &idx).wire_bytes() <= enc.wire_bytes());
        }
        // empty index stream: header + codebook + zero-bit payload
        let enc = encode_flat(&[0.5f32], &[]);
        assert_eq!(enc.wire_bytes(), flat_wire_bytes(1, 0));
        let (w, i, _) = decode(&enc.bytes).unwrap();
        assert!(w.is_empty() && i.is_empty());
    }

    #[test]
    fn index_bits_edges() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(4), 2);
        assert_eq!(index_bits(16), 4);
        assert_eq!(index_bits(17), 5);
        assert_eq!(index_bits(32), 5);
    }

    #[test]
    fn random_roundtrip_property() {
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let c = 2 + rng.below(31);
            let n = 1 + rng.below(3000);
            let cb: Vec<f32> = {
                let mut v: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            };
            let idx: Vec<u32> = (0..n).map(|_| rng.below(c) as u32).collect();
            let enc = encode(&cb, &idx);
            let (w, idx2, cb2) = decode(&enc.bytes).unwrap();
            assert_eq!(idx, idx2);
            assert_eq!(cb, cb2);
            for (k, &i) in idx.iter().enumerate() {
                assert_eq!(w[k], cb[i as usize]);
            }
        }
    }
}
