//! fedlint — the project's self-hosted determinism & wire-safety lint.
//!
//! The reproduction's claims (bit-exact run records, TCP==in-process
//! loopback equivalence, content-addressed cache keys) rest on
//! invariants no compiler checks: map iteration order must never cross
//! the wire, decode paths must never panic on adversarial bytes, wall
//! clocks and ad-hoc RNG seeds must never leak into recorded state,
//! float narrowing in codec hot paths must be deliberate. fedlint
//! enforces them statically, as named rules over the crate's own token
//! stream — `cargo run -- lint` is the CLI verb, and CI runs it as a
//! hard gate.
//!
//! Layout: [`lexer`] tokenizes (no full parse — rules are heuristics
//! over tokens), [`rules`] holds the rule registry and the
//! `fedlint:allow` contract, [`config`] reads the `fedlint.toml`
//! scope/severity table, [`report`] renders text and JSON. The engine
//! in this module walks the tree, applies scopes, and reconciles
//! violations against allow comments.
//!
//! Suppression contract: a violation is suppressed only by a comment
//! `// fedlint:allow(rule) -- reason` on the same line (trailing) or
//! the line directly above (standalone). The reason is mandatory,
//! honored allows are counted and printed, stale ones are reported as
//! `unused-allow` warnings, and malformed ones are `bad-allow`
//! denials — a broken suppression never silently suppresses.

pub mod config;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

pub use config::{LintConfig, RuleConfig, Severity};
pub use report::{render_json, render_text};
pub use rules::{rule_names, RULES};

use rules::FileCtx;

/// One reported violation, scope- and suppression-resolved.
#[derive(Clone, Debug)]
pub struct Violation {
    /// `/`-separated path relative to the linted root.
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub severity: Severity,
    pub message: String,
    /// The offending source line, trimmed.
    pub excerpt: String,
}

/// One honored `fedlint:allow`, for the reporter's accounting.
#[derive(Clone, Debug)]
pub struct AllowedSite {
    pub file: String,
    /// Line of the allow comment.
    pub line: u32,
    pub rules: Vec<String>,
    pub reason: String,
    /// Violations this allow suppressed (>= 1; stale allows are
    /// reported as `unused-allow` instead of landing here).
    pub uses: usize,
}

/// The outcome of one lint run.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Unsuppressed violations, sorted by (file, line, rule).
    pub violations: Vec<Violation>,
    /// Honored allows, sorted by (file, line).
    pub allowed: Vec<AllowedSite>,
    /// Files that had at least one applicable rule and were scanned.
    pub files_scanned: usize,
}

impl LintReport {
    pub fn deny_count(&self) -> usize {
        self.violations.iter().filter(|v| v.severity == Severity::Deny).count()
    }

    pub fn warn_count(&self) -> usize {
        self.violations.iter().filter(|v| v.severity == Severity::Warn).count()
    }

    /// Clean = nothing that should gate (warnings tolerated).
    pub fn is_clean(&self) -> bool {
        self.deny_count() == 0
    }
}

/// Lint one file's source text under `cfg`. `rel` is the
/// `/`-separated path scopes are matched against. Exposed for tests;
/// [`lint_root`] drives it over a tree.
pub fn lint_source(
    rel: &str,
    src: &str,
    cfg: &LintConfig,
    rule_filter: Option<&str>,
) -> (Vec<Violation>, Vec<AllowedSite>) {
    let applicable: Vec<&RuleConfig> = cfg
        .rules
        .iter()
        .filter(|r| r.severity != Severity::Off)
        .filter(|r| rule_filter.map_or(true, |f| f == r.name))
        .filter(|r| r.in_scope(rel))
        .collect();
    if applicable.is_empty() {
        return (Vec::new(), Vec::new());
    }

    let lexed = lexer::lex(src);
    let test_ranges = lexer::test_line_ranges(&lexed.toks);
    let ctx = FileCtx {
        rel,
        toks: &lexed.toks,
        test_ranges: &test_ranges,
    };

    let mut raw = Vec::new();
    for def in &RULES {
        if applicable.iter().any(|r| r.name == def.name) {
            (def.check)(&ctx, &mut raw);
        }
    }
    let (allows, bad_allows) = rules::parse_allows(&lexed.comments, &test_ranges);
    raw.extend(bad_allows);

    // reconcile: a violation is suppressed by an allow naming its rule
    // whose target line matches; count uses per allow
    let mut uses = vec![0usize; allows.len()];
    let mut out = Vec::new();
    let lines: Vec<&str> = src.lines().collect();
    for v in raw {
        let suppressed = allows.iter().enumerate().find(|(_, a)| {
            a.target_line == v.line && a.rules.iter().any(|r| r == v.rule)
        });
        if let Some((k, _)) = suppressed {
            uses[k] += 1;
            continue;
        }
        let severity = match cfg.rule(v.rule) {
            Some(r) => r.severity,
            // contract violations (bad-allow) always gate
            None => Severity::Deny,
        };
        out.push(Violation {
            file: rel.to_string(),
            line: v.line,
            rule: v.rule.to_string(),
            severity,
            message: v.message,
            excerpt: excerpt(&lines, v.line),
        });
    }

    let mut honored = Vec::new();
    for (k, a) in allows.iter().enumerate() {
        if uses[k] > 0 {
            honored.push(AllowedSite {
                file: rel.to_string(),
                line: a.line,
                rules: a.rules.clone(),
                reason: a.reason.clone(),
                uses: uses[k],
            });
        } else {
            out.push(Violation {
                file: rel.to_string(),
                line: a.line,
                rule: "unused-allow".to_string(),
                severity: Severity::Warn,
                message: format!(
                    "allow({}) suppresses nothing — remove it or fix its target",
                    a.rules.join(", ")
                ),
                excerpt: excerpt(&lines, a.line),
            });
        }
    }
    (out, honored)
}

fn excerpt(lines: &[&str], line: u32) -> String {
    (line as usize)
        .checked_sub(1)
        .and_then(|i| lines.get(i))
        .map(|s| s.trim().to_string())
        .unwrap_or_default()
}

/// Lint every `.rs` file under `root` (skipping `target/`, `vendor/`,
/// and VCS metadata) against `cfg`. `rule_filter` restricts to one
/// rule; `path_filters` restrict to files whose relative path starts
/// with any of the given prefixes. Deterministic: files are visited in
/// sorted order and results sorted by (file, line, rule).
pub fn lint_root(
    root: &Path,
    cfg: &LintConfig,
    rule_filter: Option<&str>,
    path_filters: &[String],
) -> Result<LintReport, String> {
    if let Some(f) = rule_filter {
        let known = rule_names();
        if !known.contains(&f) {
            let hint = crate::util::suggest::closest(f, known.iter().copied())
                .map(|c| format!(" (did you mean '{c}'?)"))
                .unwrap_or_default();
            return Err(format!("unknown rule '{f}'{hint}; known: {}", known.join(", ")));
        }
    }
    let mut files = Vec::new();
    walk(root, root, &mut files)?;
    files.sort();

    let filters: Vec<String> = path_filters
        .iter()
        .map(|f| f.trim_start_matches("./").trim_end_matches('/').to_string())
        .filter(|f| !f.is_empty())
        .collect();

    let mut report = LintReport::default();
    for (rel, path) in &files {
        if !filters.is_empty() && !filters.iter().any(|f| rel.starts_with(f.as_str())) {
            continue;
        }
        let scanned = cfg.rules.iter().any(|r| {
            r.severity != Severity::Off
                && rule_filter.map_or(true, |f| f == r.name)
                && r.in_scope(rel)
        });
        if !scanned {
            continue;
        }
        report.files_scanned += 1;
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let (violations, allowed) = lint_source(rel, &src, cfg, rule_filter);
        report.violations.extend(violations);
        report.allowed.extend(allowed);
    }
    report
        .violations
        .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    report.allowed.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<(String, PathBuf)>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("walking {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if matches!(name.as_str(), "target" | "vendor" | ".git" | ".jj" | "node_modules") {
                continue;
            }
            walk(root, &path, out)?;
        } else if name.ends_with(".rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|e| format!("relativizing {}: {e}", path.display()))?
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_for(rule: &str, scope: &str) -> LintConfig {
        LintConfig::parse(&format!(
            "[rule.{rule}]\nseverity = \"deny\"\npaths = [\"{scope}\"]\n"
        ))
        .unwrap()
    }

    #[test]
    fn scope_gates_whether_a_rule_fires() {
        let cfg = cfg_for("det-map-iter", "src/net/");
        let src = "use std::collections::HashMap;\n";
        let (v, _) = lint_source("src/net/proto.rs", src, &cfg, None);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "det-map-iter");
        assert_eq!(v[0].excerpt, "use std::collections::HashMap;");
        let (v, _) = lint_source("src/data/partition.rs", src, &cfg, None);
        assert!(v.is_empty(), "out of scope");
    }

    #[test]
    fn allows_suppress_and_are_counted_and_stale_ones_warn() {
        let cfg = cfg_for("det-map-iter", "src/");
        let src = "\
// fedlint:allow(det-map-iter) -- this map never iterates
use std::collections::HashMap;
use std::collections::BTreeMap; // fedlint:allow(det-map-iter) -- stale
";
        let (v, allowed) = lint_source("src/x.rs", src, &cfg, None);
        assert_eq!(allowed.len(), 1);
        assert_eq!(allowed[0].uses, 1);
        assert_eq!(allowed[0].reason, "this map never iterates");
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unused-allow");
        assert_eq!(v[0].severity, Severity::Warn);
    }

    #[test]
    fn severity_off_and_warn_are_respected() {
        let src = "use std::collections::HashMap;\n";
        let off = LintConfig::parse(
            "[rule.det-map-iter]\nseverity = \"off\"\npaths = [\"src/\"]\n",
        )
        .unwrap();
        assert!(lint_source("src/x.rs", src, &off, None).0.is_empty());
        let warn = LintConfig::parse(
            "[rule.det-map-iter]\nseverity = \"warn\"\npaths = [\"src/\"]\n",
        )
        .unwrap();
        let (v, _) = lint_source("src/x.rs", src, &warn, None);
        assert_eq!(v[0].severity, Severity::Warn);
        let report = LintReport {
            violations: v,
            ..Default::default()
        };
        assert!(report.is_clean(), "warnings do not gate");
        assert_eq!(report.warn_count(), 1);
    }

    #[test]
    fn rule_filter_limits_checks_and_rejects_typos() {
        let cfg = LintConfig::builtin();
        let src = "use std::collections::HashMap;\nlet t = Instant::now();\n";
        let (v, _) = lint_source("src/net/x.rs", src, &cfg, Some("no-wallclock-state"));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "no-wallclock-state");
        let err = lint_root(Path::new("."), &cfg, Some("det-map-itr"), &[]).unwrap_err();
        assert!(err.contains("det-map-iter"), "{err}");
    }
}
