//! The fedlint rules: heuristics over the token stream of one file.
//!
//! Every rule is a pure function `(FileCtx) -> violations` registered
//! in [`RULES`]; the engine decides scope (which files a rule sees,
//! from `fedlint.toml`) and suppression (`fedlint:allow` comments), so
//! a rule only has to recognize its pattern. Rules skip `#[cfg(test)]
//! mod` blocks — test code unwraps and seeds RNGs by design.
//!
//! These are token-level heuristics, not type-checked analyses: they
//! trade a few theoretical false positives (e.g. an `as f32` that
//! provably loses no precision) for zero build-time dependencies and
//! total coverage of the patterns that have actually bitten wire
//! determinism. A justified exception carries an allow comment with a
//! reason, which doubles as in-place documentation.

use super::lexer::{in_ranges, Comment, Tok, TokKind};

/// Context a rule sees for one file.
pub struct FileCtx<'a> {
    /// `/`-separated path relative to the linted root.
    pub rel: &'a str,
    pub toks: &'a [Tok],
    /// 1-based line ranges of `#[cfg(test)] mod` blocks.
    pub test_ranges: &'a [(u32, u32)],
}

/// A rule hit before scope/severity/suppression are applied.
#[derive(Clone, Debug)]
pub struct RawViolation {
    pub rule: &'static str,
    pub line: u32,
    pub message: String,
}

/// One registered rule.
pub struct RuleDef {
    pub name: &'static str,
    /// One-line description for `lint --rule list` style surfaces.
    pub summary: &'static str,
    pub check: fn(&FileCtx<'_>, &mut Vec<RawViolation>),
}

/// The rule registry. Adding a rule = one entry here + a section in
/// `fedlint.toml` + a fixture under `tests/lint_fixtures/`.
pub const RULES: [RuleDef; 6] = [
    RuleDef {
        name: "det-map-iter",
        summary: "no HashMap/HashSet where iteration order can cross the wire or land in records",
        check: check_det_map_iter,
    },
    RuleDef {
        name: "no-panic-decode",
        summary: "decode paths return typed errors: no unwrap/expect/panic!/indexing",
        check: check_no_panic_decode,
    },
    RuleDef {
        name: "no-wallclock-state",
        summary: "wall-clock reads only for environment fields excluded from diff_records",
        check: check_no_wallclock_state,
    },
    RuleDef {
        name: "rng-discipline",
        summary: "Rng construction only via the named root/fork stream constructors",
        check: check_rng_discipline,
    },
    RuleDef {
        name: "float-order",
        summary: "no unannotated f32 narrowing or f32 reductions in codec hot paths",
        check: check_float_order,
    },
    RuleDef {
        name: "unsafe-scope",
        summary: "unsafe only in src/kernels/backend_*.rs, each site with a safety argument",
        check: check_unsafe_scope,
    },
];

pub fn rule_names() -> Vec<&'static str> {
    RULES.iter().map(|r| r.name).collect()
}

// --- the rules --------------------------------------------------------------

fn check_det_map_iter(ctx: &FileCtx<'_>, out: &mut Vec<RawViolation>) {
    for t in ctx.toks {
        if t.kind == TokKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
            && !in_ranges(ctx.test_ranges, t.line)
        {
            out.push(RawViolation {
                rule: "det-map-iter",
                line: t.line,
                message: format!(
                    "{} in a determinism scope — iteration order is randomized per \
                     process; use BTreeMap/BTreeSet or sort before emitting",
                    t.text
                ),
            });
        }
    }
}

/// Identifiers that may legitimately precede `[` without it being an
/// index expression (slice patterns, array types after keywords).
const NON_INDEX_KEYWORDS: &[&str] = &[
    "as", "box", "break", "const", "continue", "crate", "dyn", "else", "enum", "extern", "fn",
    "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "static", "struct", "trait", "type", "unsafe", "use", "where", "while",
];

fn check_no_panic_decode(ctx: &FileCtx<'_>, out: &mut Vec<RawViolation>) {
    let toks = ctx.toks;
    let punct = |k: usize, text: &str| {
        toks.get(k).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
    };
    for (i, t) in toks.iter().enumerate() {
        if in_ranges(ctx.test_ranges, t.line) {
            continue;
        }
        match t.kind {
            TokKind::Ident if (t.text == "unwrap" || t.text == "expect") => {
                // `.unwrap()` / `.expect(` — method calls only, so
                // `unwrap_or` and fields named `expect` don't trip
                if i > 0 && punct(i - 1, ".") && punct(i + 1, "(") {
                    out.push(RawViolation {
                        rule: "no-panic-decode",
                        line: t.line,
                        message: format!(
                            ".{}() in a decode path — adversarial bytes must surface \
                             as a typed error, not a panic",
                            t.text
                        ),
                    });
                }
            }
            TokKind::Ident
                if matches!(t.text.as_str(), "panic" | "unreachable" | "todo" | "unimplemented") =>
            {
                if punct(i + 1, "!") {
                    out.push(RawViolation {
                        rule: "no-panic-decode",
                        line: t.line,
                        message: format!("{}! in a decode path — return a typed error", t.text),
                    });
                }
            }
            TokKind::Punct if t.text == "[" && i > 0 => {
                // index expression: `expr[...]` — `[` right after an
                // identifier, `)`, or `]`. Array types/literals and
                // slice patterns follow punctuation or a keyword.
                let indexes = toks.get(i - 1).is_some_and(|p| match p.kind {
                    TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&p.text.as_str()),
                    TokKind::Punct => p.text == ")" || p.text == "]",
                    _ => false,
                });
                if indexes {
                    out.push(RawViolation {
                        rule: "no-panic-decode",
                        line: t.line,
                        message: "slice/array indexing in a decode path — a bad offset \
                                  panics; use .get()/ByteCursor and return a typed error"
                            .to_string(),
                    });
                }
            }
            _ => {}
        }
    }
}

fn check_no_wallclock_state(ctx: &FileCtx<'_>, out: &mut Vec<RawViolation>) {
    // flag the *reads* — `Instant::now` / `SystemTime::now` — not
    // imports or type positions, so one allow marks one clock read
    for (i, t) in ctx.toks.iter().enumerate() {
        if t.kind == TokKind::Ident
            && (t.text == "Instant" || t.text == "SystemTime")
            && !in_ranges(ctx.test_ranges, t.line)
            && path_call(ctx.toks, i, "now")
        {
            out.push(RawViolation {
                rule: "no-wallclock-state",
                line: t.line,
                message: format!(
                    "{}::now in a determinism scope — wall time may only feed \
                     environment fields that diff_records excludes",
                    t.text
                ),
            });
        }
    }
}

fn check_rng_discipline(ctx: &FileCtx<'_>, out: &mut Vec<RawViolation>) {
    for (i, t) in ctx.toks.iter().enumerate() {
        if t.kind == TokKind::Ident
            && t.text == "Rng"
            && !in_ranges(ctx.test_ranges, t.line)
            && path_call(ctx.toks, i, "new")
        {
            out.push(RawViolation {
                rule: "rng-discipline",
                line: t.line,
                message: "ad-hoc Rng::new — derive streams from the run's named \
                          root/fork constructors, or allow with the stream's name"
                    .to_string(),
            });
        }
    }
}

fn check_float_order(ctx: &FileCtx<'_>, out: &mut Vec<RawViolation>) {
    let toks = ctx.toks;
    let seq = |k: usize, kind: TokKind, text: &str| {
        toks.get(k).is_some_and(|t| t.kind == kind && t.text == text)
    };
    for (i, t) in toks.iter().enumerate() {
        if in_ranges(ctx.test_ranges, t.line) {
            continue;
        }
        if t.kind == TokKind::Ident && t.text == "as" && seq(i + 1, TokKind::Ident, "f32") {
            out.push(RawViolation {
                rule: "float-order",
                line: t.line,
                message: "`as f32` narrowing in a codec hot path — rounding depends on \
                          accumulation order; annotate the deliberate cases"
                    .to_string(),
            });
        }
        // `.sum::<f32>()` — an unordered f32 reduction
        if t.kind == TokKind::Ident
            && t.text == "sum"
            && i > 0
            && seq(i - 1, TokKind::Punct, ".")
            && seq(i + 1, TokKind::Punct, ":")
            && seq(i + 2, TokKind::Punct, ":")
            && seq(i + 3, TokKind::Punct, "<")
            && seq(i + 4, TokKind::Ident, "f32")
        {
            out.push(RawViolation {
                rule: "float-order",
                line: t.line,
                message: ".sum::<f32>() — f32 reduction order changes the result; \
                          accumulate in f64 or document the ordering"
                    .to_string(),
            });
        }
    }
}

/// Files where `unsafe` is sanctioned — the SIMD kernel backends. Even
/// there, every site must carry a reasoned allow: the rule fires on
/// each `unsafe` keyword and only a `fedlint:allow(unsafe-scope) --
/// <safety argument>` suppresses it.
fn is_kernel_backend(rel: &str) -> bool {
    rel.starts_with("src/kernels/backend_") && rel.ends_with(".rs")
}

fn check_unsafe_scope(ctx: &FileCtx<'_>, out: &mut Vec<RawViolation>) {
    let backend = is_kernel_backend(ctx.rel);
    for t in ctx.toks {
        if t.kind == TokKind::Ident && t.text == "unsafe" && !in_ranges(ctx.test_ranges, t.line) {
            let message = if backend {
                "unsafe in a kernel backend — sanctioned, but every site must state \
                 its safety argument in a fedlint:allow(unsafe-scope) comment"
                    .to_string()
            } else {
                "unsafe outside src/kernels/backend_*.rs — the SIMD kernel backends \
                 are the only sanctioned unsafe scope in this crate"
                    .to_string()
            };
            out.push(RawViolation {
                rule: "unsafe-scope",
                line: t.line,
                message,
            });
        }
    }
}

/// `toks[i]` starts a `Name::method` path call: `Name :: method`.
fn path_call(toks: &[Tok], i: usize, method: &str) -> bool {
    let p = |k: usize, text: &str| {
        toks.get(k).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
    };
    p(i + 1, ":")
        && p(i + 2, ":")
        && toks
            .get(i + 3)
            .is_some_and(|t| t.kind == TokKind::Ident && t.text == method)
}

// --- the allow contract -----------------------------------------------------

/// A parsed `// fedlint:allow(rule[, rule]) -- reason` comment.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Line of the comment itself.
    pub line: u32,
    /// Line whose violations it suppresses: its own line for a
    /// trailing comment, the next line for a standalone one.
    pub target_line: u32,
    pub rules: Vec<String>,
    pub reason: String,
}

const MARKER: &str = "fedlint:allow";

/// Doc comments (`///`, `//!`, `/**`, `/*!`) *describe* the allow
/// contract — module and rule docs quote the syntax verbatim, as this
/// file's own header does — they never carry it. Treating them as
/// suppressions would turn every explanation of the contract into a
/// `bad-allow` or a stale allow.
fn is_doc_comment(text: &str) -> bool {
    text.starts_with("///")
        || text.starts_with("//!")
        || text.starts_with("/**")
        || text.starts_with("/*!")
}

/// Extract allow comments; malformed ones (missing rule list, unknown
/// rule, missing `-- reason`) become `bad-allow` violations — a broken
/// suppression must never silently suppress. Doc comments are ignored:
/// only a plain `//` (or `/* */`) comment can suppress.
pub fn parse_allows(
    comments: &[Comment],
    test_ranges: &[(u32, u32)],
) -> (Vec<Allow>, Vec<RawViolation>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    let mut push_bad = |line: u32, message: String| {
        bad.push(RawViolation {
            rule: "bad-allow",
            line,
            message,
        });
    };
    for c in comments {
        if is_doc_comment(&c.text) {
            continue;
        }
        let Some(pos) = c.text.find(MARKER) else {
            continue;
        };
        if in_ranges(test_ranges, c.line) {
            continue; // rules skip test code, so allows there are moot
        }
        let after = &c.text[pos + MARKER.len()..];
        let Some(open) = after.strip_prefix('(') else {
            push_bad(c.line, format!("expected {MARKER}(rule, ...) -- reason"));
            continue;
        };
        let Some((list, rest)) = open.split_once(')') else {
            push_bad(c.line, "unclosed rule list in allow comment".to_string());
            continue;
        };
        let rules: Vec<String> =
            list.split(',').map(|r| r.trim().to_string()).filter(|r| !r.is_empty()).collect();
        if rules.is_empty() {
            push_bad(c.line, "allow comment names no rules".to_string());
            continue;
        }
        let known = rule_names();
        if let Some(unknown) = rules.iter().find(|r| !known.contains(&r.as_str())) {
            push_bad(
                c.line,
                format!("allow names unknown rule '{unknown}' (known: {})", known.join(", ")),
            );
            continue;
        }
        let rest = rest.trim_start();
        let reason = rest.strip_prefix("--").map(str::trim).unwrap_or_default();
        if reason.is_empty() {
            push_bad(
                c.line,
                "allow comment without a reason — write `-- <why this is sound>`".to_string(),
            );
            continue;
        }
        allows.push(Allow {
            line: c.line,
            target_line: if c.trailing { c.line } else { c.line + 1 },
            rules,
            reason: reason.to_string(),
        });
    }
    (allows, bad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::{lex, test_line_ranges};

    fn run(rule: &str, src: &str) -> Vec<RawViolation> {
        let lexed = lex(src);
        let ranges = test_line_ranges(&lexed.toks);
        let ctx = FileCtx {
            rel: "src/fake.rs",
            toks: &lexed.toks,
            test_ranges: &ranges,
        };
        let mut out = Vec::new();
        for def in &RULES {
            if def.name == rule {
                (def.check)(&ctx, &mut out);
            }
        }
        out
    }

    #[test]
    fn det_map_iter_flags_hash_collections_outside_tests() {
        let hits = run(
            "det-map-iter",
            "use std::collections::HashMap;\n\
             fn f(m: &HashMap<u32, u32>) {}\n\
             #[cfg(test)]\nmod tests { use std::collections::HashSet; }\n",
        );
        assert_eq!(hits.iter().map(|v| v.line).collect::<Vec<_>>(), vec![1, 2]);
        assert!(run("det-map-iter", "use std::collections::BTreeMap;").is_empty());
    }

    #[test]
    fn no_panic_decode_distinguishes_calls_from_lookalikes() {
        let hits = run(
            "no-panic-decode",
            "fn f(v: &[u8]) -> u8 {\n\
             let a = v.first().unwrap();\n\
             let b = x.unwrap_or(0);\n\
             let c = v[0];\n\
             let d: [u8; 4] = [0; 4];\n\
             #[derive(Debug)] struct S;\n\
             panic!(\"no\");\n\
             }\n",
        );
        let lines: Vec<u32> = hits.iter().map(|v| v.line).collect();
        assert!(lines.contains(&2), "unwrap call: {hits:?}");
        assert!(!lines.contains(&3), "unwrap_or is fine: {hits:?}");
        assert!(lines.contains(&4), "indexing: {hits:?}");
        assert!(!lines.contains(&5), "array literal/type is fine: {hits:?}");
        assert!(!lines.contains(&6), "attribute is fine: {hits:?}");
        assert!(lines.contains(&7), "panic!: {hits:?}");
    }

    #[test]
    fn slice_patterns_and_macro_brackets_are_not_indexing() {
        assert!(run("no-panic-decode", "let [a, b] = pair;").is_empty());
        assert!(run("no-panic-decode", "let v = vec![1, 2];").is_empty());
        assert!(run("no-panic-decode", "fn f() -> [u8; 2] { g() }").is_empty());
        assert_eq!(run("no-panic-decode", "let x = buf[i];").len(), 1);
        assert_eq!(run("no-panic-decode", "let x = f()[0];").len(), 1);
    }

    #[test]
    fn wallclock_flags_reads_not_imports() {
        assert!(run("no-wallclock-state", "use std::time::Instant;").is_empty());
        assert!(run("no-wallclock-state", "fn f(t: Instant) {}").is_empty());
        assert_eq!(run("no-wallclock-state", "let t = Instant::now();").len(), 1);
        assert_eq!(
            run("no-wallclock-state", "let t = std::time::SystemTime::now();").len(),
            1
        );
    }

    #[test]
    fn rng_discipline_flags_construction_only() {
        assert_eq!(run("rng-discipline", "let mut r = Rng::new(42);").len(), 1);
        assert!(run("rng-discipline", "let s = rng.fork(3);").is_empty());
        assert!(run("rng-discipline", "fn f(rng: &mut Rng) {}").is_empty());
    }

    #[test]
    fn float_order_flags_narrowing_and_f32_sums() {
        assert_eq!(run("float-order", "let x = total as f32;").len(), 1);
        assert_eq!(run("float-order", "let s = v.iter().sum::<f32>();").len(), 1);
        assert!(run("float-order", "let x = total as f64;").is_empty());
        assert!(run("float-order", "let s: f64 = v.iter().sum();").is_empty());
    }

    #[test]
    fn unsafe_scope_flags_every_site_and_distinguishes_backends() {
        let src = "pub fn f() { unsafe { g() } }\n\
                   unsafe fn g() {}\n\
                   #[cfg(test)]\nmod tests { fn t() { unsafe { h() } } }\n";
        let hits = run("unsafe-scope", src);
        assert_eq!(hits.iter().map(|v| v.line).collect::<Vec<_>>(), vec![1, 2]);
        assert!(hits[0].message.contains("only sanctioned unsafe scope"));

        // same tokens under a backend path: still flagged (the allow
        // comment is what discharges it), but with the backend message
        let lexed = lex(src);
        let ranges = test_line_ranges(&lexed.toks);
        let ctx = FileCtx {
            rel: "src/kernels/backend_avx2.rs",
            toks: &lexed.toks,
            test_ranges: &ranges,
        };
        let mut out = Vec::new();
        check_unsafe_scope(&ctx, &mut out);
        assert_eq!(out.len(), 2);
        assert!(out[0].message.contains("safety argument"));

        assert!(run("unsafe-scope", "let s = \"unsafe\"; // unsafe in a str").is_empty());
    }

    #[test]
    fn allow_comments_parse_and_malformed_ones_are_violations() {
        let lexed = lex(
            "let a = 1; // fedlint:allow(det-map-iter) -- keyed iteration is sorted first\n\
             // fedlint:allow(no-panic-decode, rng-discipline) -- lock poisoning only\n\
             let b = 2;\n\
             // fedlint:allow(det-map-iter)\n\
             // fedlint:allow(not-a-rule) -- whatever\n\
             // fedlint:allow -- no list\n",
        );
        let (allows, bad) = parse_allows(&lexed.comments, &[]);
        assert_eq!(allows.len(), 2);
        assert_eq!(allows[0].target_line, 1, "trailing allow suppresses its own line");
        assert_eq!(allows[1].target_line, 3, "standalone allow suppresses the next line");
        assert_eq!(allows[1].rules.len(), 2);
        let bad_lines: Vec<u32> = bad.iter().map(|v| v.line).collect();
        assert_eq!(bad_lines, vec![4, 5, 6], "{bad:?}");
    }

    #[test]
    fn doc_comments_quoting_the_contract_are_not_allows() {
        // rule/module docs spell out the syntax — `// fedlint:allow(rule)
        // -- why` — and must parse as documentation, not as malformed or
        // stale suppressions
        let lexed = lex(
            "//! Suppress with `// fedlint:allow(rule) -- reason`.\n\
             /// the `fedlint:allow` contract\n\
             /** fedlint:allow(det-map-iter) -- quoted in a block doc */\n\
             /*! fedlint:allow -- inner block doc */\n\
             let a = 1; // fedlint:allow(det-map-iter) -- real, trailing\n",
        );
        let (allows, bad) = parse_allows(&lexed.comments, &[]);
        assert!(bad.is_empty(), "{bad:?}");
        assert_eq!(allows.len(), 1, "{allows:?}");
        assert_eq!(allows[0].line, 5);
    }
}
