//! The lint configuration: which rules run, at what severity, over
//! which path scopes — parsed from a hand-rolled `fedlint.toml` subset
//! (the vendored crate set has no toml parser, and the lint is meant
//! to stay std-only).
//!
//! Grammar (line-oriented):
//!
//! ```toml
//! # comment
//! [rule.det-map-iter]
//! severity = "deny"
//! paths = ["src/net/", "src/codec/stages.rs"]
//! ```
//!
//! A path ending in `/` scopes a whole directory subtree; a path
//! ending in `.rs` scopes exactly that file. Paths are relative to the
//! linted root, `/`-separated. The committed `rust/fedlint.toml` is
//! compiled into the binary as [`LintConfig::builtin`], so `lint`
//! works from any working directory; an on-disk `fedlint.toml` at the
//! linted root takes precedence when present.

use std::path::Path;

/// Per-rule reporting level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Violations fail the lint (nonzero exit, CI gate).
    Deny,
    /// Violations are reported but do not fail the lint.
    Warn,
    /// Rule disabled.
    Off,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Deny => "deny",
            Severity::Warn => "warn",
            Severity::Off => "off",
        }
    }
}

/// One configured rule: name + severity + path scopes.
#[derive(Clone, Debug)]
pub struct RuleConfig {
    pub name: String,
    pub severity: Severity,
    /// Scope prefixes (`src/net/`) and exact files (`src/net/proto.rs`).
    pub paths: Vec<String>,
}

impl RuleConfig {
    /// Does `rel` (a `/`-separated path relative to the linted root)
    /// fall inside this rule's scope?
    pub fn in_scope(&self, rel: &str) -> bool {
        self.paths.iter().any(|p| {
            if p.ends_with(".rs") {
                rel == p
            } else {
                rel.starts_with(p.as_str())
            }
        })
    }
}

/// The full lint configuration.
#[derive(Clone, Debug, Default)]
pub struct LintConfig {
    pub rules: Vec<RuleConfig>,
}

/// The committed project configuration, compiled in.
const BUILTIN: &str = include_str!("../../fedlint.toml");

impl LintConfig {
    /// The project's own `fedlint.toml`, baked into the binary.
    pub fn builtin() -> LintConfig {
        // the committed config must parse — covered by a unit test
        LintConfig::parse(BUILTIN).unwrap_or_default()
    }

    pub fn from_file(path: &Path) -> Result<LintConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        LintConfig::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn rule(&self, name: &str) -> Option<&RuleConfig> {
        self.rules.iter().find(|r| r.name == name)
    }

    /// Parse the `fedlint.toml` subset. Errors carry the 1-based line.
    pub fn parse(text: &str) -> Result<LintConfig, String> {
        let mut cfg = LintConfig::default();
        let mut current: Option<usize> = None;
        for (idx, raw) in text.lines().enumerate() {
            let lno = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(head) = line.strip_prefix('[') {
                let head = head
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {lno}: unclosed section header"))?;
                let name = head
                    .strip_prefix("rule.")
                    .ok_or_else(|| format!("line {lno}: expected [rule.<name>], got [{head}]"))?;
                if name.is_empty() {
                    return Err(format!("line {lno}: empty rule name"));
                }
                if cfg.rules.iter().any(|r| r.name == name) {
                    return Err(format!("line {lno}: duplicate section [rule.{name}]"));
                }
                cfg.rules.push(RuleConfig {
                    name: name.to_string(),
                    severity: Severity::Deny,
                    paths: Vec::new(),
                });
                current = Some(cfg.rules.len() - 1);
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {lno}: expected key = value"))?;
            let slot = current.ok_or_else(|| {
                format!("line {lno}: '{}' outside any [rule.<name>] section", key.trim())
            })?;
            let Some(rule) = cfg.rules.get_mut(slot) else {
                return Err(format!("line {lno}: internal section index"));
            };
            match key.trim() {
                "severity" => {
                    rule.severity = match parse_string(value.trim(), lno)?.as_str() {
                        "deny" => Severity::Deny,
                        "warn" => Severity::Warn,
                        "off" => Severity::Off,
                        other => {
                            return Err(format!(
                                "line {lno}: severity '{other}' (expected deny|warn|off)"
                            ))
                        }
                    };
                }
                "paths" => rule.paths = parse_string_array(value.trim(), lno)?,
                other => return Err(format!("line {lno}: unknown key '{other}'")),
            }
        }
        Ok(cfg)
    }
}

/// Parse a double-quoted string (no escapes — paths and severities
/// never need them).
fn parse_string(v: &str, lno: usize) -> Result<String, String> {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .filter(|s| !s.contains('"'))
        .map(str::to_string)
        .ok_or_else(|| format!("line {lno}: expected a \"quoted\" string, got {v}"))
}

/// Parse `["a", "b"]` on a single line.
fn parse_string_array(v: &str, lno: usize) -> Result<Vec<String>, String> {
    let inner = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| format!("line {lno}: expected [\"...\"], got {v}"))?;
    let inner = inner.trim();
    if inner.is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|item| {
            let item = item.trim();
            if item.is_empty() {
                Err(format!("line {lno}: empty array element"))
            } else {
                parse_string(item, lno)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_severities_and_scopes() {
        let cfg = LintConfig::parse(
            "# header comment\n\
             [rule.det-map-iter]\n\
             severity = \"deny\"\n\
             paths = [\"src/net/\", \"src/codec/stages.rs\"]\n\
             \n\
             [rule.float-order]\n\
             severity = \"warn\"\n\
             paths = [\"src/codec/\"]\n",
        )
        .unwrap();
        assert_eq!(cfg.rules.len(), 2);
        let r = cfg.rule("det-map-iter").unwrap();
        assert_eq!(r.severity, Severity::Deny);
        assert!(r.in_scope("src/net/frame.rs"));
        assert!(r.in_scope("src/codec/stages.rs"));
        assert!(!r.in_scope("src/codec/registry.rs"), "exact-file scope");
        assert!(!r.in_scope("src/store/record.rs"));
        assert_eq!(cfg.rule("float-order").unwrap().severity, Severity::Warn);
        assert!(cfg.rule("missing").is_none());
    }

    #[test]
    fn rejects_malformed_configs() {
        for bad in [
            "[rule.x",                       // unclosed header
            "[other.x]",                     // not a rule section
            "severity = \"deny\"",           // key outside a section
            "[rule.x]\nseverity = \"hard\"", // unknown severity
            "[rule.x]\npaths = \"src/\"",    // not an array
            "[rule.x]\nwat = \"y\"",         // unknown key
            "[rule.x]\nseverity deny",       // no '='
            "[rule.x]\n[rule.x]",            // duplicate
            "[rule.]",                       // empty name
            "[rule.x]\npaths = [\"a\",]",    // empty element
        ] {
            assert!(LintConfig::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn builtin_config_parses_and_covers_the_known_rules() {
        let cfg = LintConfig::builtin();
        assert!(!cfg.rules.is_empty(), "committed fedlint.toml must parse");
        for name in [
            "det-map-iter",
            "no-panic-decode",
            "no-wallclock-state",
            "rng-discipline",
            "float-order",
            "unsafe-scope",
        ] {
            let rule = cfg.rule(name).unwrap_or_else(|| panic!("missing rule {name}"));
            assert!(!rule.paths.is_empty(), "{name} has no scope");
        }
    }
}
