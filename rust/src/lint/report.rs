//! Reporters for [`super::LintReport`]: human text (file:line + rule +
//! excerpt, plus the allow ledger) and machine JSON for the CI gate
//! artifact. Both are deterministic — the report is pre-sorted and the
//! JSON object keys are BTreeMap-ordered — so reports diff cleanly
//! across runs.

use super::{LintReport, Severity};
use crate::util::json::Json;

/// Human-readable report. Violations first, then the honored-allow
/// ledger (every suppression is visible, never silent), then a
/// one-line summary.
pub fn render_text(report: &LintReport) -> String {
    let mut out = String::new();
    for v in &report.violations {
        out.push_str(&format!(
            "{}:{}: [{}] {}: {}\n",
            v.file,
            v.line,
            v.severity.name(),
            v.rule,
            v.message
        ));
        if !v.excerpt.is_empty() {
            out.push_str(&format!("    {}\n", v.excerpt));
        }
    }
    if !report.allowed.is_empty() {
        out.push_str(&format!("{} allow(s) in effect:\n", report.allowed.len()));
        for a in &report.allowed {
            out.push_str(&format!(
                "    {}:{} allow({}) x{} -- {}\n",
                a.file,
                a.line,
                a.rules.join(", "),
                a.uses,
                a.reason
            ));
        }
    }
    let (deny, warn) = (report.deny_count(), report.warn_count());
    if deny == 0 && warn == 0 {
        out.push_str(&format!(
            "fedlint: {} file(s) scanned, clean ({} allows honored)\n",
            report.files_scanned,
            report.allowed.len()
        ));
    } else {
        out.push_str(&format!(
            "fedlint: {} file(s) scanned, {deny} deny / {warn} warn violation(s), \
             {} allows honored\n",
            report.files_scanned,
            report.allowed.len()
        ));
    }
    out
}

/// JSON report (one line, stable key order) for `lint --json` and the
/// CI artifact.
pub fn render_json(report: &LintReport) -> String {
    let violations: Vec<Json> = report
        .violations
        .iter()
        .map(|v| {
            Json::obj(vec![
                ("file", Json::str(&v.file)),
                ("line", Json::from(v.line as usize)),
                ("rule", Json::str(&v.rule)),
                ("severity", Json::str(v.severity.name())),
                ("message", Json::str(&v.message)),
                ("excerpt", Json::str(&v.excerpt)),
            ])
        })
        .collect();
    let allows: Vec<Json> = report
        .allowed
        .iter()
        .map(|a| {
            Json::obj(vec![
                ("file", Json::str(&a.file)),
                ("line", Json::from(a.line as usize)),
                ("rules", Json::Arr(a.rules.iter().map(|r| Json::str(r)).collect())),
                ("reason", Json::str(&a.reason)),
                ("uses", Json::from(a.uses)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("files_scanned", Json::from(report.files_scanned)),
        ("deny", Json::from(report.deny_count())),
        ("warn", Json::from(report.warn_count())),
        ("violations", Json::Arr(violations)),
        ("allows", Json::Arr(allows)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{AllowedSite, Violation};

    fn sample() -> LintReport {
        LintReport {
            violations: vec![Violation {
                file: "src/net/proto.rs".into(),
                line: 42,
                rule: "no-panic-decode".into(),
                severity: Severity::Deny,
                message: "unwrap in decode".into(),
                excerpt: "x.unwrap()".into(),
            }],
            allowed: vec![AllowedSite {
                file: "src/store/record.rs".into(),
                line: 84,
                rules: vec!["no-wallclock-state".into()],
                reason: "created_unix is an environment field".into(),
                uses: 1,
            }],
            files_scanned: 7,
        }
    }

    #[test]
    fn text_report_has_file_line_rule_and_allow_ledger() {
        let text = render_text(&sample());
        assert!(text.contains("src/net/proto.rs:42: [deny] no-panic-decode"), "{text}");
        assert!(text.contains("x.unwrap()"), "{text}");
        assert!(text.contains("allow(no-wallclock-state) x1"), "{text}");
        assert!(text.contains("1 deny / 0 warn"), "{text}");
    }

    #[test]
    fn json_report_round_trips_through_the_parser() {
        let parsed = Json::parse(&render_json(&sample())).unwrap();
        assert_eq!(parsed.get("deny").unwrap().as_usize().unwrap(), 1);
        assert_eq!(parsed.get("warn").unwrap().as_usize().unwrap(), 0);
        assert_eq!(parsed.get("files_scanned").unwrap().as_usize().unwrap(), 7);
        let v = parsed.get("violations").unwrap().as_arr().unwrap();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].get("line").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v[0].get("rule").unwrap().as_str().unwrap(), "no-panic-decode");
        let a = parsed.get("allows").unwrap().as_arr().unwrap();
        assert_eq!(a[0].get("uses").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn clean_report_says_so() {
        let clean = LintReport {
            files_scanned: 3,
            ..Default::default()
        };
        assert!(render_text(&clean).contains("clean"));
        let parsed = Json::parse(&render_json(&clean)).unwrap();
        assert_eq!(parsed.get("deny").unwrap().as_usize().unwrap(), 0);
    }
}
