//! A lightweight Rust tokenizer for fedlint — comments, strings,
//! identifiers, numbers, and punctuation with line spans. Deliberately
//! *not* a full parser: the lint rules are heuristics over the token
//! stream, and a token stream is all they need. The lexer must accept
//! arbitrary bytes without panicking (it lints work-in-progress files),
//! so every branch degrades gracefully: an unterminated string runs to
//! end of file, an unknown character becomes punctuation.
//!
//! What it does understand, because the rules depend on it:
//! * line (`//`) and nested block (`/* /* */ */`) comments, captured
//!   separately from the token stream (the `fedlint:allow` contract
//!   lives in comments);
//! * string/char/byte/raw-string literals (`"…"`, `'…'`, `b"…"`,
//!   `r#"…"#`, …) so quoted text can never fake a violation or an
//!   allow;
//! * lifetimes vs char literals (`'a` vs `'a'`);
//! * `#[cfg(test)] mod … { … }` blocks, reported as line ranges so
//!   rules can exempt inline unit tests.

/// Token kinds — just enough structure for heuristic rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `unwrap`, `mut`, ...).
    Ident,
    /// Single punctuation character (`.`, `[`, `!`, `:`, ...).
    Punct,
    /// Numeric literal (`42`, `0xFF`, `1.5e-3`, `1_000u64`).
    Num,
    /// String literal of any flavor, content included.
    Str,
    /// Char or byte literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

/// A comment, kept out of the token stream.
#[derive(Clone, Debug)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: u32,
    /// Full text including the `//` / `/*` introducer.
    pub text: String,
    /// True when code precedes the comment on its starting line
    /// (a trailing comment annotates its own line; a standalone
    /// comment annotates the next code line).
    pub trailing: bool,
}

/// Lexer output: token stream plus the comment side-channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

struct Scan {
    chars: Vec<char>,
    i: usize,
    line: u32,
}

impl Scan {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.i).copied()
    }

    fn peek_at(&self, k: usize) -> Option<char> {
        self.i.checked_add(k).and_then(|j| self.chars.get(j)).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Total over all inputs: never panics, never loops
/// forever (every iteration of the main loop consumes at least one
/// character).
pub fn lex(src: &str) -> Lexed {
    let mut s = Scan {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
    };
    let mut out = Lexed::default();
    // line number of the most recent token, to classify comments as
    // trailing (code before them on the same line) or standalone
    let mut last_tok_line = 0u32;

    while let Some(c) = s.peek() {
        let line = s.line;
        if c.is_whitespace() {
            s.bump();
        } else if c == '/' && s.peek_at(1) == Some('/') {
            let mut text = String::new();
            while let Some(ch) = s.peek() {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                s.bump();
            }
            out.comments.push(Comment {
                line,
                text,
                trailing: last_tok_line == line,
            });
        } else if c == '/' && s.peek_at(1) == Some('*') {
            let mut text = String::new();
            let mut depth = 0u32;
            while let Some(ch) = s.peek() {
                if ch == '/' && s.peek_at(1) == Some('*') {
                    depth += 1;
                    text.push('/');
                    text.push('*');
                    s.bump();
                    s.bump();
                } else if ch == '*' && s.peek_at(1) == Some('/') {
                    depth = depth.saturating_sub(1);
                    text.push('*');
                    text.push('/');
                    s.bump();
                    s.bump();
                    if depth == 0 {
                        break;
                    }
                } else {
                    text.push(ch);
                    s.bump();
                }
            }
            out.comments.push(Comment {
                line,
                text,
                trailing: last_tok_line == line,
            });
        } else if c == '"' {
            let text = lex_string(&mut s);
            out.toks.push(Tok { kind: TokKind::Str, text, line });
            last_tok_line = line;
        } else if c == '\'' {
            let (kind, text) = lex_quote(&mut s);
            out.toks.push(Tok { kind, text, line });
            last_tok_line = line;
        } else if is_ident_start(c) {
            let mut text = String::new();
            while let Some(ch) = s.peek() {
                if !is_ident_continue(ch) {
                    break;
                }
                text.push(ch);
                s.bump();
            }
            // raw / byte string prefixes: r"", r#""#, b"", br"", c"", …
            let is_raw = matches!(text.as_str(), "r" | "br" | "cr") && raw_string_follows(&s);
            let is_bstr = matches!(text.as_str(), "b" | "c") && s.peek() == Some('"');
            let is_bchar = text == "b" && s.peek() == Some('\'');
            let (kind, text) = if is_raw {
                (TokKind::Str, lex_raw_string(&mut s, text))
            } else if is_bstr {
                let mut t = text;
                t.push_str(&lex_string(&mut s));
                (TokKind::Str, t)
            } else if is_bchar {
                let (_, q) = lex_quote(&mut s);
                let mut t = text;
                t.push_str(&q);
                (TokKind::Char, t)
            } else {
                (TokKind::Ident, text)
            };
            out.toks.push(Tok { kind, text, line });
            last_tok_line = line;
        } else if c.is_ascii_digit() {
            let mut text = String::new();
            let mut seen_dot = false;
            while let Some(ch) = s.peek() {
                if is_ident_continue(ch) {
                    text.push(ch);
                    s.bump();
                } else if ch == '.'
                    && !seen_dot
                    && s.peek_at(1).is_some_and(|d| d.is_ascii_digit())
                {
                    seen_dot = true;
                    text.push(ch);
                    s.bump();
                } else {
                    break;
                }
            }
            out.toks.push(Tok { kind: TokKind::Num, text, line });
            last_tok_line = line;
        } else {
            s.bump();
            out.toks.push(Tok {
                kind: TokKind::Punct,
                text: c.to_string(),
                line,
            });
            last_tok_line = line;
        }
    }
    out
}

/// After an `r`/`br`/`cr` identifier: true when `#*"` follows (a raw
/// string, not a raw identifier like `r#type`).
fn raw_string_follows(s: &Scan) -> bool {
    let mut k = 0;
    while s.peek_at(k) == Some('#') {
        k += 1;
    }
    s.peek_at(k) == Some('"')
}

/// Consume a raw string body (cursor sits on the first `#` or the
/// opening quote); `prefix` is the already-consumed `r`/`br`/`cr`.
fn lex_raw_string(s: &mut Scan, prefix: String) -> String {
    let mut text = prefix;
    let mut hashes = 0usize;
    while s.peek() == Some('#') {
        hashes += 1;
        text.push('#');
        s.bump();
    }
    if s.peek() == Some('"') {
        text.push('"');
        s.bump();
    }
    // body runs until `"` followed by `hashes` `#`s (or end of input)
    while let Some(ch) = s.peek() {
        if ch == '"' && (0..hashes).all(|k| s.peek_at(1 + k) == Some('#')) {
            text.push('"');
            s.bump();
            for _ in 0..hashes {
                text.push('#');
                s.bump();
            }
            break;
        }
        text.push(ch);
        s.bump();
    }
    text
}

/// Consume a `"…"` string with escapes (cursor on the opening quote).
/// Unterminated strings run to end of input.
fn lex_string(s: &mut Scan) -> String {
    let mut text = String::new();
    text.push('"');
    s.bump();
    while let Some(ch) = s.bump() {
        text.push(ch);
        if ch == '\\' {
            if let Some(esc) = s.bump() {
                text.push(esc);
            }
        } else if ch == '"' {
            break;
        }
    }
    text
}

/// Disambiguate `'` between a lifetime (`'a`, `'static`) and a char
/// literal (`'x'`, `'\n'`, `'\u{1F600}'`). Cursor on the quote.
fn lex_quote(s: &mut Scan) -> (TokKind, String) {
    let mut text = String::new();
    text.push('\'');
    s.bump();
    let first = s.peek();
    // `'ident` not followed by a closing quote is a lifetime
    if first.is_some_and(is_ident_start) {
        let mut k = 1;
        while s.peek_at(k).is_some_and(is_ident_continue) {
            k += 1;
        }
        if s.peek_at(k) != Some('\'') {
            while s.peek().is_some_and(is_ident_continue) {
                if let Some(ch) = s.bump() {
                    text.push(ch);
                }
            }
            return (TokKind::Lifetime, text);
        }
    }
    // char literal: escapes may span several chars (`'\u{…}'`); cap
    // the scan so malformed input can't absorb the rest of the file
    let mut budget = 16;
    while let Some(ch) = s.bump() {
        text.push(ch);
        if ch == '\\' {
            if let Some(esc) = s.bump() {
                text.push(esc);
            }
        } else if ch == '\'' {
            break;
        }
        budget -= 1;
        if budget == 0 {
            break;
        }
    }
    (TokKind::Char, text)
}

/// 1-based inclusive line ranges of `#[cfg(test)] mod … { … }` blocks,
/// so rules can exempt inline unit tests (test code asserts and
/// unwraps by design). Conservative: an unmatched brace extends the
/// range to the last token.
pub fn test_line_ranges(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let is = |t: Option<&Tok>, kind: TokKind, text: &str| {
        t.is_some_and(|t| t.kind == kind && t.text == text)
    };
    let mut i = 0;
    while i < toks.len() {
        // match `# [ cfg ( test ) ]`
        let m = is(toks.get(i), TokKind::Punct, "#")
            && is(toks.get(i + 1), TokKind::Punct, "[")
            && is(toks.get(i + 2), TokKind::Ident, "cfg")
            && is(toks.get(i + 3), TokKind::Punct, "(")
            && is(toks.get(i + 4), TokKind::Ident, "test")
            && is(toks.get(i + 5), TokKind::Punct, ")")
            && is(toks.get(i + 6), TokKind::Punct, "]");
        if !m {
            i += 1;
            continue;
        }
        let start_line = toks.get(i).map_or(0, |t| t.line);
        let mut j = i + 7;
        // skip further attributes between the cfg and the item
        while is(toks.get(j), TokKind::Punct, "#") && is(toks.get(j + 1), TokKind::Punct, "[") {
            let mut depth = 0usize;
            while let Some(t) = toks.get(j) {
                if t.kind == TokKind::Punct && t.text == "[" {
                    depth += 1;
                } else if t.kind == TokKind::Punct && t.text == "]" {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        if !is(toks.get(j), TokKind::Ident, "mod") {
            i += 7;
            continue;
        }
        // find the block's opening brace, then match braces to its end
        while let Some(t) = toks.get(j) {
            if t.kind == TokKind::Punct && (t.text == "{" || t.text == ";") {
                break;
            }
            j += 1;
        }
        if is(toks.get(j), TokKind::Punct, ";") {
            i = j + 1; // `#[cfg(test)] mod tests;` — out-of-line, no range
            continue;
        }
        let mut depth = 0usize;
        let mut end_line = toks.last().map_or(start_line, |t| t.line);
        while let Some(t) = toks.get(j) {
            if t.kind == TokKind::Punct && t.text == "{" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == "}" {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    end_line = t.line;
                    break;
                }
            }
            j += 1;
        }
        ranges.push((start_line, end_line));
        i = j + 1;
    }
    ranges
}

/// True when `line` falls inside any of `ranges` (inclusive).
pub fn in_ranges(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| a <= line && line <= b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_never_leak_tokens() {
        let src = r##"
            let a = "HashMap::new() // not a comment";
            // HashMap in a comment is not a token
            let b = 'x'; /* Instant::now */
            let c = r#"SystemTime "quoted" raw"#;
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
        assert!(!ids.contains(&"SystemTime".to_string()), "{ids:?}");
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 2);
        assert!(lexed.comments[1].text.contains("Instant::now"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lexed = lex("fn f<'a>(x: &'a str) -> char { 'a' }");
        let lifetimes: Vec<_> = lexed
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .collect();
        assert_eq!(lifetimes.len(), 2);
        let chars: Vec<_> = lexed.toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(chars.len(), 1);
        assert_eq!(chars[0].text, "'a'");
    }

    #[test]
    fn lines_are_tracked_through_multiline_constructs() {
        let src = "let a = \"x\ny\";\n/* c\nc */\nlet b = 1;";
        let lexed = lex(src);
        let b = lexed.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 5);
        assert_eq!(lexed.comments[0].line, 3);
    }

    #[test]
    fn trailing_vs_standalone_comments() {
        let src = "let a = 1; // trailing\n// standalone\nlet b = 2;";
        let lexed = lex(src);
        assert!(lexed.comments[0].trailing);
        assert!(!lexed.comments[1].trailing);
    }

    #[test]
    fn raw_identifiers_are_not_raw_strings() {
        let ids = idents("let r#type = 1; let r = 2;");
        assert!(ids.contains(&"r".to_string()));
        assert!(ids.contains(&"type".to_string()));
    }

    #[test]
    fn cfg_test_mod_ranges_cover_the_block() {
        let src = "\
fn real() {}
#[cfg(test)]
mod tests {
    fn t() { let x = vec![1]; }
}
fn after() {}
";
        let lexed = lex(src);
        let ranges = test_line_ranges(&lexed.toks);
        assert_eq!(ranges.len(), 1);
        assert!(in_ranges(&ranges, 3));
        assert!(in_ranges(&ranges, 4));
        assert!(!in_ranges(&ranges, 1));
        assert!(!in_ranges(&ranges, 6));
    }

    #[test]
    fn cfg_test_with_extra_attributes_still_matches() {
        let src = "\
#[cfg(test)]
#[allow(dead_code)]
mod tests { fn t() {} }
fn real() {}
";
        let ranges = test_line_ranges(&lex(src).toks);
        assert_eq!(ranges.len(), 1);
        assert!(in_ranges(&ranges, 3));
        assert!(!in_ranges(&ranges, 4));
    }

    #[test]
    fn pathological_inputs_do_not_panic() {
        for src in [
            "",
            "\"unterminated",
            "'",
            "'\\",
            "r#\"unterminated raw",
            "/* unterminated /* nested",
            "#[cfg(test)] mod t {",
            "b'",
            "1.2.3.4",
            "\u{1F600}\u{1F600}",
            "'''''",
            "r#####",
        ] {
            let lexed = lex(src);
            let _ = test_line_ranges(&lexed.toks);
        }
    }
}
