//! Mini property-based testing framework (proptest is not in the
//! vendored crate set).
//!
//! Deterministic, seeded case generation with linear input shrinking:
//! `forall(cases, gen, prop)` runs `prop` over `cases` generated inputs;
//! on failure it retries progressively "smaller" inputs from the
//! generator's shrink channel and reports the smallest failing seed so
//! the case is reproducible.

use crate::util::rng::Rng;

/// A generator produces a value from an rng at a given "size" level.
/// Smaller sizes should produce structurally smaller values.
pub trait Gen {
    type Value;
    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Value;
}

/// Generator from a closure.
pub struct FnGen<F>(pub F);

impl<F, V> Gen for FnGen<F>
where
    F: Fn(&mut Rng, usize) -> V,
{
    type Value = V;
    fn generate(&self, rng: &mut Rng, size: usize) -> V {
        (self.0)(rng, size)
    }
}

/// Vec of f32 drawn from N(0, scale), length in [1, size.max(1)].
pub fn vec_f32(scale: f32) -> impl Gen<Value = Vec<f32>> {
    FnGen(move |rng: &mut Rng, size: usize| {
        let n = 1 + rng.below(size.max(1));
        (0..n).map(|_| rng.normal() * scale).collect()
    })
}

/// usize in [lo, hi].
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<Value = usize> {
    FnGen(move |rng: &mut Rng, _| lo + rng.below(hi - lo + 1))
}

/// Pair generator.
pub fn pair<A: Gen, B: Gen>(a: A, b: B) -> impl Gen<Value = (A::Value, B::Value)> {
    FnGen(move |rng: &mut Rng, size: usize| (a.generate(rng, size), b.generate(rng, size)))
}

/// Outcome carrying the reproducing seed on failure.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop` over `cases` generated inputs with growing size, then on
/// failure search smaller sizes at the same seed (input shrinking).
/// Panics with the smallest reproduction found.
pub fn forall<G, F>(cases: usize, base_seed: u64, gen: &G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(case as u64);
        let size = 4 + (case * 97) % 500; // sweep sizes deterministically
        let mut rng = Rng::new(seed);
        let value = gen.generate(&mut rng, size);
        if let Err(msg) = prop(&value) {
            // shrink: retry the same seed at smaller sizes
            let mut best = Failure {
                seed,
                size,
                message: msg,
            };
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(seed);
                let v = gen.generate(&mut rng, s);
                if let Err(m) = prop(&v) {
                    best = Failure {
                        seed,
                        size: s,
                        message: m,
                    };
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property failed (seed={}, size={}): {}",
                best.seed, best.size, best.message
            );
        }
    }
}

/// Assertion helpers returning Result for use inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, 1, &vec_f32(1.0), |v| {
            ensure(!v.is_empty(), "generated empty vec")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(50, 2, &usize_in(0, 100), |&n| {
            ensure(n < 40, format!("n={n} too big"))
        });
    }

    #[test]
    fn pair_generator_composes() {
        forall(20, 3, &pair(vec_f32(1.0), usize_in(1, 8)), |(v, k)| {
            ensure(*k >= 1 && !v.is_empty(), "bad pair")
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let g = vec_f32(1.0);
        let a = g.generate(&mut Rng::new(7), 10);
        let b = g.generate(&mut Rng::new(7), 10);
        assert_eq!(a, b);
    }
}
