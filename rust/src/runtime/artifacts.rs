//! Manifest loader: the contract between `python/compile/aot.py` and
//! the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::models::ModelSpec;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dtype = match j.get("dtype")?.as_str()? {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unknown dtype '{other}'"),
        };
        Ok(TensorSpec {
            shape: j.get("shape")?.usize_array()?,
            dtype,
        })
    }
}

#[derive(Clone, Debug)]
pub struct EntrySignature {
    pub inputs: Vec<TensorSpec>,
    pub output_shapes: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct DatasetManifest {
    pub spec: ModelSpec,
    /// entry name -> artifact filename
    pub artifacts: BTreeMap<String, String>,
    pub signatures: BTreeMap<String, EntrySignature>,
    pub init_theta: String,
    pub golden_dir: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub c_max: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub tau: f64,
    pub block: usize,
    pub datasets: BTreeMap<String, DatasetManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut datasets = BTreeMap::new();
        for (name, ds) in j.get("datasets")?.as_obj()? {
            let spec = ModelSpec::from_manifest(name, ds)?;
            let artifacts = ds
                .get("artifacts")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
                .collect::<Result<BTreeMap<_, _>>>()?;
            let signatures = ds
                .get("entry_signatures")?
                .as_obj()?
                .iter()
                .map(|(k, v)| {
                    let inputs = v
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?;
                    let output_shapes = v
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(|o| o.usize_array())
                        .collect::<Result<Vec<_>>>()?;
                    Ok((
                        k.clone(),
                        EntrySignature {
                            inputs,
                            output_shapes,
                        },
                    ))
                })
                .collect::<Result<BTreeMap<_, _>>>()?;
            datasets.insert(
                name.clone(),
                DatasetManifest {
                    spec,
                    artifacts,
                    signatures,
                    init_theta: ds.get("init_theta")?.as_str()?.to_string(),
                    golden_dir: ds.get("golden_dir")?.as_str()?.to_string(),
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            c_max: j.get("c_max")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            tau: j.get("tau")?.as_f64()?,
            block: j.get("block")?.as_usize()?,
            datasets,
        })
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetManifest> {
        self.datasets
            .get(name)
            .with_context(|| format!("dataset '{name}' not in manifest"))
    }

    /// Read a raw little-endian f32 binary (init params, goldens).
    pub fn read_f32_bin(&self, rel: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(rel);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path:?}: length not a multiple of 4");
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn read_i32_bin(&self, rel: &str) -> Result<Vec<i32>> {
        let path = self.dir.join(rel);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path:?}: length not a multiple of 4");
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Default artifacts directory: $FEDCOMPRESS_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var("FEDCOMPRESS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&default_dir()).unwrap();
        assert_eq!(m.datasets.len(), 5);
        assert_eq!(m.c_max, 32);
        let ds = m.dataset("cifar10").unwrap();
        assert_eq!(ds.spec.num_classes, 10);
        assert!(ds.artifacts.contains_key("train_step"));
        assert_eq!(ds.signatures["train_step"].inputs.len(), 7);
        // init theta matches the declared param count
        let theta = m.read_f32_bin(&ds.init_theta).unwrap();
        assert_eq!(theta.len(), ds.spec.param_count);
    }

    #[test]
    fn missing_dataset_errors() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&default_dir()).unwrap();
        assert!(m.dataset("imagenet").is_err());
    }
}
