//! Conversions between rust buffers and XLA literals.

use anyhow::{bail, Result};
use xla::Literal;

use super::artifacts::{DType, TensorSpec};

/// Build a literal matching a tensor spec from a flat buffer.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        bail!("literal shape mismatch: {} elems vs shape {shape:?}", data.len());
    }
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        bail!("literal shape mismatch: {} elems vs shape {shape:?}", data.len());
    }
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Typed dispatch against a signature entry.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Scalar(f32),
}

pub fn to_literal(arg: &Arg<'_>, spec: &TensorSpec) -> Result<Literal> {
    match (arg, spec.dtype) {
        (Arg::F32(d), DType::F32) => f32_literal(d, &spec.shape),
        (Arg::I32(d), DType::I32) => i32_literal(d, &spec.shape),
        (Arg::Scalar(v), DType::F32) => {
            if !spec.shape.is_empty() {
                bail!("scalar arg for non-scalar spec {:?}", spec.shape);
            }
            Ok(Literal::scalar(*v))
        }
        _ => bail!("dtype mismatch between arg and spec"),
    }
}

pub fn literal_to_f32(l: &Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

pub fn literal_to_i32(l: &Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

pub fn literal_scalar_f32(l: &Literal) -> Result<f32> {
    let v = l.to_vec::<f32>()?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}
