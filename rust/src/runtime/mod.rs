//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client from the rust hot path.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//! Executables are compiled once per (dataset, entry) and cached.
//!
//! The `xla` crate's PjRtClient wraps `Rc` (not Send), so the engine is
//! thread-confined: the coordinator owns it on the main thread and
//! simulated clients execute through it sequentially — faithful to a
//! single shared accelerator, and XLA's own intra-op thread pool keeps
//! the cores busy.

pub mod artifacts;
pub mod engine;
pub mod literals;

pub use artifacts::{DatasetManifest, EntrySignature, Manifest, TensorSpec};
pub use engine::Engine;
