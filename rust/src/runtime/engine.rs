//! The PJRT execution engine: artifact loading, executable caching, and
//! the typed `run` entry the coordinator/client layers call.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::{default_dir, Manifest};
use super::literals::{to_literal, Arg};
use crate::info;

pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<(String, String), Rc<PjRtLoadedExecutable>>>,
    /// executions performed (for perf accounting)
    exec_count: RefCell<u64>,
}

impl Engine {
    /// Load the manifest and stand up the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        info!(
            "runtime: platform={} devices={} datasets={}",
            client.platform_name(),
            client.device_count(),
            manifest.datasets.len()
        );
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    pub fn load_default() -> Result<Engine> {
        Engine::load(&default_dir())
    }

    /// Compile (or fetch from cache) the executable for an entry point.
    pub fn executable(&self, dataset: &str, entry: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        let key = (dataset.to_string(), entry.to_string());
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(Rc::clone(exe));
        }
        let ds = self.manifest.dataset(dataset)?;
        let fname = ds
            .artifacts
            .get(entry)
            .with_context(|| format!("no artifact for entry '{entry}'"))?;
        let path = self.manifest.dir.join(fname);
        let sw = crate::util::timer::Stopwatch::start();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        info!(
            "runtime: compiled {dataset}.{entry} in {:.0} ms",
            sw.elapsed_ms()
        );
        self.cache.borrow_mut().insert(key, Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an entry point with typed args; returns the un-tupled
    /// output literals. Arg count and shapes are validated against the
    /// manifest signature before touching PJRT.
    pub fn run(&self, dataset: &str, entry: &str, args: &[Arg<'_>]) -> Result<Vec<Literal>> {
        let ds = self.manifest.dataset(dataset)?;
        let sig = ds
            .signatures
            .get(entry)
            .with_context(|| format!("no signature for entry '{entry}'"))?;
        anyhow::ensure!(
            args.len() == sig.inputs.len(),
            "{dataset}.{entry}: expected {} args, got {}",
            sig.inputs.len(),
            args.len()
        );
        let literals: Vec<Literal> = args
            .iter()
            .zip(&sig.inputs)
            .map(|(a, s)| to_literal(a, s))
            .collect::<Result<_>>()?;

        let exe = self.executable(dataset, entry)?;
        let result = exe.execute::<Literal>(&literals)?;
        *self.exec_count.borrow_mut() += 1;
        // lowered with return_tuple=True: single tuple output
        let mut tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.decompose_tuple()?)
    }

    /// Initial (He-init, seed 0) flat parameters for a dataset's model.
    pub fn init_theta(&self, dataset: &str) -> Result<Vec<f32>> {
        let ds = self.manifest.dataset(dataset)?;
        self.manifest.read_f32_bin(&ds.init_theta)
    }

    pub fn exec_count(&self) -> u64 {
        *self.exec_count.borrow()
    }

    /// Pre-compile every entry point for a dataset (startup warm-up so
    /// the first federated round doesn't pay compile latency).
    pub fn warmup(&self, dataset: &str) -> Result<()> {
        let entries: Vec<String> = self
            .manifest
            .dataset(dataset)?
            .artifacts
            .keys()
            .cloned()
            .collect();
        for e in entries {
            self.executable(dataset, &e)?;
        }
        Ok(())
    }
}
