//! Typed experiment configuration with JSON file loading, CLI override
//! hooks, validation, and the two standard presets:
//! * `paper`  — Table 1 parameters (R=20, M=20, E_c=10, E_s=10, σ=25%)
//! * `quick`  — CI-sized preset exercising every code path in minutes

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::clustering::ControllerConfig;
use crate::sim::{FleetConfig, FleetPreset};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct FedConfig {
    pub dataset: String,
    /// federated rounds R
    pub rounds: usize,
    /// total clients M
    pub clients: usize,
    /// fraction of clients participating per round
    pub participation: f64,
    /// local train epochs E_c
    pub local_epochs: usize,
    /// server self-compression epochs E_s
    pub server_epochs: usize,
    /// total training samples (partitioned across clients)
    pub train_size: usize,
    pub test_size: usize,
    /// server OOD set size
    pub ood_size: usize,
    /// per-client unlabeled shard |D_u| (carved from the client's data)
    pub unlabeled_per_client: usize,
    /// label heterogeneity (paper's sigma, 0.25 in Table 1)
    pub sigma: f64,
    pub lr_client: f32,
    pub lr_server: f32,
    /// weight-clustering loss weight once engaged
    pub beta: f32,
    /// local epochs with beta=0 before engaging L_wc (paper §1.2)
    pub beta_warmup_epochs: usize,
    /// federated rounds of plain L_ce (dense wire, no SCS) before the
    /// compression machinery engages. The paper "allow[s] for a few
    /// training rounds using L_ce before introducing L_wc"; its 4.5x
    /// CCR over R=20 back-solves to ~2-3 dense rounds (DESIGN.md §3).
    pub warmup_rounds: usize,
    /// distillation temperature lambda
    pub temperature: f32,
    pub controller: ControllerConfig,
    /// FedZip's fixed cluster count (paper: 15)
    pub fedzip_clusters: usize,
    /// FedZip magnitude-prune keep fraction
    pub fedzip_keep: f64,
    /// top-k sparsification keep fraction (the `topk` strategy)
    pub topk_keep: f64,
    /// worker threads for the parallel client encode step (0 = auto)
    pub upload_workers: usize,
    /// codec pipeline spec overriding every strategy's compressed
    /// *upload* path (e.g. `topk|kmeans|huffman`; see `--codec list`).
    /// Empty = each strategy's declared default, byte-identical to the
    /// pre-codec-API runs. Sweepable via `--axis codec=a,b`.
    pub codec: String,
    /// fleet simulation knobs: preset, extra dropout, round deadline.
    /// The default is the ideal fleet — byte-identical to pre-sim runs.
    pub fleet: FleetConfig,
    pub seed: u64,
    /// wall-clock seconds a connecting peer gets to complete the TCP
    /// handshake before being dropped (0 = wait forever). Real time,
    /// not sim time: it guards `serve` against port scanners, so it
    /// never touches metrics.
    pub handshake_timeout_s: f64,
}

impl FedConfig {
    /// Table 1 parameters.
    pub fn paper(dataset: &str) -> FedConfig {
        FedConfig {
            dataset: dataset.to_string(),
            rounds: 20,
            clients: 20,
            participation: 1.0,
            local_epochs: 10,
            server_epochs: 10,
            train_size: 2000,
            test_size: 512,
            ood_size: 256,
            unlabeled_per_client: 32,
            sigma: 0.25,
            lr_client: 0.05,
            lr_server: 0.05,
            beta: 0.1,
            beta_warmup_epochs: 5,
            warmup_rounds: 3,
            temperature: 2.0,
            controller: ControllerConfig::default(),
            fedzip_clusters: 15,
            fedzip_keep: 0.6,
            topk_keep: 0.1,
            upload_workers: 0,
            codec: String::new(),
            fleet: FleetConfig::default(),
            seed: 42,
            handshake_timeout_s: 30.0,
        }
    }

    /// Small preset for CI / smoke experiments: every code path, minutes
    /// not hours.
    pub fn quick(dataset: &str) -> FedConfig {
        FedConfig {
            rounds: 8,
            clients: 6,
            local_epochs: 6,
            server_epochs: 3,
            train_size: 576,
            test_size: 192,
            ood_size: 96,
            unlabeled_per_client: 32,
            beta_warmup_epochs: 3,
            warmup_rounds: 2,
            ..FedConfig::paper(dataset)
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.rounds == 0 || self.clients == 0 {
            bail!("rounds and clients must be positive");
        }
        if !(0.0..=1.0).contains(&self.participation) || self.participation == 0.0 {
            bail!("participation must be in (0, 1]");
        }
        if self.train_size / self.clients < 8 {
            bail!(
                "too little data per client: {} samples / {} clients",
                self.train_size,
                self.clients
            );
        }
        if !(0.0..1.0).contains(&self.sigma) {
            bail!("sigma must be in [0, 1)");
        }
        if self.controller.c_min < 2 {
            bail!("c_min must be >= 2");
        }
        if !(self.topk_keep > 0.0 && self.topk_keep <= 1.0) {
            bail!("topk_keep must be in (0, 1]");
        }
        if !self.codec.is_empty() {
            // resolve against the built-in codec registry so typos fail
            // here (with a suggestion), before anything runs
            crate::codec::CodecRegistry::builtin()
                .build(&self.codec)
                .map_err(|e| anyhow::anyhow!("codec '{}': {e}", self.codec))?;
        }
        if !(0.0..1.0).contains(&self.fleet.dropout) {
            bail!("fleet dropout must be in [0, 1)");
        }
        if !(self.fleet.deadline_s >= 0.0 && self.fleet.deadline_s.is_finite()) {
            bail!("fleet deadline_s must be finite and >= 0");
        }
        if !(self.handshake_timeout_s >= 0.0 && self.handshake_timeout_s.is_finite()) {
            bail!("handshake_timeout_s must be finite and >= 0");
        }
        Ok(())
    }

    /// Apply `key=value` overrides (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let e = || format!("invalid value '{value}' for '{key}'");
        match key {
            "dataset" => self.dataset = value.to_string(),
            "rounds" => self.rounds = value.parse().with_context(e)?,
            "clients" => self.clients = value.parse().with_context(e)?,
            "participation" => self.participation = value.parse().with_context(e)?,
            "local_epochs" => self.local_epochs = value.parse().with_context(e)?,
            "server_epochs" => self.server_epochs = value.parse().with_context(e)?,
            "train_size" => self.train_size = value.parse().with_context(e)?,
            "test_size" => self.test_size = value.parse().with_context(e)?,
            "ood_size" => self.ood_size = value.parse().with_context(e)?,
            "unlabeled_per_client" => {
                self.unlabeled_per_client = value.parse().with_context(e)?
            }
            "sigma" => self.sigma = value.parse().with_context(e)?,
            "lr_client" => self.lr_client = value.parse().with_context(e)?,
            "lr_server" => self.lr_server = value.parse().with_context(e)?,
            "beta" => self.beta = value.parse().with_context(e)?,
            "beta_warmup_epochs" => {
                self.beta_warmup_epochs = value.parse().with_context(e)?
            }
            "warmup_rounds" => self.warmup_rounds = value.parse().with_context(e)?,
            "temperature" => self.temperature = value.parse().with_context(e)?,
            "c_min" => self.controller.c_min = value.parse().with_context(e)?,
            "c_max" => self.controller.c_max = value.parse().with_context(e)?,
            "c_step" => self.controller.step = value.parse().with_context(e)?,
            "window" => self.controller.window = value.parse().with_context(e)?,
            "patience" => self.controller.patience = value.parse().with_context(e)?,
            "fedzip_clusters" => self.fedzip_clusters = value.parse().with_context(e)?,
            "fedzip_keep" => self.fedzip_keep = value.parse().with_context(e)?,
            "topk_keep" => self.topk_keep = value.parse().with_context(e)?,
            "workers" | "upload_workers" => {
                self.upload_workers = value.parse().with_context(e)?
            }
            "codec" => self.codec = value.to_string(),
            "fleet" => self.fleet.preset = FleetPreset::from_name(value)?,
            "dropout" => self.fleet.dropout = value.parse().with_context(e)?,
            "deadline_s" => self.fleet.deadline_s = value.parse().with_context(e)?,
            "edge_of" => self.fleet.edge_of = value.parse().with_context(e)?,
            "seed" => self.seed = value.parse().with_context(e)?,
            "handshake_timeout_s" => {
                self.handshake_timeout_s = value.parse().with_context(e)?
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Load overrides from a JSON object file on top of a preset.
    pub fn load_overrides(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let j = Json::parse(&text)?;
        for (k, v) in j.as_obj()? {
            let s = match v {
                Json::Str(s) => s.clone(),
                other => other.to_string(),
            };
            self.set(k, &s)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        FedConfig::paper("cifar10").validate().unwrap();
        FedConfig::quick("voxforge").validate().unwrap();
    }

    #[test]
    fn set_overrides() {
        let mut c = FedConfig::quick("cifar10");
        c.set("rounds", "3").unwrap();
        c.set("sigma", "0.5").unwrap();
        c.set("c_min", "4").unwrap();
        assert_eq!(c.rounds, 3);
        assert_eq!(c.sigma, 0.5);
        assert_eq!(c.controller.c_min, 4);
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("rounds", "abc").is_err());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = FedConfig::quick("cifar10");
        c.rounds = 0;
        assert!(c.validate().is_err());
        let mut c = FedConfig::quick("cifar10");
        c.train_size = 10;
        assert!(c.validate().is_err());
        let mut c = FedConfig::quick("cifar10");
        c.sigma = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn topk_keep_validation_and_override() {
        let mut c = FedConfig::quick("cifar10");
        c.set("topk_keep", "0.25").unwrap();
        assert_eq!(c.topk_keep, 0.25);
        c.set("workers", "2").unwrap();
        assert_eq!(c.upload_workers, 2);
        c.topk_keep = 0.0;
        assert!(c.validate().is_err());
        c.topk_keep = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn codec_override_and_validation() {
        let mut c = FedConfig::quick("cifar10");
        assert!(c.codec.is_empty(), "default must be the declared pipelines");
        c.set("codec", "topk(keep=0.2)|kmeans(c=8)|huffman").unwrap();
        c.validate().unwrap();
        c.set("codec", "topk|kmean").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("did you mean 'kmeans'"), "{err}");
        c.set("codec", "").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn fleet_overrides_and_validation() {
        let mut c = FedConfig::quick("cifar10");
        assert!(c.fleet.is_ideal(), "default fleet must be the ideal one");
        c.set("fleet", "mobile").unwrap();
        c.set("dropout", "0.1").unwrap();
        c.set("deadline_s", "30").unwrap();
        c.set("edge_of", "8").unwrap();
        assert_eq!(c.fleet.preset, FleetPreset::Mobile);
        assert_eq!(c.fleet.dropout, 0.1);
        assert_eq!(c.fleet.deadline_s, 30.0);
        assert_eq!(c.fleet.edge_of, 8);
        assert!(!c.fleet.is_ideal());
        c.validate().unwrap();
        assert!(c.set("edge_of", "-3").is_err(), "edge_of is a count");
        let err = c.set("fleet", "marsnet").unwrap_err().to_string();
        assert!(err.contains("marsnet"), "{err}");
        c.fleet.dropout = 1.0;
        assert!(c.validate().is_err());
        c.fleet.dropout = 0.1;
        c.fleet.deadline_s = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_overrides() {
        let dir = std::env::temp_dir().join("fedcompress_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"rounds": 4, "dataset": "voxforge", "beta": 0.5}"#).unwrap();
        let mut c = FedConfig::quick("cifar10");
        c.load_overrides(&p).unwrap();
        assert_eq!(c.rounds, 4);
        assert_eq!(c.dataset, "voxforge");
        assert_eq!(c.beta, 0.5);
    }
}
