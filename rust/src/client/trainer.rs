//! ClientUpdate (paper Algorithm 1, lines 11-19): E_c epochs of SGD on
//! L_ce + beta * L_wc, with the beta=0 warmup epochs the paper uses to
//! protect early representation learning, followed by the
//! representation-quality score on the unlabeled shard D_u.

use anyhow::Result;

use crate::clustering::{representation_score, CentroidState};
use crate::config::FedConfig;
use crate::data::Dataset;
use crate::runtime::literals::{literal_scalar_f32, literal_to_f32, Arg};
use crate::runtime::Engine;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ClientOutcome {
    pub theta: Vec<f32>,
    pub mu: Vec<f32>,
    /// representation quality score E_k on D_u
    pub score: f64,
    /// labeled sample count N_k (FedAvg weight)
    pub n: usize,
    pub mean_loss: f32,
    pub mean_ce: f32,
    pub steps: usize,
}

/// Run one client's local update. `use_wc` disables the clustering loss
/// entirely (FedAvg / FedZip clients train plain CE).
#[allow(clippy::too_many_arguments)]
pub fn train_local(
    engine: &Engine,
    cfg: &FedConfig,
    labeled: &Dataset,
    unlabeled: &Dataset,
    theta0: &[f32],
    centroids: &CentroidState,
    use_wc: bool,
    rng: &mut Rng,
) -> Result<ClientOutcome> {
    let ds = &cfg.dataset;
    let batch = engine.manifest.batch;
    let mut theta = theta0.to_vec();
    let mut mu = centroids.mu.clone();
    let mask = &centroids.mask;

    let mut loss_sum = 0.0f64;
    let mut ce_sum = 0.0f64;
    let mut steps = 0usize;

    for epoch in 0..cfg.local_epochs {
        let beta = if !use_wc || epoch < cfg.beta_warmup_epochs {
            0.0
        } else {
            cfg.beta
        };
        for (xs, ys) in labeled.epoch_batches(batch, rng) {
            let out = engine.run(
                ds,
                "train_step",
                &[
                    Arg::F32(&theta),
                    Arg::F32(&mu),
                    Arg::F32(mask),
                    Arg::F32(&xs),
                    Arg::I32(&ys),
                    Arg::Scalar(cfg.lr_client),
                    Arg::Scalar(beta),
                ],
            )?;
            theta = literal_to_f32(&out[0])?;
            mu = literal_to_f32(&out[1])?;
            loss_sum += literal_scalar_f32(&out[2])? as f64;
            ce_sum += literal_scalar_f32(&out[3])? as f64;
            steps += 1;
        }
    }

    let score = compute_score(engine, cfg, unlabeled, &theta)?;

    Ok(ClientOutcome {
        theta,
        mu,
        score,
        n: labeled.len(),
        mean_loss: (loss_sum / steps.max(1) as f64) as f32,
        mean_ce: (ce_sum / steps.max(1) as f64) as f32,
        steps,
    })
}

/// Representation score E on the unlabeled shard: embed through the
/// penultimate layer, then effective rank of the embedding spectrum.
pub fn compute_score(
    engine: &Engine,
    cfg: &FedConfig,
    unlabeled: &Dataset,
    theta: &[f32],
) -> Result<f64> {
    let ds = &cfg.dataset;
    let eval_batch = engine.manifest.eval_batch;
    let emb_dim = engine.manifest.dataset(ds)?.spec.emb_dim;

    let mut rows: Vec<f32> = Vec::new();
    let mut n_rows = 0usize;
    for (xs, _ys, valid) in unlabeled.eval_batches(eval_batch) {
        let out = engine.run(ds, "embed", &[Arg::F32(theta), Arg::F32(&xs)])?;
        let emb = literal_to_f32(&out[0])?;
        rows.extend_from_slice(&emb[..valid * emb_dim]);
        n_rows += valid;
    }
    Ok(representation_score(&rows, n_rows, emb_dim))
}

/// Evaluate a model on a dataset: (accuracy, mean CE loss).
pub fn evaluate(
    engine: &Engine,
    dataset: &str,
    data: &Dataset,
    theta: &[f32],
) -> Result<(f64, f64)> {
    let eval_batch = engine.manifest.eval_batch;
    let mut correct = 0.0f64;
    let mut loss = 0.0f64;
    let mut total = 0usize;
    for (xs, ys, valid) in data.eval_batches(eval_batch) {
        if valid == eval_batch {
            let out = engine.run(
                dataset,
                "eval_step",
                &[Arg::F32(theta), Arg::F32(&xs), Arg::I32(&ys)],
            )?;
            correct += literal_scalar_f32(&out[0])? as f64;
            loss += literal_scalar_f32(&out[1])? as f64;
        } else {
            // padded tail: count correctness per-sample from eval on the
            // padded batch minus the padding's contribution is not
            // separable, so recompute via embed-free path: run eval on a
            // batch where padding repeats sample 0 and subtract its known
            // contribution measured on a pure-padding batch.
            let out = engine.run(
                dataset,
                "eval_step",
                &[Arg::F32(theta), Arg::F32(&xs), Arg::I32(&ys)],
            )?;
            let c_all = literal_scalar_f32(&out[0])? as f64;
            let l_all = literal_scalar_f32(&out[1])? as f64;
            // padding batch: all slots = sample 0
            let pad_n = eval_batch - valid;
            let x0 = &xs[..data.feature_len()];
            let y0 = ys[0];
            let mut xs_pad = Vec::with_capacity(xs.len());
            for _ in 0..eval_batch {
                xs_pad.extend_from_slice(x0);
            }
            let ys_pad = vec![y0; eval_batch];
            let out_pad = engine.run(
                dataset,
                "eval_step",
                &[Arg::F32(theta), Arg::F32(&xs_pad), Arg::I32(&ys_pad)],
            )?;
            let c0 = literal_scalar_f32(&out_pad[0])? as f64 / eval_batch as f64;
            let l0 = literal_scalar_f32(&out_pad[1])? as f64 / eval_batch as f64;
            correct += c_all - c0 * pad_n as f64;
            loss += l_all - l0 * pad_n as f64;
        }
        total += valid;
    }
    Ok((correct / total as f64, loss / total as f64))
}
