//! Simulated federated client: local compression-aware training plus
//! the representation-quality score, all through the PJRT runtime.

pub mod trainer;

pub use trainer::{train_local, ClientOutcome};
