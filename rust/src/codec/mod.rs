//! First-class codec API: named, registered, composable compression
//! pipelines that cross the wire.
//!
//! The compression surface used to be a closed 4-variant enum
//! (`WireCodec`) with every strategy hand-rolling its encode path and
//! custom (`Opaque`) formats unable to cross the TCP transport. This
//! module replaces it with an open subsystem shaped like the strategy
//! plugin API:
//!
//! * [`Codec`] — the wire-facing contract: `encode(&CodecInput, &mut
//!   Rng) -> EncodedBlob` with exact `wire_bytes` accounting, and
//!   `decode(payload) -> Vec<f32>` reproducing the encoder's quantized
//!   model bit-for-bit.
//! * [`Stage`] — the composable unit. A stage transforms a
//!   [`StageData`] stream (`Floats` or `Indexed`) and defines its own
//!   terminal serialization, so `topk|kmeans|huffman` stacks prune ->
//!   cluster -> entropy-code exactly like FedZip hand-rolled it.
//! * [`Pipeline`] — the combinator: an ordered stage stack parsed from
//!   a spec string (`name(key=value,...)` joined by `|`), validating
//!   stage input/output kinds at build time and ledgering per-stage
//!   wire bytes individually.
//! * [`CodecRegistry`] — name -> stage constructor, with aliases,
//!   `--codec list`, and closest-name typo suggestions
//!   (`util::suggest`), mirroring `StrategyRegistry`.
//! * [`CodecCache`] — spec -> built pipeline, memoized. The networked
//!   transport decodes through a cache so stateful stages (`delta`)
//!   keep their cross-round stream state between messages.
//!
//! The canonical spec string is also the self-describing wire header:
//! `net::proto` ships `version | spec` ahead of every payload, so any
//! codec registered on both ends — including downstream user codecs —
//! round-trips through the TCP worker path. There is no in-process-only
//! carve-out anymore.

pub mod pipeline;
pub mod registry;
pub mod stages;

pub use pipeline::{DataKind, Pipeline, Stage, StageData};
pub use registry::{CodecCache, CodecInfo, CodecRegistry, StageCtor, StageParams};

use std::fmt;

use crate::clustering::CentroidState;
use crate::util::rng::Rng;

/// Stream identities for cross-round stateful stages (`delta`): one
/// monotone sequence of blobs per (direction, client). Upload streams
/// are the client index; the download broadcast and the finalize
/// encode get reserved ids far above any client count.
pub mod stream {
    /// Upload stream of client `k`.
    pub fn upload(client: usize) -> u64 {
        client as u64
    }
    /// The server -> client broadcast stream.
    pub const DOWNLOAD: u64 = 1 << 40;
    /// The final-deliverable encode (outside the round sequence).
    pub const FINAL: u64 = 1 << 41;
}

/// Everything an encoder may draw on beyond the raw weights. Kept
/// borrow-only so `encode` fans out over the upload worker pool
/// without cloning server state.
pub struct CodecInput<'a> {
    /// The dense model to encode.
    pub theta: &'a [f32],
    /// Centroid state for codebook-snapping stages (`codebook`); None
    /// when the caller has no clustering state.
    pub centroids: Option<&'a CentroidState>,
    /// Stream identity for cross-round stateful stages ([`stream`]).
    pub stream: u64,
}

impl<'a> CodecInput<'a> {
    /// Bare input: weights only, no centroid state, finalize stream.
    pub fn floats(theta: &'a [f32]) -> CodecInput<'a> {
        CodecInput {
            theta,
            centroids: None,
            stream: stream::FINAL,
        }
    }
}

/// One stage's exact contribution to the wire ledger: the serialized
/// size of the stream *after* that stage (what the transfer would cost
/// if the pipeline stopped there). The last stage's entry equals the
/// payload length, so the sequence reads as a compression trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageBytes {
    pub stage: String,
    pub bytes: usize,
}

/// What `Codec::encode` produces: the exact payload that crosses the
/// wire, the model the receiver reconstructs from it (`decode(payload)
/// == theta`, bit-for-bit), and the per-stage byte ledger.
#[derive(Clone, Debug, Default)]
pub struct EncodedBlob {
    pub payload: Vec<u8>,
    pub theta: Vec<f32>,
    pub stage_bytes: Vec<StageBytes>,
}

impl EncodedBlob {
    /// Exact wire size of the encoded model.
    pub fn wire_bytes(&self) -> usize {
        self.payload.len()
    }
}

/// Typed codec failure. Decoders never panic on corrupt input; spec
/// parsing reports unknown names with the registry's closest-name
/// suggestion, exactly like unknown strategies.
#[derive(Debug)]
pub enum CodecError {
    /// Spec references a name the registry does not know.
    UnknownStage {
        name: String,
        suggestion: Option<String>,
        known: String,
    },
    /// Structurally invalid spec string or stage parameter.
    BadSpec { what: String },
    /// A stage that needs data got an empty weight vector.
    EmptyInput { stage: &'static str },
    /// A codebook-snapping stage ran without centroid state.
    MissingCodebook { stage: &'static str },
    /// Payload ended mid-structure.
    Truncated { what: &'static str },
    /// Structurally invalid payload (bad magic, out-of-range index,
    /// desynchronized delta stream, ...).
    Malformed { what: String },
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::UnknownStage {
                name,
                suggestion,
                known,
            } => match suggestion {
                Some(s) => write!(
                    f,
                    "unknown codec '{name}' — did you mean '{s}'? (registered: {known})"
                ),
                None => write!(f, "unknown codec '{name}' (registered: {known})"),
            },
            CodecError::BadSpec { what } => write!(f, "bad codec spec: {what}"),
            CodecError::EmptyInput { stage } => {
                write!(f, "codec stage '{stage}' cannot encode an empty weight vector")
            }
            CodecError::MissingCodebook { stage } => write!(
                f,
                "codec stage '{stage}' needs centroid state, but the caller provided none"
            ),
            CodecError::Truncated { what } => write!(f, "truncated codec payload: {what}"),
            CodecError::Malformed { what } => write!(f, "malformed codec payload: {what}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// A wire codec: the contract every registered pipeline (and any
/// downstream `Codec` implementation) satisfies.
///
/// Invariants the property suite (`tests/codec_roundtrip.rs`) holds
/// every implementation to:
///
/// * `encode(...).payload.len() == wire_bytes` — the ledger never lies;
/// * `decode(&blob.payload) == blob.theta` bit-for-bit — sender and
///   receiver agree on the reconstructed model;
/// * `blob.theta.len() == input.theta.len()` — parameter count is
///   preserved through any stage stack.
pub trait Codec: Send + Sync {
    /// Canonical spec string — the self-describing wire header the
    /// receiving side resolves against its registry.
    fn spec(&self) -> String;

    /// Encode a model. `rng` is the caller's deterministic stream
    /// (clients pass their fork positioned where training left it), so
    /// equal inputs and RNG positions give bit-identical blobs.
    fn encode(&self, input: &CodecInput<'_>, rng: &mut Rng) -> Result<EncodedBlob, CodecError>;

    /// Decode a payload back to the exact quantized model the encoder
    /// reported as `theta`.
    fn decode(&self, payload: &[u8]) -> Result<Vec<f32>, CodecError>;
}
