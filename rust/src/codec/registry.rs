//! Codec registry + spec-string parser: the open end of the codec API,
//! mirroring `baselines::StrategyRegistry` (name -> constructor,
//! aliases, `--codec list`, closest-name typo suggestions via
//! `util::suggest`).
//!
//! Spec grammar (also the self-describing wire header):
//!
//! ```text
//! spec   := stage ('|' stage)*
//! stage  := name [ '(' key '=' value (',' key '=' value)* ')' ]
//! ```
//!
//! e.g. `topk(keep=0.6)|kmeans(c=15,iters=25)|huffman`. Parameters are
//! validated by each stage constructor (unknown keys are rejected) and
//! the resulting [`Pipeline`] re-renders the canonical spec with every
//! parameter explicit, so wire headers round-trip through `build`.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::pipeline::{Pipeline, Stage};
use super::stages::{CodebookStage, DeltaStage, DenseStage, HuffmanStage, KmeansStage, TopkStage};
use super::CodecError;
use crate::util::suggest;

/// Longest spec string `build` accepts (the wire header length-prefixes
/// specs with a u16, and anything near that is garbage anyway).
pub const MAX_SPEC_LEN: usize = 4096;

/// Parsed `key=value` parameters of one stage, with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct StageParams {
    stage: String,
    pairs: Vec<(String, String)>,
}

impl StageParams {
    fn bad(&self, what: String) -> CodecError {
        CodecError::BadSpec {
            what: format!("stage '{}': {what}", self.stage),
        }
    }

    fn raw(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Reject unknown parameter keys (typo guard, like `Args::restrict`).
    pub fn ensure_known(&self, allowed: &[&str]) -> Result<(), CodecError> {
        for (k, _) in &self.pairs {
            if !allowed.contains(&k.as_str()) {
                return Err(self.bad(format!(
                    "unknown parameter '{k}' (takes: {})",
                    if allowed.is_empty() {
                        "no parameters".to_string()
                    } else {
                        allowed.join(", ")
                    }
                )));
            }
        }
        Ok(())
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CodecError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| self.bad(format!("'{key}={v}' is not a number"))),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CodecError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| self.bad(format!("'{key}={v}' is not a count"))),
        }
    }
}

/// Constructor: a fresh stage instance from its parsed parameters.
pub type StageCtor = fn(&StageParams) -> Result<Box<dyn Stage>, CodecError>;

pub struct CodecInfo {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// one-line description shown by `--codec list`
    pub description: &'static str,
    pub ctor: StageCtor,
}

pub struct CodecRegistry {
    entries: Vec<CodecInfo>,
}

impl CodecRegistry {
    /// Empty registry (for embedding custom codec sets).
    pub fn empty() -> CodecRegistry {
        CodecRegistry {
            entries: Vec::new(),
        }
    }

    /// The built-in stages: the `compression/` substrate as registry
    /// parts.
    pub fn builtin() -> CodecRegistry {
        let mut r = CodecRegistry::empty();
        r.register(CodecInfo {
            name: "dense",
            aliases: &["raw", "f32"],
            description: "raw little-endian f32s, 4 bytes per parameter",
            ctor: |p| {
                p.ensure_known(&[])?;
                Ok(Box::new(DenseStage))
            },
        })
        .unwrap();
        r.register(CodecInfo {
            name: "topk",
            aliases: &["top-k", "sparsify"],
            description: "magnitude prune to `keep`; sparse (position, value) terminal form",
            ctor: |p| {
                p.ensure_known(&["keep"])?;
                let keep = p.f64_or("keep", 0.1)?;
                if !(keep > 0.0 && keep <= 1.0) {
                    return Err(CodecError::BadSpec {
                        what: format!("topk keep={keep} must be in (0, 1]"),
                    });
                }
                Ok(Box::new(TopkStage { keep }))
            },
        })
        .unwrap();
        r.register(CodecInfo {
            name: "kmeans",
            aliases: &["k-means"],
            description: "fit a fresh c-entry 1-D k-means codebook per blob and snap",
            ctor: |p| {
                p.ensure_known(&["c", "iters"])?;
                let c = p.usize_or("c", 16)?;
                let iters = p.usize_or("iters", 25)?;
                if c == 0 || c > u16::MAX as usize {
                    return Err(CodecError::BadSpec {
                        what: format!("kmeans c={c} must be in 1..=65535"),
                    });
                }
                if iters == 0 {
                    return Err(CodecError::BadSpec {
                        what: "kmeans iters=0 would never fit".to_string(),
                    });
                }
                Ok(Box::new(KmeansStage { c, iters }))
            },
        })
        .unwrap();
        r.register(CodecInfo {
            name: "codebook",
            aliases: &["cluster", "snap"],
            description: "snap to the caller's learned centroid table (FedCompress wire)",
            ctor: |p| {
                p.ensure_known(&[])?;
                Ok(Box::new(CodebookStage))
            },
        })
        .unwrap();
        r.register(CodecInfo {
            name: "huffman",
            aliases: &["entropy"],
            description: "entropy-code the index stream (canonical Huffman or flat, smaller wins)",
            ctor: |p| {
                p.ensure_known(&[])?;
                Ok(Box::new(HuffmanStage))
            },
        })
        .unwrap();
        r.register(CodecInfo {
            name: "delta",
            aliases: &["residual"],
            description: "cross-round residual coding: ship only changed indices per stream",
            ctor: |p| {
                p.ensure_known(&[])?;
                Ok(Box::<DeltaStage>::default())
            },
        })
        .unwrap();
        r
    }

    /// Add an entry; fails on a name/alias collision or a name `build`
    /// could never resolve (lookup is lowercase; `|(),=` are grammar).
    pub fn register(&mut self, info: CodecInfo) -> Result<(), CodecError> {
        let mut new_names = vec![info.name];
        new_names.extend_from_slice(info.aliases);
        for n in &new_names {
            let ok = !n.is_empty()
                && n.chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-' || c == '_');
            if !ok {
                return Err(CodecError::BadSpec {
                    what: format!("codec name '{n}' must be non-empty [a-z0-9_-]"),
                });
            }
        }
        for e in &self.entries {
            let mut taken = vec![e.name];
            taken.extend_from_slice(e.aliases);
            if let Some(dup) = new_names.iter().find(|n| taken.contains(n)) {
                return Err(CodecError::BadSpec {
                    what: format!("codec name '{dup}' already registered"),
                });
            }
        }
        self.entries.push(info);
        Ok(())
    }

    pub fn entries(&self) -> &[CodecInfo] {
        &self.entries
    }

    /// Canonical names, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    fn lookup(&self, name: &str) -> Option<&CodecInfo> {
        self.entries
            .iter()
            .find(|e| e.name == name || e.aliases.contains(&name))
    }

    /// Closest registered name/alias, if plausibly a typo.
    pub fn suggest(&self, name: &str) -> Option<&'static str> {
        suggest::closest(
            name,
            self.entries
                .iter()
                .flat_map(|e| std::iter::once(e.name).chain(e.aliases.iter().copied())),
        )
    }

    /// Parse a pipeline spec (`name(k=v,...)` joined by `|`) into a
    /// validated [`Pipeline`]. Unknown names fail with the closest
    /// registered name suggested; stage constructors validate params.
    pub fn build(&self, spec: &str) -> Result<Pipeline, CodecError> {
        if spec.len() > MAX_SPEC_LEN {
            return Err(CodecError::BadSpec {
                what: format!("spec of {} chars exceeds the {MAX_SPEC_LEN} cap", spec.len()),
            });
        }
        let mut stages: Vec<Box<dyn Stage>> = Vec::new();
        for part in spec.split('|') {
            let params = parse_stage(part)?;
            let want = params.stage.to_ascii_lowercase();
            let Some(info) = self.lookup(&want) else {
                return Err(CodecError::UnknownStage {
                    name: params.stage.clone(),
                    suggestion: self.suggest(&want).map(String::from),
                    known: self.names().join(", "),
                });
            };
            stages.push((info.ctor)(&params)?);
        }
        Pipeline::new(stages)
    }

    /// Render the `--codec list` table.
    pub fn render_list(&self) -> String {
        let mut s = String::from(
            "registered codec stages (compose with '|', e.g. topk|kmeans|huffman):\n",
        );
        for e in &self.entries {
            let alias = if e.aliases.is_empty() {
                String::new()
            } else {
                format!(" (alias: {})", e.aliases.join(", "))
            };
            s.push_str(&format!("  {:<10} {}{}\n", e.name, e.description, alias));
        }
        s
    }
}

/// Parse one `name` / `name(key=value,...)` stage fragment.
fn parse_stage(part: &str) -> Result<StageParams, CodecError> {
    let part = part.trim();
    let bad = |what: String| CodecError::BadSpec { what };
    if part.is_empty() {
        return Err(bad("empty stage name (doubled '|'?)".to_string()));
    }
    let (name, args) = match part.split_once('(') {
        None => {
            if part.contains(')') {
                return Err(bad(format!("stray ')' in '{part}'")));
            }
            (part, None)
        }
        Some((name, rest)) => {
            let Some(args) = rest.strip_suffix(')') else {
                return Err(bad(format!("unclosed '(' in '{part}'")));
            };
            if args.contains('(') || args.contains(')') {
                return Err(bad(format!("nested parentheses in '{part}'")));
            }
            (name.trim(), Some(args))
        }
    };
    if name.is_empty() {
        return Err(bad(format!("missing stage name in '{part}'")));
    }
    let mut params = StageParams {
        stage: name.to_string(),
        pairs: Vec::new(),
    };
    if let Some(args) = args {
        for pair in args.split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let Some((k, v)) = pair.split_once('=') else {
                return Err(bad(format!("'{pair}' in '{part}' is not key=value")));
            };
            let (k, v) = (k.trim().to_string(), v.trim().to_string());
            if params.pairs.iter().any(|(pk, _)| *pk == k) {
                return Err(bad(format!("duplicate parameter '{k}' in '{part}'")));
            }
            params.pairs.push((k, v));
        }
    }
    Ok(params)
}

/// Spec -> built pipeline, memoized. Decode paths hold one cache per
/// peer so stateful stages (`delta`) keep their cross-round stream
/// state between messages; encode paths may use it for convenience.
pub struct CodecCache {
    registry: CodecRegistry,
    built: Mutex<BTreeMap<String, Arc<Pipeline>>>,
}

impl CodecCache {
    pub fn new(registry: CodecRegistry) -> CodecCache {
        CodecCache {
            registry,
            built: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn builtin() -> CodecCache {
        CodecCache::new(CodecRegistry::builtin())
    }

    pub fn registry(&self) -> &CodecRegistry {
        &self.registry
    }

    /// The pipeline for `spec`, building and memoizing on first use.
    pub fn get(&self, spec: &str) -> Result<Arc<Pipeline>, CodecError> {
        let mut built = self.built.lock().expect("codec cache");
        if let Some(p) = built.get(spec) {
            return Ok(p.clone());
        }
        let pipeline = Arc::new(self.registry.build(spec)?);
        built.insert(spec.to_string(), pipeline.clone());
        Ok(pipeline)
    }

    /// Decode a received payload under its wire spec.
    pub fn decode(&self, spec: &str, payload: &[u8]) -> Result<Vec<f32>, CodecError> {
        use super::Codec;
        self.get(spec)?.decode(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{Codec, CodecInput};
    use crate::util::rng::Rng;

    #[test]
    fn every_builtin_name_builds_standalone_or_chained() {
        let reg = CodecRegistry::builtin();
        assert!(reg.names().len() >= 6, "{:?}", reg.names());
        for name in reg.names() {
            // stages that consume an index stream need a clustering
            // stage in front; everything else stands alone
            let spec = match name {
                "huffman" | "delta" => format!("kmeans(c=4)|{name}"),
                other => other.to_string(),
            };
            let p = reg.build(&spec).unwrap();
            assert!(p.spec().contains(name), "{name}: {}", p.spec());
        }
    }

    #[test]
    fn aliases_and_case_resolve_to_canonical_specs() {
        let reg = CodecRegistry::builtin();
        assert_eq!(reg.build("raw").unwrap().spec(), "dense");
        assert_eq!(
            reg.build("sparsify(keep=0.5)").unwrap().spec(),
            "topk(keep=0.5)"
        );
        assert_eq!(
            reg.build("Top-K|K-Means(c=8)|Entropy").unwrap().spec(),
            "topk(keep=0.1)|kmeans(c=8,iters=25)|huffman"
        );
    }

    #[test]
    fn canonical_specs_reparse_to_themselves() {
        let reg = CodecRegistry::builtin();
        for spec in [
            "dense",
            "topk(keep=0.6)|kmeans(c=15,iters=25)|huffman",
            "codebook|huffman",
            "codebook|delta",
        ] {
            let p = reg.build(spec).unwrap();
            assert_eq!(p.spec(), spec);
            assert_eq!(reg.build(&p.spec()).unwrap().spec(), spec);
        }
    }

    #[test]
    fn unknown_names_suggest_like_the_strategy_registry() {
        let reg = CodecRegistry::builtin();
        let err = reg.build("topk|hufman").unwrap_err().to_string();
        assert!(err.contains("did you mean 'huffman'"), "{err}");
        let err = reg.build("zstd").unwrap_err().to_string();
        assert!(err.contains("unknown codec 'zstd'"), "{err}");
        assert!(err.contains("registered:"), "{err}");
    }

    #[test]
    fn bad_specs_fail_with_the_offending_fragment() {
        let reg = CodecRegistry::builtin();
        for (spec, needle) in [
            ("", "empty stage"),
            ("topk||huffman", "empty stage"),
            ("topk(keep=0.5", "unclosed"),
            ("topk(keep)", "not key=value"),
            ("topk(keep=0.5,keep=0.6)", "duplicate"),
            ("topk(scale=2)", "unknown parameter"),
            ("topk(keep=zero)", "not a number"),
            ("topk(keep=0)", "(0, 1]"),
            ("kmeans(c=0)", "1..=65535"),
            ("huffman", "cannot open a pipeline"),
            ("huffman|kmeans", "cannot open a pipeline"),
            ("kmeans|huffman|dense", "must be the last stage"),
            ("kmeans|kmeans", "consumes"),
        ] {
            let err = reg.build(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "spec '{spec}': {err}");
        }
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut reg = CodecRegistry::builtin();
        let dup = CodecInfo {
            name: "dense",
            aliases: &[],
            description: "dup",
            ctor: |_| Ok(Box::new(crate::codec::stages::DenseStage)),
        };
        assert!(reg.register(dup).is_err());
        let bad = CodecInfo {
            name: "Bad|Name",
            aliases: &[],
            description: "grammar chars",
            ctor: |_| Ok(Box::new(crate::codec::stages::DenseStage)),
        };
        assert!(reg.register(bad).is_err());
    }

    #[test]
    fn list_mentions_every_name() {
        let reg = CodecRegistry::builtin();
        let list = reg.render_list();
        for name in reg.names() {
            assert!(list.contains(name), "{name} missing from list");
        }
    }

    #[test]
    fn cache_memoizes_and_decodes() {
        let cache = CodecCache::builtin();
        let a = cache.get("dense").unwrap();
        let b = cache.get("dense").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same spec must share the pipeline");
        let theta = [1.0f32, -2.5, 0.25];
        let blob = a
            .encode(&CodecInput::floats(&theta), &mut Rng::new(1))
            .unwrap();
        assert_eq!(cache.decode("dense", &blob.payload).unwrap(), theta);
        assert!(cache.decode("nonsense", &[]).is_err());
    }
}
