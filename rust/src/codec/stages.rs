//! The built-in codec stages — the `compression/` substrate
//! (`sparsify`, `kmeans`, `huffman`, `delta`) surfaced as registered,
//! composable [`Stage`]s:
//!
//! * `dense`    — raw little-endian f32s (FedAvg's wire, 4 B/param).
//! * `topk`     — magnitude prune; terminal form is the sparse
//!                (position, value) format the `topk` strategy ships.
//! * `kmeans`   — fit a fresh per-blob codebook and snap; terminal
//!                form is the flat-packed clustered container.
//! * `codebook` — snap to the caller's centroid table (FedCompress's
//!                transport; needs `CodecInput::centroids`).
//! * `huffman`  — entropy-code an index stream (picks canonical
//!                Huffman or flat packing, whichever is smaller).
//! * `delta`    — cross-round residual coding of index streams: ship
//!                only changed positions against the previous blob on
//!                the same stream, falling back to flat when the delta
//!                would not pay.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::pipeline::{DataKind, Stage, StageData};
use super::{CodecError, CodecInput};
use crate::compression::codec::{
    decode as clustered_decode, dense_bytes, encode as clustered_encode, encode_flat,
    flat_wire_bytes, index_bits,
};
use crate::compression::delta::{delta_decode, delta_encode};
use crate::compression::kmeans::{kmeans_1d, snap};
use crate::compression::sparsify::magnitude_prune;
use crate::util::cursor::ByteCursor;
use crate::util::rng::Rng;

/// Refuse wire-claimed element counts above this. A corrupt or hostile
/// length field must not become a multi-gigabyte allocation before the
/// payload-length checks run (64M f32 params = 256 MiB dense, matching
/// `net::frame::MAX_PAYLOAD`).
pub const MAX_PARAMS: usize = 64 << 20;

fn malformed(what: impl Into<String>) -> CodecError {
    CodecError::Malformed { what: what.into() }
}

/// Internal-invariant guard: a stage fed the wrong [`StageData`] kind
/// (impossible through a validated [`super::Pipeline`], reachable only
/// by calling stages by hand).
fn wrong_kind(stage: &'static str, want: DataKind, got: &StageData) -> CodecError {
    malformed(format!(
        "stage '{stage}' expects {}, got {}",
        want.name(),
        got.kind().name()
    ))
}

// --- dense ------------------------------------------------------------------

/// Raw little-endian f32 transport: lossless, 4 bytes per parameter.
pub struct DenseStage;

/// Serialize a weight vector as raw little-endian f32s.
pub fn dense_encode(theta: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * theta.len());
    for w in theta {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

/// Inverse of [`dense_encode`].
pub fn dense_decode(payload: &[u8]) -> Result<Vec<f32>, CodecError> {
    if payload.len() % 4 != 0 {
        return Err(malformed(format!(
            "dense payload of {} bytes is not a whole number of f32s",
            payload.len()
        )));
    }
    let mut cur = ByteCursor::new(payload);
    let mut out = Vec::with_capacity(payload.len() / 4);
    while let Some(w) = cur.f32() {
        out.push(w);
    }
    Ok(out)
}

impl Stage for DenseStage {
    fn name(&self) -> &'static str {
        "dense"
    }
    fn spec(&self) -> String {
        "dense".to_string()
    }
    fn input_kind(&self) -> DataKind {
        DataKind::Floats
    }
    fn output_kind(&self) -> DataKind {
        DataKind::Floats
    }

    fn encode(
        &self,
        data: StageData,
        _input: &CodecInput<'_>,
        _rng: &mut Rng,
    ) -> Result<StageData, CodecError> {
        Ok(data)
    }

    fn wire_len(&self, data: &StageData) -> usize {
        dense_bytes(data.param_count())
    }

    fn serialize(&self, data: &StageData, _input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError> {
        match data {
            StageData::Floats(v) => Ok(dense_encode(v)),
            other => Err(wrong_kind("dense", DataKind::Floats, other)),
        }
    }

    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError> {
        Ok(StageData::Floats(dense_decode(payload)?))
    }

    fn backward(&self, data: StageData) -> Result<StageData, CodecError> {
        Ok(data)
    }
}

// --- topk (magnitude sparsification) ----------------------------------------

const SPARSE_MAGIC: u32 = 0x4643_5331; // "FCS1"

/// Exact wire size of the sparse format for `n` params, `k` survivors.
pub fn sparse_wire_bytes(n: usize, k: usize) -> usize {
    let bits = index_bits(n.max(2)) as usize;
    13 + (k * bits).div_ceil(8) + 4 * k
}

/// Sparse-encode an (already pruned) weight vector as (position,
/// value) pairs: positions bit-packed at ceil(log2 n) bits, values as
/// raw f32. Layout (little-endian):
/// `u32 magic 'FCS1' | u32 n | u32 k | u8 bits | positions | values`.
pub fn sparse_encode(pruned: &[f32]) -> Vec<u8> {
    let survivors: Vec<(usize, f32)> = pruned
        .iter()
        .enumerate()
        .filter(|(_, w)| **w != 0.0)
        .map(|(i, w)| (i, *w))
        .collect();
    let n = pruned.len();
    let bits = index_bits(n.max(2));
    let mut out = Vec::with_capacity(sparse_wire_bytes(n, survivors.len()));
    out.extend_from_slice(&SPARSE_MAGIC.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(survivors.len() as u32).to_le_bytes());
    out.push(bits as u8);
    let positions: Vec<u32> = survivors.iter().map(|&(pos, _)| pos as u32).collect();
    out.extend_from_slice(&crate::kernels::pack_bits(&positions, bits));
    for (_, v) in &survivors {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a sparse blob back to the dense (pruned) weight vector.
pub fn sparse_decode(bytes: &[u8]) -> Result<Vec<f32>, CodecError> {
    let short = |what: &'static str| CodecError::Truncated { what };
    let mut cur = ByteCursor::new(bytes);
    if cur.u32().ok_or(short("sparse blob"))? != SPARSE_MAGIC {
        return Err(malformed("bad sparse magic"));
    }
    let n = cur.u32().ok_or(short("sparse blob"))? as usize;
    let k = cur.u32().ok_or(short("sparse blob"))? as usize;
    let bits = cur.u8().ok_or(short("sparse blob"))? as u32;
    if n > MAX_PARAMS {
        return Err(malformed(format!(
            "sparse blob claims {n} params (cap {MAX_PARAMS})"
        )));
    }
    if k > n {
        return Err(malformed(format!(
            "sparse blob claims {k} survivors of {n} params"
        )));
    }
    if bits != index_bits(n.max(2)) {
        return Err(malformed(format!(
            "sparse blob bit width {bits} does not match {n} params"
        )));
    }
    let pos_bytes = (k * bits as usize).div_ceil(8);
    let packed = cur.take(pos_bytes).ok_or(short("sparse blob"))?;
    let positions = crate::kernels::unpack_bits(packed, bits, k).ok_or(CodecError::Truncated {
        what: "sparse position stream",
    })?;
    for &p in &positions {
        if p as usize >= n {
            return Err(malformed(format!("position {p} out of range {n}")));
        }
    }
    let mut theta = vec![0.0f32; n];
    for &pos in &positions {
        let v = cur.f32().ok_or(short("sparse blob"))?;
        if let Some(slot) = theta.get_mut(pos as usize) {
            *slot = v;
        }
    }
    if !cur.done() {
        return Err(malformed("trailing garbage after sparse values"));
    }
    Ok(theta)
}

/// Magnitude pruning: keep the top `keep` fraction of weights by
/// |magnitude|, zero the rest. Terminal form is the sparse format.
pub struct TopkStage {
    pub keep: f64,
}

impl Stage for TopkStage {
    fn name(&self) -> &'static str {
        "topk"
    }
    fn spec(&self) -> String {
        format!("topk(keep={})", self.keep)
    }
    fn input_kind(&self) -> DataKind {
        DataKind::Floats
    }
    fn output_kind(&self) -> DataKind {
        DataKind::Floats
    }

    fn encode(
        &self,
        data: StageData,
        _input: &CodecInput<'_>,
        _rng: &mut Rng,
    ) -> Result<StageData, CodecError> {
        match data {
            StageData::Floats(mut v) => {
                magnitude_prune(&mut v, self.keep);
                Ok(StageData::Floats(v))
            }
            other => Err(wrong_kind("topk", DataKind::Floats, &other)),
        }
    }

    fn wire_len(&self, data: &StageData) -> usize {
        match data {
            StageData::Floats(v) => {
                let k = v.iter().filter(|w| **w != 0.0).count();
                sparse_wire_bytes(v.len(), k)
            }
            StageData::Indexed { indices, .. } => sparse_wire_bytes(indices.len(), indices.len()),
        }
    }

    fn serialize(&self, data: &StageData, _input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError> {
        match data {
            StageData::Floats(v) => Ok(sparse_encode(v)),
            other => Err(wrong_kind("topk", DataKind::Floats, other)),
        }
    }

    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError> {
        Ok(StageData::Floats(sparse_decode(payload)?))
    }

    fn backward(&self, data: StageData) -> Result<StageData, CodecError> {
        // pruning is not invertible: the pruned vector IS the decode
        Ok(data)
    }
}

// --- kmeans (per-blob codebook fit) -----------------------------------------

/// Fit a fresh `c`-entry 1-D k-means codebook on the incoming floats
/// (consuming the caller's RNG stream exactly like the hand-rolled
/// FedZip path did) and snap. Terminal form is the flat-packed
/// clustered container.
pub struct KmeansStage {
    pub c: usize,
    pub iters: usize,
}

impl Stage for KmeansStage {
    fn name(&self) -> &'static str {
        "kmeans"
    }
    fn spec(&self) -> String {
        format!("kmeans(c={},iters={})", self.c, self.iters)
    }
    fn input_kind(&self) -> DataKind {
        DataKind::Floats
    }
    fn output_kind(&self) -> DataKind {
        DataKind::Indexed
    }

    fn encode(
        &self,
        data: StageData,
        _input: &CodecInput<'_>,
        rng: &mut Rng,
    ) -> Result<StageData, CodecError> {
        match data {
            StageData::Floats(mut v) => {
                if v.is_empty() {
                    return Err(CodecError::EmptyInput { stage: "kmeans" });
                }
                let (codebook, _, _) = kmeans_1d(&v, self.c, self.iters, rng);
                let indices = snap(&mut v, &codebook);
                Ok(StageData::Indexed { codebook, indices })
            }
            other => Err(wrong_kind("kmeans", DataKind::Floats, &other)),
        }
    }

    fn wire_len(&self, data: &StageData) -> usize {
        match data {
            StageData::Indexed { codebook, indices } => {
                flat_wire_bytes(codebook.len(), indices.len())
            }
            StageData::Floats(v) => flat_wire_bytes(self.c, v.len()),
        }
    }

    fn serialize(&self, data: &StageData, _input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError> {
        serialize_indexed_flat("kmeans", data)
    }

    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError> {
        deserialize_clustered(payload)
    }

    fn backward(&self, data: StageData) -> Result<StageData, CodecError> {
        Ok(StageData::Floats(data.to_floats()))
    }
}

// --- codebook (snap to the caller's centroid table) -------------------------

/// Snap to the *caller-provided* sorted codebook
/// (`CodecInput::centroids`): FedCompress's transport, lossless once
/// the model is centroid-structured. Terminal form is flat-packed.
pub struct CodebookStage;

impl Stage for CodebookStage {
    fn name(&self) -> &'static str {
        "codebook"
    }
    fn spec(&self) -> String {
        "codebook".to_string()
    }
    fn input_kind(&self) -> DataKind {
        DataKind::Floats
    }
    fn output_kind(&self) -> DataKind {
        DataKind::Indexed
    }

    fn encode(
        &self,
        data: StageData,
        input: &CodecInput<'_>,
        _rng: &mut Rng,
    ) -> Result<StageData, CodecError> {
        let Some(centroids) = input.centroids else {
            return Err(CodecError::MissingCodebook { stage: "codebook" });
        };
        let codebook = centroids.active_codebook();
        if codebook.is_empty() {
            return Err(CodecError::MissingCodebook { stage: "codebook" });
        }
        match data {
            StageData::Floats(mut v) => {
                let indices = snap(&mut v, &codebook);
                Ok(StageData::Indexed { codebook, indices })
            }
            other => Err(wrong_kind("codebook", DataKind::Floats, &other)),
        }
    }

    fn wire_len(&self, data: &StageData) -> usize {
        match data {
            StageData::Indexed { codebook, indices } => {
                flat_wire_bytes(codebook.len(), indices.len())
            }
            StageData::Floats(v) => flat_wire_bytes(1, v.len()),
        }
    }

    fn serialize(&self, data: &StageData, _input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError> {
        serialize_indexed_flat("codebook", data)
    }

    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError> {
        deserialize_clustered(payload)
    }

    fn backward(&self, data: StageData) -> Result<StageData, CodecError> {
        Ok(StageData::Floats(data.to_floats()))
    }
}

// --- huffman (entropy stage) ------------------------------------------------

/// Entropy-code an index stream inside the clustered container,
/// picking canonical Huffman or flat packing per blob — exactly the
/// adaptive choice the hand-rolled FedZip/FedCompress encoders made.
/// Terminal-only: its compression lives in serialization.
pub struct HuffmanStage;

impl Stage for HuffmanStage {
    fn name(&self) -> &'static str {
        "huffman"
    }
    fn spec(&self) -> String {
        "huffman".to_string()
    }
    fn input_kind(&self) -> DataKind {
        DataKind::Indexed
    }
    fn output_kind(&self) -> DataKind {
        DataKind::Indexed
    }
    fn terminal_only(&self) -> bool {
        true
    }

    fn encode(
        &self,
        data: StageData,
        _input: &CodecInput<'_>,
        _rng: &mut Rng,
    ) -> Result<StageData, CodecError> {
        match data {
            d @ StageData::Indexed { .. } => Ok(d),
            other => Err(wrong_kind("huffman", DataKind::Indexed, &other)),
        }
    }

    fn serialize(&self, data: &StageData, _input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError> {
        match data {
            StageData::Indexed { codebook, indices } => {
                if codebook.is_empty() {
                    return Err(CodecError::EmptyInput { stage: "huffman" });
                }
                Ok(clustered_encode(codebook, indices).bytes)
            }
            other => Err(wrong_kind("huffman", DataKind::Indexed, other)),
        }
    }

    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError> {
        deserialize_clustered(payload)
    }

    fn backward(&self, data: StageData) -> Result<StageData, CodecError> {
        Ok(data)
    }
}

// --- delta (cross-round residual coding) ------------------------------------

/// Previous index stream per stream id, kept separately for the encode
/// and decode directions so one instance can serve both sides of a
/// loopback without corrupting itself. `BTreeMap` so any iteration
/// over the state (diagnostics, future serialization) is
/// insertion-order-independent — fedlint's `det-map-iter` rule bans
/// `HashMap` in codec modules outright.
type DeltaState = Mutex<BTreeMap<u64, (usize, Vec<u32>)>>;

/// Cross-round residual coding of index streams
/// (`compression::delta`): when consecutive blobs on one stream share
/// most assignments, ship only the changed (position, index) pairs.
/// Self-describing fallback: blobs that would not beat flat packing
/// ship flat, so the first blob of a stream and codebook-size changes
/// cost nothing extra. Terminal-only and stateful per stream id —
/// resumed runs start a fresh stream (their first blob ships flat).
///
/// Layout: `u64 stream | u16 c | f32 codebook[c] | u32 n | u8 mode |
/// body` where mode 0 = flat-packed indices and mode 1 = a
/// `delta_encode` blob against the stream's previous indices.
#[derive(Default)]
pub struct DeltaStage {
    enc: DeltaState,
    dec: DeltaState,
}

const DELTA_MODE_FLAT: u8 = 0;
const DELTA_MODE_DELTA: u8 = 1;

impl Stage for DeltaStage {
    fn name(&self) -> &'static str {
        "delta"
    }
    fn spec(&self) -> String {
        "delta".to_string()
    }
    fn input_kind(&self) -> DataKind {
        DataKind::Indexed
    }
    fn output_kind(&self) -> DataKind {
        DataKind::Indexed
    }
    fn terminal_only(&self) -> bool {
        true
    }

    fn encode(
        &self,
        data: StageData,
        _input: &CodecInput<'_>,
        _rng: &mut Rng,
    ) -> Result<StageData, CodecError> {
        match data {
            d @ StageData::Indexed { .. } => Ok(d),
            other => Err(wrong_kind("delta", DataKind::Indexed, &other)),
        }
    }

    fn serialize(&self, data: &StageData, input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError> {
        let StageData::Indexed { codebook, indices } = data else {
            return Err(wrong_kind("delta", DataKind::Indexed, data));
        };
        let c = codebook.len();
        if c == 0 || c > u16::MAX as usize {
            return Err(malformed(format!("delta codebook size {c} out of range")));
        }
        let mut out = Vec::new();
        out.extend_from_slice(&input.stream.to_le_bytes());
        out.extend_from_slice(&(c as u16).to_le_bytes());
        for &v in codebook {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(indices.len() as u32).to_le_bytes());

        // fedlint:allow(no-panic-decode) -- lock poisoning means a prior panic in this process, not adversarial bytes
        let mut state = self.enc.lock().expect("delta encode state");
        let prev = state.get(&input.stream);
        let body = match prev {
            Some((pc, pi)) if *pc == c && pi.len() == indices.len() => {
                delta_encode(pi, indices, c)
            }
            _ => None,
        };
        match body {
            Some(blob) => {
                out.push(DELTA_MODE_DELTA);
                out.extend_from_slice(&blob);
            }
            None => {
                out.push(DELTA_MODE_FLAT);
                let bits = index_bits(c);
                out.extend_from_slice(&crate::kernels::pack_bits(indices, bits));
            }
        }
        state.insert(input.stream, (c, indices.clone()));
        Ok(out)
    }

    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError> {
        let short = || CodecError::Truncated { what: "delta blob" };
        let mut cur = ByteCursor::new(payload);
        let stream = cur.u64().ok_or_else(short)?;
        let c = cur.u16().ok_or_else(short)? as usize;
        if c == 0 {
            return Err(malformed("delta blob with empty codebook"));
        }
        let mut codebook = Vec::with_capacity(c);
        for _ in 0..c {
            codebook.push(cur.f32().ok_or_else(short)?);
        }
        let n = cur.u32().ok_or_else(short)? as usize;
        if n > MAX_PARAMS {
            return Err(malformed(format!(
                "delta blob claims {n} indices (cap {MAX_PARAMS})"
            )));
        }
        let mode = cur.u8().ok_or_else(short)?;
        let body = cur.rest();

        // fedlint:allow(no-panic-decode) -- lock poisoning means a prior panic in this process, not adversarial bytes
        let mut state = self.dec.lock().expect("delta decode state");
        let indices = match mode {
            DELTA_MODE_FLAT => {
                let bits = index_bits(c);
                let v =
                    crate::kernels::unpack_bits(body, bits, n).ok_or(CodecError::Truncated {
                        what: "delta flat index stream",
                    })?;
                for &x in &v {
                    if x as usize >= c {
                        return Err(malformed(format!("index {x} out of codebook range {c}")));
                    }
                }
                v
            }
            DELTA_MODE_DELTA => {
                let Some((pc, prev)) = state.get(&stream) else {
                    return Err(malformed(format!(
                        "delta blob on unknown stream {stream} (receiver has no baseline)"
                    )));
                };
                if *pc != c || prev.len() != n {
                    return Err(malformed(format!(
                        "delta stream {stream} desynchronized: baseline is {}x{}, blob \
                         claims {n}x{c}",
                        prev.len(),
                        pc
                    )));
                }
                delta_decode(prev, body, c).map_err(|e| malformed(format!("delta body: {e}")))?
            }
            other => return Err(malformed(format!("unknown delta mode {other}"))),
        };
        state.insert(stream, (c, indices.clone()));
        Ok(StageData::Indexed { codebook, indices })
    }

    fn backward(&self, data: StageData) -> Result<StageData, CodecError> {
        Ok(data)
    }
}

// --- shared helpers ---------------------------------------------------------

/// Flat-packed clustered container for an `Indexed` stream (the
/// terminal form of `kmeans`/`codebook`).
fn serialize_indexed_flat(stage: &'static str, data: &StageData) -> Result<Vec<u8>, CodecError> {
    match data {
        StageData::Indexed { codebook, indices } => {
            if codebook.is_empty() {
                return Err(CodecError::EmptyInput { stage });
            }
            Ok(encode_flat(codebook, indices).bytes)
        }
        other => Err(wrong_kind(stage, DataKind::Indexed, other)),
    }
}

/// Decode a clustered container (flat or Huffman payload) back to an
/// `Indexed` stream.
fn deserialize_clustered(payload: &[u8]) -> Result<StageData, CodecError> {
    let (_, indices, codebook) =
        clustered_decode(payload).map_err(|e| malformed(format!("clustered payload: {e}")))?;
    Ok(StageData::Indexed { codebook, indices })
}
