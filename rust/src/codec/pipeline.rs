//! The [`Pipeline`] combinator: an ordered stack of [`Stage`]s behind
//! the [`Codec`] contract.
//!
//! Encode walks the stage list forward, transforming a [`StageData`]
//! stream; the *last* stage serializes its output as the wire payload.
//! Decode deserializes with the last stage and walks the rest backward
//! (each stage's lossy inverse), reproducing exactly the quantized
//! model the encoder reported. Per-stage wire sizes are ledgered
//! individually: entry `i` is the exact serialized size the transfer
//! would have cost had the pipeline stopped after stage `i`, so the
//! sequence reads as a compression trace (`topk|kmeans|huffman` shows
//! sparse -> flat-packed -> entropy-coded bytes).
//!
//! Stage compatibility is validated at build time: the first stage
//! must consume `Floats`, adjacent kinds must match, and terminal-only
//! stages (whose compression lives in serialization: `huffman`,
//! `delta`) must come last.

use super::{Codec, CodecError, CodecInput, EncodedBlob, StageBytes};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// The intermediate stream stages transform.
#[derive(Clone, Debug, PartialEq)]
pub enum StageData {
    /// A dense weight vector (possibly pruned: zeros are meaningful).
    Floats(Vec<f32>),
    /// A clustered stream: sorted codebook + one index per parameter.
    Indexed {
        codebook: Vec<f32>,
        indices: Vec<u32>,
    },
}

/// The kind tag used for build-time chain validation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataKind {
    Floats,
    Indexed,
}

impl DataKind {
    pub fn name(self) -> &'static str {
        match self {
            DataKind::Floats => "floats",
            DataKind::Indexed => "an index stream",
        }
    }
}

impl StageData {
    pub fn kind(&self) -> DataKind {
        match self {
            StageData::Floats(_) => DataKind::Floats,
            StageData::Indexed { .. } => DataKind::Indexed,
        }
    }

    /// Parameter count of the stream.
    pub fn param_count(&self) -> usize {
        match self {
            StageData::Floats(v) => v.len(),
            StageData::Indexed { indices, .. } => indices.len(),
        }
    }

    /// The decoded view: what a receiver reconstructs from this stream.
    /// Every decode path validates indices against the codebook before
    /// building an `Indexed` stream, so the out-of-range arm is
    /// unreachable from wire bytes; it maps to 0.0 rather than
    /// panicking so a hand-built stream cannot take the process down.
    pub fn to_floats(&self) -> Vec<f32> {
        match self {
            StageData::Floats(v) => v.clone(),
            StageData::Indexed { codebook, indices } => indices
                .iter()
                .map(|&i| codebook.get(i as usize).copied().unwrap_or(0.0))
                .collect(),
        }
    }
}

/// One composable codec stage. Implementations are `&self` + `Send +
/// Sync` so encodes fan out over the upload worker pool; stages with
/// cross-round state (`delta`) guard it behind a mutex keyed by the
/// input's stream id.
pub trait Stage: Send + Sync {
    /// Registry name (ledger label).
    fn name(&self) -> &'static str;

    /// Canonical spec fragment including explicit parameters, e.g.
    /// `topk(keep=0.6)`. Must re-parse to an equivalent stage.
    fn spec(&self) -> String;

    fn input_kind(&self) -> DataKind;
    fn output_kind(&self) -> DataKind;

    /// Terminal-only stages compress in `serialize` and are identity
    /// transforms on the stream; the pipeline rejects them anywhere but
    /// last.
    fn terminal_only(&self) -> bool {
        false
    }

    /// Forward transform (prune, cluster, snap, ...). Consumes the
    /// stream so in-place transforms need no copies.
    fn encode(
        &self,
        data: StageData,
        input: &CodecInput<'_>,
        rng: &mut Rng,
    ) -> Result<StageData, CodecError>;

    /// Exact serialized size of `data` under this stage's terminal
    /// format — the per-stage ledger entry for intermediate stages.
    /// Terminal-only stages may keep the default (the pipeline uses
    /// the real payload length for the last stage).
    fn wire_len(&self, _data: &StageData) -> usize {
        0
    }

    /// Terminal serialization of this stage's output.
    fn serialize(&self, data: &StageData, input: &CodecInput<'_>) -> Result<Vec<u8>, CodecError>;

    /// Inverse of [`Stage::serialize`].
    fn deserialize(&self, payload: &[u8]) -> Result<StageData, CodecError>;

    /// Lossy inverse transform: map this stage's output stream back to
    /// the decoded view of its *input* stream (e.g. `kmeans` expands
    /// indices through the codebook; `topk` is the identity — pruning
    /// is not invertible).
    fn backward(&self, data: StageData) -> Result<StageData, CodecError>;
}

/// Stage-count cap: a spec with more stages than this is a typo or an
/// attack, not an experiment.
pub const MAX_STAGES: usize = 8;

/// An ordered, validated stage stack. Build one from a spec string via
/// [`super::CodecRegistry::build`].
pub struct Pipeline {
    stages: Vec<Box<dyn Stage>>,
}

impl Pipeline {
    /// Validate and assemble. Errors name the offending stage so CLI
    /// users see exactly which part of the spec is wrong.
    pub fn new(stages: Vec<Box<dyn Stage>>) -> Result<Pipeline, CodecError> {
        if stages.is_empty() {
            return Err(CodecError::BadSpec {
                what: "empty pipeline (expected name[|name]...)".to_string(),
            });
        }
        if stages.len() > MAX_STAGES {
            return Err(CodecError::BadSpec {
                what: format!("{} stages exceed the {MAX_STAGES}-stage cap", stages.len()),
            });
        }
        if let Some(first) = stages.first() {
            if first.input_kind() != DataKind::Floats {
                return Err(CodecError::BadSpec {
                    what: format!(
                        "'{}' consumes {} and cannot open a pipeline — put a \
                         clustering stage (kmeans, codebook) before it",
                        first.name(),
                        first.input_kind().name()
                    ),
                });
            }
        }
        for pair in stages.windows(2) {
            if let [a, b] = pair {
                if a.output_kind() != b.input_kind() {
                    return Err(CodecError::BadSpec {
                        what: format!(
                            "'{}' produces {} but '{}' consumes {}",
                            a.name(),
                            a.output_kind().name(),
                            b.name(),
                            b.input_kind().name()
                        ),
                    });
                }
            }
        }
        if let Some((_, init)) = stages.split_last() {
            for s in init {
                if s.terminal_only() {
                    return Err(CodecError::BadSpec {
                        what: format!("'{}' must be the last stage of a pipeline", s.name()),
                    });
                }
            }
        }
        Ok(Pipeline { stages })
    }

    pub fn stages(&self) -> &[Box<dyn Stage>] {
        &self.stages
    }

    /// [`Codec::encode`] plus a per-stage wall-time profile: one
    /// `("<idx>:<stage>", ns)` entry per stage in pipeline order (the
    /// index prefix keeps repeated stage names distinct). The blob is
    /// bit-identical to the untimed path — timing is observation only
    /// and must stay out of anything canonical (live-only by the
    /// `util::timer` contract).
    pub fn encode_timed(
        &self,
        input: &CodecInput<'_>,
        rng: &mut Rng,
    ) -> Result<(EncodedBlob, Vec<(String, u64)>), CodecError> {
        let mut ns = Vec::with_capacity(self.stages.len());
        let blob = self.encode_impl(input, rng, Some(&mut ns))?;
        Ok((blob, ns))
    }

    /// [`Codec::decode`] plus the per-stage profile, entries in
    /// execution order: terminal deserialize first, then each
    /// backward pass.
    pub fn decode_timed(&self, payload: &[u8]) -> Result<(Vec<f32>, Vec<(String, u64)>), CodecError> {
        let mut ns = Vec::with_capacity(self.stages.len());
        let theta = self.decode_impl(payload, Some(&mut ns))?;
        Ok((theta, ns))
    }

    fn encode_impl(
        &self,
        input: &CodecInput<'_>,
        rng: &mut Rng,
        mut timings: Option<&mut Vec<(String, u64)>>,
    ) -> Result<EncodedBlob, CodecError> {
        let (terminal, init) = self.stages.split_last().ok_or_else(empty_pipeline)?;
        let mut data = StageData::Floats(input.theta.to_vec());
        let mut stage_bytes = Vec::with_capacity(self.stages.len());
        for (i, stage) in init.iter().enumerate() {
            let sw = timings.is_some().then(Stopwatch::start);
            data = stage.encode(data, input, rng)?;
            stage_bytes.push(StageBytes {
                stage: stage.name().to_string(),
                bytes: stage.wire_len(&data),
            });
            if let (Some(t), Some(sw)) = (timings.as_deref_mut(), sw) {
                t.push((stage_label(i, stage.name()), sw.elapsed_ns()));
            }
        }
        let sw = timings.is_some().then(Stopwatch::start);
        data = terminal.encode(data, input, rng)?;
        let payload = terminal.serialize(&data, input)?;
        if let (Some(t), Some(sw)) = (timings.as_deref_mut(), sw) {
            t.push((stage_label(init.len(), terminal.name()), sw.elapsed_ns()));
        }
        stage_bytes.push(StageBytes {
            stage: terminal.name().to_string(),
            bytes: payload.len(),
        });
        Ok(EncodedBlob {
            payload,
            theta: data.to_floats(),
            stage_bytes,
        })
    }

    fn decode_impl(
        &self,
        payload: &[u8],
        mut timings: Option<&mut Vec<(String, u64)>>,
    ) -> Result<Vec<f32>, CodecError> {
        let (terminal, init) = self.stages.split_last().ok_or_else(empty_pipeline)?;
        let sw = timings.is_some().then(Stopwatch::start);
        let mut data = terminal.deserialize(payload)?;
        if let (Some(t), Some(sw)) = (timings.as_deref_mut(), sw) {
            t.push((stage_label(init.len(), terminal.name()), sw.elapsed_ns()));
        }
        for (i, stage) in init.iter().enumerate().rev() {
            let sw = timings.is_some().then(Stopwatch::start);
            data = stage.backward(data)?;
            if let (Some(t), Some(sw)) = (timings.as_deref_mut(), sw) {
                t.push((stage_label(i, stage.name()), sw.elapsed_ns()));
            }
        }
        Ok(data.to_floats())
    }
}

/// `<idx>:<stage>` — unique even when a stage name repeats in a spec.
fn stage_label(idx: usize, name: &str) -> String {
    format!("{idx}:{name}")
}

/// The error for the statically-unreachable empty-stage-list case
/// (`Pipeline::new` rejects it); keeps encode/decode panic-free.
fn empty_pipeline() -> CodecError {
    CodecError::BadSpec {
        what: "empty pipeline".to_string(),
    }
}

impl Codec for Pipeline {
    fn spec(&self) -> String {
        self.stages
            .iter()
            .map(|s| s.spec())
            .collect::<Vec<_>>()
            .join("|")
    }

    fn encode(&self, input: &CodecInput<'_>, rng: &mut Rng) -> Result<EncodedBlob, CodecError> {
        self.encode_impl(input, rng, None)
    }

    fn decode(&self, payload: &[u8]) -> Result<Vec<f32>, CodecError> {
        self.decode_impl(payload, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{stream, CodecRegistry};

    #[test]
    fn timed_paths_match_untimed_and_profile_every_stage() {
        let reg = CodecRegistry::builtin();
        let spec = "topk(keep=0.5)|kmeans(c=4,iters=5)|huffman";
        let mut rng = Rng::new(11);
        let theta: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
        let input = CodecInput {
            theta: &theta,
            centroids: None,
            stream: stream::FINAL,
        };

        let plain = reg.build(spec).unwrap();
        let blob = plain.encode(&input, &mut Rng::new(7)).unwrap();

        let timed = reg.build(spec).unwrap();
        let (tblob, enc_ns) = timed.encode_timed(&input, &mut Rng::new(7)).unwrap();
        assert_eq!(tblob.payload, blob.payload, "timing must not change bytes");
        assert_eq!(tblob.theta, blob.theta);
        let labels: Vec<&str> = enc_ns.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["0:topk", "1:kmeans", "2:huffman"]);

        let (theta_t, dec_ns) = timed.decode_timed(&blob.payload).unwrap();
        assert_eq!(theta_t, plain.decode(&blob.payload).unwrap());
        let labels: Vec<&str> = dec_ns.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["2:huffman", "1:kmeans", "0:topk"]);
    }
}
