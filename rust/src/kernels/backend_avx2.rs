//! AVX2 backend: 8-lane f32/i32 and 4-lane f64 kernels for x86-64.
//!
//! Safety model: every public function here is *safe* — it asserts
//! AVX2 support (cheap: `is_x86_feature_detected!` caches in an
//! atomic) before entering the `#[target_feature]` inner function, so
//! the only unsafety left is the CPU-feature contract, which the
//! assert discharges. Raw pointer arithmetic stays inside the proven
//! `i + LANES <= len` main loops; tails run the scalar reference.
//!
//! Bit-exactness notes (see `kernels::` module docs for the contract):
//! * integer kernels (keys, counts, max, histogram) are exact by
//!   commutativity;
//! * `axpy_f64` uses `_mm256_mul_pd` + `_mm256_add_pd` — two roundings
//!   per element like the scalar loop. Never replace with an FMA.
//! * `assign_nearest` counts `w <= boundary` with `_CMP_LE_OQ`
//!   (unordered compares false, so NaN counts zero boundaries and
//!   lands on the last centroid, exactly like the binary search).

use std::arch::x86_64::{
    __m256i, _mm256_add_pd, _mm256_and_si256, _mm256_castps_si256, _mm256_castsi256_ps,
    _mm256_cmp_ps, _mm256_cmpgt_epi32, _mm256_cvtps_pd, _mm256_loadu_pd, _mm256_loadu_ps,
    _mm256_loadu_si256, _mm256_max_epu32, _mm256_movemask_ps, _mm256_mul_pd, _mm256_set1_epi32,
    _mm256_set1_pd, _mm256_set1_ps, _mm256_setzero_si256, _mm256_storeu_pd, _mm256_storeu_si256,
    _mm256_sub_epi32, _CMP_LE_OQ,
};

use super::backend_scalar;
use super::magnitude_key;

/// Boundary count above which the O(n·c) lane-counting assignment
/// loses to the scalar O(n log c) binary search; measured crossover is
/// well past typical codebooks (C_max = 64 in the paper's controller).
const ASSIGN_MAX_BOUNDS: usize = 64;

#[inline]
fn have_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

pub fn magnitude_keys(xs: &[f32], out: &mut [u32]) {
    assert!(have_avx2(), "avx2 backend selected without avx2");
    // fedlint:allow(unsafe-scope) -- CPU-feature contract asserted on the line above
    unsafe { magnitude_keys_impl(xs, out) }
}

#[target_feature(enable = "avx2")]
// fedlint:allow(unsafe-scope) -- target_feature fn; sole caller asserts avx2 first
unsafe fn magnitude_keys_impl(xs: &[f32], out: &mut [u32]) {
    let n = xs.len().min(out.len());
    let mask = _mm256_set1_epi32(0x7FFF_FFFF);
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_loadu_si256(xs.as_ptr().add(i).cast::<__m256i>());
        let k = _mm256_and_si256(v, mask);
        _mm256_storeu_si256(out.as_mut_ptr().add(i).cast::<__m256i>(), k);
        i += 8;
    }
    backend_scalar::magnitude_keys(&xs[i..n], &mut out[i..n]);
}

pub fn abs_max_key(xs: &[f32]) -> u32 {
    assert!(have_avx2(), "avx2 backend selected without avx2");
    // fedlint:allow(unsafe-scope) -- CPU-feature contract asserted on the line above
    unsafe { abs_max_key_impl(xs) }
}

#[target_feature(enable = "avx2")]
// fedlint:allow(unsafe-scope) -- target_feature fn; sole caller asserts avx2 first
unsafe fn abs_max_key_impl(xs: &[f32]) -> u32 {
    let mask = _mm256_set1_epi32(0x7FFF_FFFF);
    let mut best8 = _mm256_setzero_si256();
    let mut i = 0;
    while i + 8 <= xs.len() {
        let v = _mm256_loadu_si256(xs.as_ptr().add(i).cast::<__m256i>());
        best8 = _mm256_max_epu32(best8, _mm256_and_si256(v, mask));
        i += 8;
    }
    let mut lanes = [0u32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr().cast::<__m256i>(), best8);
    let mut best = lanes.iter().copied().max().unwrap_or(0);
    for &x in &xs[i..] {
        best = best.max(magnitude_key(x));
    }
    best
}

pub fn threshold_count(keys: &[u32], threshold: u32) -> usize {
    assert!(have_avx2(), "avx2 backend selected without avx2");
    // fedlint:allow(unsafe-scope) -- CPU-feature contract asserted on the line above
    unsafe { threshold_count_impl(keys, threshold) }
}

#[target_feature(enable = "avx2")]
// fedlint:allow(unsafe-scope) -- target_feature fn; sole caller asserts avx2 first
unsafe fn threshold_count_impl(keys: &[u32], threshold: u32) -> usize {
    // magnitude keys never set bit 31, so the signed lane compare
    // orders them exactly like u32 comparison
    let t = _mm256_set1_epi32(threshold as i32);
    let mut count = 0usize;
    let mut i = 0;
    while i + 8 <= keys.len() {
        let k = _mm256_loadu_si256(keys.as_ptr().add(i).cast::<__m256i>());
        let gt = _mm256_cmpgt_epi32(k, t);
        count += _mm256_movemask_ps(_mm256_castsi256_ps(gt)).count_ones() as usize;
        i += 8;
    }
    count + backend_scalar::threshold_count(&keys[i..], threshold)
}

pub fn assign_nearest(xs: &[f32], sorted: &[f32], out: &mut [u32]) {
    assert!(have_avx2(), "avx2 backend selected without avx2");
    if sorted.len() > ASSIGN_MAX_BOUNDS + 1 {
        return backend_scalar::assign_nearest(xs, sorted, out);
    }
    // same f32 arithmetic as the scalar search evaluates at each probe
    let bounds: Vec<f32> = (0..sorted.len() - 1)
        .map(|j| 0.5 * (sorted[j] + sorted[j + 1]))
        .collect();
    // fedlint:allow(unsafe-scope) -- CPU-feature contract asserted on the first line
    unsafe { assign_nearest_impl(xs, &bounds, out) }
}

/// For nondecreasing boundaries, the binary search result equals
/// `(c-1) - #{j : w <= bounds[j]}` — including for NaN, where both
/// sides give `c-1`. The lane loop computes that count directly.
#[target_feature(enable = "avx2")]
// fedlint:allow(unsafe-scope) -- target_feature fn; sole caller asserts avx2 first
unsafe fn assign_nearest_impl(xs: &[f32], bounds: &[f32], out: &mut [u32]) {
    let n = xs.len().min(out.len());
    let last = _mm256_set1_epi32(bounds.len() as i32);
    let mut i = 0;
    while i + 8 <= n {
        let w = _mm256_loadu_ps(xs.as_ptr().add(i));
        let mut le = _mm256_setzero_si256();
        for &b in bounds {
            let cmp = _mm256_cmp_ps::<_CMP_LE_OQ>(w, _mm256_set1_ps(b));
            // a true lane is all-ones (-1 as i32); subtracting increments
            le = _mm256_sub_epi32(le, _mm256_castps_si256(cmp));
        }
        let idx = _mm256_sub_epi32(last, le);
        _mm256_storeu_si256(out.as_mut_ptr().add(i).cast::<__m256i>(), idx);
        i += 8;
    }
    for j in i..n {
        let mut count = 0u32;
        for &b in bounds {
            count += u32::from(xs[j] <= b);
        }
        out[j] = bounds.len() as u32 - count;
    }
}

pub fn axpy_f64(acc: &mut [f64], xs: &[f32], w: f64) {
    assert!(have_avx2(), "avx2 backend selected without avx2");
    // fedlint:allow(unsafe-scope) -- CPU-feature contract asserted on the line above
    unsafe { axpy_f64_impl(acc, xs, w) }
}

#[target_feature(enable = "avx2")]
// fedlint:allow(unsafe-scope) -- target_feature fn; sole caller asserts avx2 first
unsafe fn axpy_f64_impl(acc: &mut [f64], xs: &[f32], w: f64) {
    let n = acc.len().min(xs.len());
    let wv = _mm256_set1_pd(w);
    let mut i = 0;
    while i + 4 <= n {
        let x4 = std::arch::x86_64::_mm_loadu_ps(xs.as_ptr().add(i));
        let xd = _mm256_cvtps_pd(x4); // f32 -> f64 is exact
        let prod = _mm256_mul_pd(xd, wv); // rounding 1, as in `w * f64::from(x)`
        let a = _mm256_loadu_pd(acc.as_ptr().add(i));
        let sum = _mm256_add_pd(a, prod); // rounding 2, as in `+=`
        _mm256_storeu_pd(acc.as_mut_ptr().add(i), sum);
        i += 4;
    }
    backend_scalar::axpy_f64(&mut acc[i..n], &xs[i..n], w);
}
