//! NEON backend: 4-lane f32/u32 and 2-lane f64 kernels for aarch64.
//!
//! NEON is part of the aarch64 baseline, so unlike AVX2 there is no
//! runtime feature probe — `Backend::Neon` is unconditionally
//! available on this arch and the only unsafety is the raw-pointer
//! loads/stores inside the proven `i + LANES <= len` loops.
//!
//! The bit-exactness arguments mirror `backend_avx2`: integer kernels
//! are exact, `vcleq_f32` treats NaN as false like the scalar `<=`,
//! and `axpy_f64` uses separate `vmulq_f64` + `vaddq_f64` (never a
//! fused `vfmaq_f64`) to reproduce the scalar two-rounding sequence.

use core::arch::aarch64::{
    vaddq_f64, vaddvq_u32, vandq_u32, vcgtq_u32, vcleq_f32, vcvt_f64_f32, vdupq_n_f32,
    vdupq_n_f64, vdupq_n_u32, vld1_f32, vld1q_f32, vld1q_f64, vld1q_u32, vmaxq_u32, vmaxvq_u32,
    vmulq_f64, vst1q_f64, vst1q_u32, vsubq_u32,
};

use super::backend_scalar;
use super::magnitude_key;

/// Same crossover heuristic as the AVX2 backend: past this many
/// boundaries the O(n·c) counting loop loses to the scalar search.
const ASSIGN_MAX_BOUNDS: usize = 64;

pub fn magnitude_keys(xs: &[f32], out: &mut [u32]) {
    // fedlint:allow(unsafe-scope) -- NEON is aarch64 baseline; bounds proven in the loop
    unsafe { magnitude_keys_impl(xs, out) }
}

// fedlint:allow(unsafe-scope) -- raw-pointer lane loads; callers stay in-bounds
unsafe fn magnitude_keys_impl(xs: &[f32], out: &mut [u32]) {
    let n = xs.len().min(out.len());
    let mask = vdupq_n_u32(0x7FFF_FFFF);
    let mut i = 0;
    while i + 4 <= n {
        let v = vld1q_u32(xs.as_ptr().add(i).cast::<u32>());
        vst1q_u32(out.as_mut_ptr().add(i), vandq_u32(v, mask));
        i += 4;
    }
    backend_scalar::magnitude_keys(&xs[i..n], &mut out[i..n]);
}

pub fn abs_max_key(xs: &[f32]) -> u32 {
    // fedlint:allow(unsafe-scope) -- NEON is aarch64 baseline; bounds proven in the loop
    unsafe { abs_max_key_impl(xs) }
}

// fedlint:allow(unsafe-scope) -- raw-pointer lane loads; callers stay in-bounds
unsafe fn abs_max_key_impl(xs: &[f32]) -> u32 {
    let mask = vdupq_n_u32(0x7FFF_FFFF);
    let mut best4 = vdupq_n_u32(0);
    let mut i = 0;
    while i + 4 <= xs.len() {
        let v = vld1q_u32(xs.as_ptr().add(i).cast::<u32>());
        best4 = vmaxq_u32(best4, vandq_u32(v, mask));
        i += 4;
    }
    let mut best = vmaxvq_u32(best4);
    for &x in &xs[i..] {
        best = best.max(magnitude_key(x));
    }
    best
}

pub fn threshold_count(keys: &[u32], threshold: u32) -> usize {
    // fedlint:allow(unsafe-scope) -- NEON is aarch64 baseline; bounds proven in the loop
    unsafe { threshold_count_impl(keys, threshold) }
}

// fedlint:allow(unsafe-scope) -- raw-pointer lane loads; callers stay in-bounds
unsafe fn threshold_count_impl(keys: &[u32], threshold: u32) -> usize {
    let t = vdupq_n_u32(threshold);
    let mut count = 0usize;
    let mut i = 0;
    while i + 4 <= keys.len() {
        // a true lane is all-ones; subtracting it increments. Lane
        // counters reach at most 2^28, so the 4-lane horizontal sum
        // stays below 2^30 — no u32 wrap.
        let mut acc = vdupq_n_u32(0);
        let block_end = keys.len().min(i + 4 * (1usize << 28));
        while i + 4 <= block_end {
            let k = vld1q_u32(keys.as_ptr().add(i));
            acc = vsubq_u32(acc, vcgtq_u32(k, t));
            i += 4;
        }
        count += vaddvq_u32(acc) as usize;
    }
    count + backend_scalar::threshold_count(&keys[i..], threshold)
}

pub fn assign_nearest(xs: &[f32], sorted: &[f32], out: &mut [u32]) {
    if sorted.len() > ASSIGN_MAX_BOUNDS + 1 {
        return backend_scalar::assign_nearest(xs, sorted, out);
    }
    // same f32 arithmetic as the scalar search evaluates at each probe
    let bounds: Vec<f32> = (0..sorted.len() - 1)
        .map(|j| 0.5 * (sorted[j] + sorted[j + 1]))
        .collect();
    // fedlint:allow(unsafe-scope) -- NEON is aarch64 baseline; bounds proven in the loop
    unsafe { assign_nearest_impl(xs, &bounds, out) }
}

/// Count formulation, as in the AVX2 backend: the binary search result
/// equals `(c-1) - #{j : w <= bounds[j]}`, including for NaN.
// fedlint:allow(unsafe-scope) -- raw-pointer lane loads; callers stay in-bounds
unsafe fn assign_nearest_impl(xs: &[f32], bounds: &[f32], out: &mut [u32]) {
    let n = xs.len().min(out.len());
    let last = vdupq_n_u32(bounds.len() as u32);
    let mut i = 0;
    while i + 4 <= n {
        let w = vld1q_f32(xs.as_ptr().add(i));
        let mut le = vdupq_n_u32(0);
        for &b in bounds {
            le = vsubq_u32(le, vcleq_f32(w, vdupq_n_f32(b)));
        }
        vst1q_u32(out.as_mut_ptr().add(i), vsubq_u32(last, le));
        i += 4;
    }
    for j in i..n {
        let mut count = 0u32;
        for &b in bounds {
            count += u32::from(xs[j] <= b);
        }
        out[j] = bounds.len() as u32 - count;
    }
}

pub fn axpy_f64(acc: &mut [f64], xs: &[f32], w: f64) {
    // fedlint:allow(unsafe-scope) -- NEON is aarch64 baseline; bounds proven in the loop
    unsafe { axpy_f64_impl(acc, xs, w) }
}

// fedlint:allow(unsafe-scope) -- raw-pointer lane loads; callers stay in-bounds
unsafe fn axpy_f64_impl(acc: &mut [f64], xs: &[f32], w: f64) {
    let n = acc.len().min(xs.len());
    let wv = vdupq_n_f64(w);
    let mut i = 0;
    while i + 2 <= n {
        let xd = vcvt_f64_f32(vld1_f32(xs.as_ptr().add(i))); // f32 -> f64 is exact
        let prod = vmulq_f64(xd, wv); // rounding 1, as in `w * f64::from(x)`
        let sum = vaddq_f64(vld1q_f64(acc.as_ptr().add(i)), prod); // rounding 2
        vst1q_f64(acc.as_mut_ptr().add(i), sum);
        i += 2;
    }
    backend_scalar::axpy_f64(&mut acc[i..n], &xs[i..n], w);
}
