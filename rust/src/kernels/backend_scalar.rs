//! The scalar reference backend — the semantic source of truth.
//!
//! Every function here is a plain portable loop; the SIMD backends are
//! tested bit-identical against these (`tests/kernels_equiv.rs`). Keep
//! them boring: no manual unrolling, no word tricks — when a reference
//! and an optimized implementation disagree, the reference wins, so it
//! must be easy to audit against the call sites it replaced
//! (`util::bitio`, `compression::kmeans::assign_sorted`, the
//! `coordinator::accumulate` fold loop).

use super::magnitude_key;

pub fn magnitude_keys(xs: &[f32], out: &mut [u32]) {
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = magnitude_key(x);
    }
}

/// Magnitude key of the largest `|x|` (0 for empty input).
pub fn abs_max_key(xs: &[f32]) -> u32 {
    let mut best = 0u32;
    for &x in xs {
        best = best.max(magnitude_key(x));
    }
    best
}

pub fn threshold_count(keys: &[u32], threshold: u32) -> usize {
    let mut count = 0usize;
    for &k in keys {
        count += usize::from(k > threshold);
    }
    count
}

/// Midpoint binary search per element — the exact loop
/// `compression::kmeans::assign_sorted` has always run. NaN compares
/// false against every boundary, so it lands on the last centroid.
pub fn assign_nearest(xs: &[f32], sorted: &[f32], out: &mut [u32]) {
    for (o, &w) in out.iter_mut().zip(xs) {
        let mut lo = 0usize;
        let mut hi = sorted.len() - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            let boundary = 0.5 * (sorted[mid] + sorted[mid + 1]);
            if w <= boundary {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        *o = lo as u32;
    }
}

pub fn histogram_u32(symbols: &[u32], alphabet: usize) -> Vec<u64> {
    let mut freqs = vec![0u64; alphabet];
    for &s in symbols {
        freqs[s as usize] += 1;
    }
    freqs
}

/// Fixed-width LSB-first packing: a verbatim port of feeding
/// `util::bitio::BitWriter::write(v, bits)` per value.
pub fn pack_bits(values: &[u32], bits: u32) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    let mut used: u32 = 0;
    for &value in values {
        let mut v = value as u64;
        let mut n = bits;
        while n > 0 {
            if used == 0 {
                buf.push(0);
            }
            let free = 8 - used;
            let take = free.min(n);
            let last = buf.len() - 1;
            buf[last] |= ((v & ((1u64 << take) - 1)) as u8) << used;
            used = (used + take) % 8;
            v >>= take;
            n -= take;
        }
    }
    buf
}

/// Fixed-width LSB-first unpacking: a verbatim port of calling
/// `util::bitio::BitReader::read(bits)` `n` times, with the same
/// None-past-the-end contract.
pub fn unpack_bits(bytes: &[u8], bits: u32, n: usize) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(n);
    let mut pos = 0usize; // absolute bit position
    for _ in 0..n {
        if pos + bits as usize > bytes.len() * 8 {
            return None;
        }
        let mut v: u64 = 0;
        let mut got = 0;
        while got < bits {
            let byte = bytes[pos / 8];
            let off = (pos % 8) as u32;
            let take = (8 - off).min(bits - got);
            v |= (((byte >> off) as u64) & ((1u64 << take) - 1)) << got;
            got += take;
            pos += take as usize;
        }
        out.push(v as u32);
    }
    Some(out)
}

/// `acc[i] += w * f64::from(xs[i])` — two roundings per element, in
/// this order. This is the association the aggregate run keys were
/// produced under; every backend must reproduce it exactly.
pub fn axpy_f64(acc: &mut [f64], xs: &[f32], w: f64) {
    for (a, &x) in acc.iter_mut().zip(xs) {
        *a += w * f64::from(x);
    }
}
