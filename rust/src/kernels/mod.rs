//! Runtime-dispatched SIMD kernels for the codec and aggregation hot
//! paths (ROADMAP item 2).
//!
//! Every round, every client runs topk selection, k-means assignment,
//! and entropy coding over the full weight vector, and the coordinator
//! folds uploads — all inner loops over flat arrays. This module is the
//! narrow waist those loops go through: a small kernel API with three
//! backends, selected once at startup:
//!
//! * `scalar` — portable reference loops, the **semantic source of
//!   truth**. Every other backend must be bit-identical to it on every
//!   input (`tests/kernels_equiv.rs` is the gate).
//! * `avx2`   — x86-64, used when `is_x86_feature_detected!("avx2")`
//!   reports support at startup.
//! * `neon`   — aarch64 baseline SIMD.
//!
//! `FEDCOMPRESS_KERNELS=scalar|avx2|neon` overrides detection (CI runs
//! the full suite once with `scalar` forced); an unavailable or unknown
//! value warns on stderr and falls back to detection, so a bad override
//! can never change results — only speed.
//!
//! # Bit-exactness contract
//!
//! Wire bytes and aggregates are content-addressed (run keys, golden
//! loopback, record caches), so backends are **not allowed to change
//! results**, ever. That restricts SIMD to order-independent lanes:
//!
//! * magnitude keys (`|x|` bit patterns), compares, selects, integer
//!   histograms, and bit manipulation are elementwise or commutative —
//!   freely vectorizable;
//! * the weighted-sum fold (`axpy_f64`) is elementwise over independent
//!   accumulator slots: each lane performs the same two IEEE roundings
//!   (`mul` then `add`) as the scalar loop. Backends must NOT fuse them
//!   (no FMA) — a single-rounding fused lane would diverge;
//! * `assign_nearest` replaces the scalar binary search with a
//!   count-of-boundaries formulation that is provably identical for a
//!   sorted codebook (including NaN inputs, which land on the last
//!   centroid under both); both evaluate boundaries as
//!   `0.5 * (c[j] + c[j+1])` in f32.
//!
//! Anything order-dependent (the tie budget in `magnitude_prune`, the
//! variable-width Huffman bit stream) stays scalar at the call site.
//!
//! # Magnitude keys
//!
//! `|x|` comparisons run on `x.to_bits() & 0x7FFF_FFFF`: for
//! non-negative floats the IEEE bit pattern is monotone, so integer
//! compares on keys order exactly like `f32::total_cmp` on `|x|` —
//! finite magnitudes in numeric order, then infinity, then NaN. This
//! buys panic-free selection on non-finite input and lets the SIMD
//! backends use integer compares (keys never set bit 31, so signed
//! lane compares are safe).
//!
//! # Adding a backend
//!
//! 1. `src/kernels/backend_<name>.rs`, `#[cfg(target_arch = ...)]`
//!    gated, exposing the same function set as `backend_scalar` —
//!    delegating any kernel it does not accelerate back to the shared
//!    implementations is fine (NEON does this for `histogram_u32`).
//! 2. A `Backend` variant + arms in `available`, `from_name`,
//!    `detect`, and each `*_on` dispatch below (the `_ => scalar`
//!    catch-alls keep other arches compiling).
//! 3. `unsafe` is allowed only in `src/kernels/backend_*.rs`, and each
//!    block carries `// fedlint:allow(unsafe-scope) -- <why sound>`
//!    (the `unsafe-scope` lint rule gates this).
//! 4. Run `cargo test --test kernels_equiv` on the target hardware —
//!    the property suite must pass before the backend can ship.

pub mod backend_scalar;

#[cfg(target_arch = "x86_64")]
pub mod backend_avx2;

#[cfg(target_arch = "aarch64")]
pub mod backend_neon;

use std::sync::OnceLock;

/// One kernel implementation set. `Scalar` is always available and is
/// the reference the others are tested against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    pub fn from_name(name: &str) -> Option<Backend> {
        match name {
            "scalar" => Some(Backend::Scalar),
            "avx2" => Some(Backend::Avx2),
            "neon" => Some(Backend::Neon),
            _ => None,
        }
    }

    /// Can this backend run on the current machine?
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => true,
            _ => false,
        }
    }
}

/// Best available backend for this machine (ignores the env override).
pub fn detect() -> Backend {
    if Backend::Avx2.available() {
        Backend::Avx2
    } else if Backend::Neon.available() {
        Backend::Neon
    } else {
        Backend::Scalar
    }
}

/// Every backend that can run here, scalar first — the iteration set
/// for the equivalence suite and the comparative bench tables.
pub fn available_backends() -> Vec<Backend> {
    [Backend::Scalar, Backend::Avx2, Backend::Neon]
        .into_iter()
        .filter(|b| b.available())
        .collect()
}

/// The process-wide backend: `FEDCOMPRESS_KERNELS` when set and
/// available, detection otherwise. Resolved once, on first use.
pub fn active() -> Backend {
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("FEDCOMPRESS_KERNELS") {
        Ok(name) => match Backend::from_name(name.trim()) {
            Some(b) if b.available() => b,
            Some(b) => {
                eprintln!(
                    "fedcompress: FEDCOMPRESS_KERNELS={} unavailable on this cpu; \
                     using {}",
                    b.name(),
                    detect().name()
                );
                detect()
            }
            None => {
                eprintln!(
                    "fedcompress: FEDCOMPRESS_KERNELS={name:?} unknown \
                     (expected scalar|avx2|neon); using {}",
                    detect().name()
                );
                detect()
            }
        },
        Err(_) => detect(),
    })
}

/// Clamp an explicit backend request to something runnable.
fn resolve(b: Backend) -> Backend {
    if b.available() {
        b
    } else {
        Backend::Scalar
    }
}

// --- the kernel API ---------------------------------------------------------
//
// Each kernel has an `*_on(backend, ...)` form (the equivalence suite
// and the bench tables pick backends explicitly) and a plain form that
// dispatches through [`active`]. An unavailable backend silently runs
// scalar — results are identical by contract, so this is safe.

/// Magnitude key of one f32: the bit pattern of `|x|`. Monotone with
/// `f32::total_cmp` on `|x|`; never sets bit 31.
#[inline]
pub fn magnitude_key(x: f32) -> u32 {
    x.to_bits() & 0x7FFF_FFFF
}

/// Fill `out[i] = magnitude_key(xs[i])`.
pub fn magnitude_keys_on(b: Backend, xs: &[f32], out: &mut [u32]) {
    debug_assert_eq!(xs.len(), out.len());
    match resolve(b) {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => backend_avx2::magnitude_keys(xs, out),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => backend_neon::magnitude_keys(xs, out),
        _ => backend_scalar::magnitude_keys(xs, out),
    }
}

/// Magnitude keys of `xs` as a fresh vector.
pub fn magnitude_keys(xs: &[f32]) -> Vec<u32> {
    let mut out = vec![0u32; xs.len()];
    magnitude_keys_on(active(), xs, &mut out);
    out
}

/// Largest `|x|` in `xs` under the magnitude-key order (0.0 for empty
/// input; NaN wins over everything when present).
pub fn abs_max_on(b: Backend, xs: &[f32]) -> f32 {
    let key = match resolve(b) {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => backend_avx2::abs_max_key(xs),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => backend_neon::abs_max_key(xs),
        _ => backend_scalar::abs_max_key(xs),
    };
    f32::from_bits(key)
}

pub fn abs_max(xs: &[f32]) -> f32 {
    abs_max_on(active(), xs)
}

/// Count of `keys[i] > threshold`. Both sides must be magnitude keys
/// (bit 31 clear) — the SIMD backends rely on that for signed lane
/// compares.
pub fn threshold_count_on(b: Backend, keys: &[u32], threshold: u32) -> usize {
    debug_assert!(threshold <= 0x7FFF_FFFF);
    match resolve(b) {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => backend_avx2::threshold_count(keys, threshold),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => backend_neon::threshold_count(keys, threshold),
        _ => backend_scalar::threshold_count(keys, threshold),
    }
}

pub fn threshold_count(keys: &[u32], threshold: u32) -> usize {
    threshold_count_on(active(), keys, threshold)
}

/// Nearest-centroid assignment against a *sorted* codebook:
/// `out[i] = argmin_j |xs[i] - sorted[j]|`, ties to the lower index,
/// NaN to the last. Identical to a midpoint binary search.
pub fn assign_nearest_on(b: Backend, xs: &[f32], sorted: &[f32], out: &mut [u32]) {
    assert!(!sorted.is_empty(), "assign_nearest needs a codebook");
    debug_assert_eq!(xs.len(), out.len());
    match resolve(b) {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => backend_avx2::assign_nearest(xs, sorted, out),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => backend_neon::assign_nearest(xs, sorted, out),
        _ => backend_scalar::assign_nearest(xs, sorted, out),
    }
}

pub fn assign_nearest(xs: &[f32], sorted: &[f32], out: &mut [u32]) {
    assign_nearest_on(active(), xs, sorted, out)
}

/// Quantize `xs` in place against a sorted codebook; returns the index
/// stream. Composition of [`assign_nearest`] and a gather — the gather
/// is the same loop on every backend.
pub fn snap_to_codebook_on(b: Backend, xs: &mut [f32], sorted: &[f32]) -> Vec<u32> {
    let mut idx = vec![0u32; xs.len()];
    assign_nearest_on(b, xs, sorted, &mut idx);
    for (x, &j) in xs.iter_mut().zip(&idx) {
        *x = sorted[j as usize];
    }
    idx
}

pub fn snap_to_codebook(xs: &mut [f32], sorted: &[f32]) -> Vec<u32> {
    snap_to_codebook_on(active(), xs, sorted)
}

/// Frequency count of `symbols` over `0..alphabet`. Panics (like the
/// plain indexing loop it replaces) on an out-of-range symbol — the
/// Huffman encoder owns the alphabet it counts.
pub fn histogram_u32_on(b: Backend, symbols: &[u32], alphabet: usize) -> Vec<u64> {
    match resolve(b) {
        Backend::Scalar => backend_scalar::histogram_u32(symbols, alphabet),
        // integer adds are commutative: the unrolled multi-table count
        // is exact on every backend
        _ => fast::histogram_u32(symbols, alphabet),
    }
}

pub fn histogram_u32(symbols: &[u32], alphabet: usize) -> Vec<u64> {
    histogram_u32_on(active(), symbols, alphabet)
}

/// Pack the low `bits` bits of each value, LSB-first — byte-identical
/// to `util::bitio::BitWriter` fed the same stream. Values must fit in
/// `bits` (1..=32), as the bitio writer also requires.
pub fn pack_bits_on(b: Backend, values: &[u32], bits: u32) -> Vec<u8> {
    debug_assert!((1..=32).contains(&bits));
    match resolve(b) {
        Backend::Scalar => backend_scalar::pack_bits(values, bits),
        _ => fast::pack_bits(values, bits),
    }
}

pub fn pack_bits(values: &[u32], bits: u32) -> Vec<u8> {
    pack_bits_on(active(), values, bits)
}

/// Unpack `n` fixed-width values (LSB-first) — the inverse of
/// [`pack_bits`], matching `util::bitio::BitReader`. `None` when
/// `bytes` holds fewer than `n * bits` bits; range checks stay with
/// the caller, which knows the domain.
pub fn unpack_bits_on(b: Backend, bytes: &[u8], bits: u32, n: usize) -> Option<Vec<u32>> {
    debug_assert!((1..=32).contains(&bits));
    match resolve(b) {
        Backend::Scalar => backend_scalar::unpack_bits(bytes, bits, n),
        _ => fast::unpack_bits(bytes, bits, n),
    }
}

pub fn unpack_bits(bytes: &[u8], bits: u32, n: usize) -> Option<Vec<u32>> {
    unpack_bits_on(active(), bytes, bits, n)
}

/// The weighted-sum fold: `acc[i] += w * f64::from(xs[i])` — exactly
/// two IEEE roundings per element, never fused. Slices must be the
/// same length (the accumulator validates before calling).
pub fn axpy_f64_on(b: Backend, acc: &mut [f64], xs: &[f32], w: f64) {
    debug_assert_eq!(acc.len(), xs.len());
    match resolve(b) {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => backend_avx2::axpy_f64(acc, xs, w),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => backend_neon::axpy_f64(acc, xs, w),
        _ => backend_scalar::axpy_f64(acc, xs, w),
    }
}

pub fn axpy_f64(acc: &mut [f64], xs: &[f32], w: f64) {
    axpy_f64_on(active(), acc, xs, w)
}

// --- shared word-level implementations --------------------------------------

/// Safe, word-parallel bit packing and unrolled histogram shared by
/// the SIMD backends: no lane intrinsics, but a u64 bit accumulator
/// (one store per 8 output bytes instead of bit-twiddling per byte)
/// and a 4-way table split that breaks the store-to-load dependency
/// chain. Byte- and count-identical to the scalar reference.
mod fast {
    pub fn pack_bits(values: &[u32], bits: u32) -> Vec<u8> {
        let total_bits = values.len() * bits as usize;
        let mut out = Vec::with_capacity(total_bits.div_ceil(8));
        let mut acc: u64 = 0;
        let mut used: u32 = 0;
        let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
        for &v in values {
            acc |= (v as u64 & mask) << used;
            used += bits;
            while used >= 8 {
                out.push((acc & 0xFF) as u8);
                acc >>= 8;
                used -= 8;
            }
        }
        if used > 0 {
            out.push((acc & 0xFF) as u8);
        }
        out
    }

    pub fn unpack_bits(bytes: &[u8], bits: u32, n: usize) -> Option<Vec<u32>> {
        if n.checked_mul(bits as usize)? > bytes.len().checked_mul(8)? {
            return None;
        }
        let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
        let mut out = Vec::with_capacity(n);
        let mut acc: u64 = 0;
        let mut have: u32 = 0;
        let mut pos = 0usize;
        for _ in 0..n {
            while have < bits {
                // the upfront bit-count check guarantees the byte
                acc |= (bytes[pos] as u64) << have;
                pos += 1;
                have += 8;
            }
            out.push((acc & mask) as u32);
            acc >>= bits;
            have -= bits;
        }
        Some(out)
    }

    pub fn histogram_u32(symbols: &[u32], alphabet: usize) -> Vec<u64> {
        let mut t0 = vec![0u64; alphabet];
        let mut t1 = vec![0u64; alphabet];
        let mut t2 = vec![0u64; alphabet];
        let mut t3 = vec![0u64; alphabet];
        let mut quads = symbols.chunks_exact(4);
        for q in &mut quads {
            t0[q[0] as usize] += 1;
            t1[q[1] as usize] += 1;
            t2[q[2] as usize] += 1;
            t3[q[3] as usize] += 1;
        }
        for &s in quads.remainder() {
            t0[s as usize] += 1;
        }
        for i in 0..alphabet {
            t0[i] += t1[i] + t2[i] + t3[i];
        }
        t0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_round_trip() {
        for b in [Backend::Scalar, Backend::Avx2, Backend::Neon] {
            assert_eq!(Backend::from_name(b.name()), Some(b));
        }
        assert_eq!(Backend::from_name("sse9"), None);
    }

    #[test]
    fn scalar_is_always_available_and_detection_is_runnable() {
        assert!(Backend::Scalar.available());
        assert!(detect().available());
        let avail = available_backends();
        assert_eq!(avail[0], Backend::Scalar);
        assert!(avail.contains(&detect()));
        // the active backend is runnable whatever the env said
        assert!(active().available());
    }

    #[test]
    fn unavailable_backend_requests_resolve_to_scalar_results() {
        // on any one machine at most one SIMD set is available; the
        // other must silently produce scalar (= identical) results
        let xs = [1.5f32, -2.0, 0.0, 3.25];
        for b in [Backend::Avx2, Backend::Neon] {
            assert_eq!(abs_max_on(b, &xs), abs_max_on(Backend::Scalar, &xs));
        }
    }

    #[test]
    fn magnitude_keys_order_like_total_cmp_on_abs() {
        let vals = [0.0f32, -0.0, 1.0, -1.0, 1.5, f32::INFINITY, f32::NAN, 1e-30];
        for &a in &vals {
            for &b in &vals {
                let key_ord = magnitude_key(a).cmp(&magnitude_key(b));
                let cmp_ord = a.abs().total_cmp(&b.abs());
                assert_eq!(key_ord, cmp_ord, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn abs_max_matches_float_fold_on_finite_input() {
        let xs = [0.5f32, -3.25, 2.0, -0.0, 1.0];
        assert_eq!(abs_max(&xs), 3.25);
        assert_eq!(abs_max(&[]), 0.0);
        assert!(abs_max(&[f32::NAN, 1.0]).is_nan());
    }

    #[test]
    fn pack_bits_is_byte_identical_to_bitwriter() {
        use crate::util::bitio::BitWriter;
        let vals: Vec<u32> = (0..257).map(|i| (i * 37) as u32 % 2048).collect();
        for bits in [1u32, 3, 8, 11, 16, 31, 32] {
            let capped: Vec<u32> = vals
                .iter()
                .map(|&v| if bits == 32 { v } else { v & ((1u32 << bits) - 1) })
                .collect();
            let mut w = BitWriter::new();
            for &v in &capped {
                w.write(v, bits);
            }
            let reference = w.into_bytes();
            for b in available_backends() {
                assert_eq!(pack_bits_on(b, &capped, bits), reference, "bits={bits} {b:?}");
            }
        }
    }

    #[test]
    fn unpack_bits_inverts_pack_and_detects_truncation() {
        let vals: Vec<u32> = (0..100).map(|i| i * 7 % 512).collect();
        for bits in [9u32, 10, 16] {
            let bytes = pack_bits(&vals, bits);
            for b in available_backends() {
                assert_eq!(
                    unpack_bits_on(b, &bytes, bits, vals.len()).as_deref(),
                    Some(vals.as_slice())
                );
                assert_eq!(unpack_bits_on(b, &bytes[..bytes.len() - 1], bits, vals.len()), None);
            }
        }
        assert_eq!(unpack_bits(&[], 8, 0).as_deref(), Some(&[][..]));
        assert_eq!(unpack_bits(&[], 8, 1), None);
    }

    #[test]
    fn snap_matches_the_kmeans_reference() {
        let cb = [-1.0f32, 0.0, 2.0];
        let mut xs = [-3.0f32, -0.6, -0.49, -0.4, 0.9, 1.1, 9.0];
        let idx = snap_to_codebook(&mut xs, &cb);
        assert_eq!(idx, [0, 0, 1, 1, 1, 2, 2]);
        assert_eq!(xs, [-1.0, -1.0, 0.0, 0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn histogram_counts_every_symbol_once() {
        let symbols: Vec<u32> = (0..1000).map(|i| (i % 7) as u32).collect();
        for b in available_backends() {
            let h = histogram_u32_on(b, &symbols, 7);
            assert_eq!(h.iter().sum::<u64>(), 1000);
            assert_eq!(h[0], 143);
            assert_eq!(h[6], 142);
        }
    }

    #[test]
    fn axpy_accumulates_like_the_scalar_loop() {
        let xs: Vec<f32> = (0..37).map(|i| (i as f32) * 0.37 - 5.0).collect();
        let w = 0.12345f64;
        let mut want = vec![0.25f64; xs.len()];
        for (a, &x) in want.iter_mut().zip(&xs) {
            *a += w * f64::from(x);
        }
        for b in available_backends() {
            let mut acc = vec![0.25f64; xs.len()];
            axpy_f64_on(b, &mut acc, &xs, w);
            assert_eq!(acc, want, "{b:?}");
        }
    }
}
