//! The sweep orchestrator: expand a declarative grid into
//! content-addressed jobs, execute them in parallel, persist every
//! completed run in the [`RunStore`], and skip anything the store
//! already holds.
//!
//! ```text
//! SweepSpec (flags or spec file)        store::RunStore
//!   -> expand()    strategies x fleets x seeds x grid axes
//!   -> partition   key in store?  -> cached (resume-by-cache)
//!   -> execute     threadpool::parallel_map, one engine per worker
//!                  thread (spec.rs / runner.rs), records appended
//!                  under a mutex as each job completes
//!   -> SweepOutcome  executed / cached / failed counts
//! ```
//!
//! Failure isolation: one failed job never aborts the sweep — its
//! error is reported through [`SweepEvent::JobFailed`] and counted in
//! [`SweepOutcome::failed`]; every completed job is already durable in
//! the store, so re-running the same sweep re-attempts only the
//! failures (everything else cache-hits).

pub mod runner;
pub mod spec;

pub use runner::{run_or_cached, verify_cached, CacheStats, EngineRunner, JobRunner, SmokeRunner};
pub use spec::{GridAxis, SweepJob, SweepSpec};

use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::obs::stream::write_record_stream;
use crate::store::{key_hex, RunRecord, RunStore};
use crate::util::threadpool::parallel_map;

/// Progress stream of a sweep (the CLI prints these as they happen).
/// Owned payloads — the stream outlives no borrow and closures over it
/// never need higher-ranked lifetimes.
#[derive(Clone, Debug)]
pub enum SweepEvent {
    /// Emitted once after cache partitioning, before execution.
    Planned { total: usize, cached: usize },
    JobStart { idx: usize, label: String },
    JobDone {
        idx: usize,
        key: u64,
        label: String,
        cached: bool,
        final_accuracy: f64,
        wall_s: f64,
    },
    JobFailed {
        idx: usize,
        label: String,
        error: String,
    },
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepOutcome {
    pub total: usize,
    pub executed: usize,
    pub cached: usize,
    pub failed: usize,
}

impl SweepOutcome {
    /// One-line summary (the CLI's final line; CI greps it).
    pub fn summary(&self) -> String {
        format!(
            "sweep: {} jobs — {} executed, {} cached, {} failed",
            self.total, self.executed, self.cached, self.failed
        )
    }
}

/// Best-effort per-job event stream tee into `events_dir`. A failed
/// write is logged, never escalated — observability must not fail a
/// sweep whose record is already durable in the store.
fn tee_record(events_dir: Option<&Path>, rec: &RunRecord, overwrite: bool) {
    if let Some(dir) = events_dir {
        let path = dir.join(format!("{}.jsonl", key_hex(rec.key)));
        if !overwrite && path.exists() {
            return;
        }
        if let Err(e) = write_record_stream(rec, &path) {
            crate::info!("event stream tee {}: {e}", path.display());
        }
    }
}

/// Execute `jobs` against `store` with `workers` parallel threads.
///
/// Jobs whose key already has a completed record are skipped
/// (`force` re-executes them; the fresh record supersedes). Pending
/// jobs run on [`parallel_map`]; each completed record is appended to
/// the store immediately (mutex-serialized), so an interrupted sweep
/// resumes from what finished.
///
/// `events_dir` (usually `<store>/events`) tees a replayable
/// `<key>.jsonl` event stream per completed job: freshly executed jobs
/// overwrite theirs, cache hits only fill in a missing file — the tee
/// is best-effort observability and never fails the sweep.
pub fn run_sweep(
    jobs: &[SweepJob],
    store: &mut RunStore,
    runner: &dyn JobRunner,
    workers: usize,
    force: bool,
    events_dir: Option<&Path>,
    progress: &(dyn Fn(SweepEvent) + Sync),
) -> Result<SweepOutcome> {
    let mut cached: Vec<&SweepJob> = Vec::new();
    let mut pending: Vec<&SweepJob> = Vec::new();
    for job in jobs {
        if !force && store.contains(job.key) {
            cached.push(job);
        } else {
            pending.push(job);
        }
    }
    progress(SweepEvent::Planned {
        total: jobs.len(),
        cached: cached.len(),
    });

    // cache hits are still verified: a key collision or a tampered
    // store must fail the sweep, not silently stand in for a run
    for &job in &cached {
        let rec = store.get(job.key)?.expect("partitioned as cached");
        verify_cached(&rec, &job.strategy, &job.cfg)?;
        tee_record(events_dir, &rec, false);
        progress(SweepEvent::JobDone {
            idx: job.idx,
            key: job.key,
            label: job.label(),
            cached: true,
            final_accuracy: rec.final_accuracy,
            wall_s: 0.0,
        });
    }

    let store_mutex = Mutex::new(store);
    let failures: Vec<Option<String>> = if pending.is_empty() {
        Vec::new()
    } else {
        parallel_map(pending.len(), workers.max(1), |i| {
            let job = pending[i];
            progress(SweepEvent::JobStart {
                idx: job.idx,
                label: job.label(),
            });
            // wall_s is a bench field, excluded from record diffing;
            // the read goes through the sanctioned timer
            let t0 = crate::util::timer::Stopwatch::start();
            match runner.run(job) {
                Ok(rec) => {
                    debug_assert_eq!(rec.key, job.key, "runner broke the key contract");
                    let append = {
                        let mut guard = store_mutex.lock().unwrap();
                        guard.append(&rec)
                    };
                    match append {
                        Ok(()) => {
                            tee_record(events_dir, &rec, true);
                            progress(SweepEvent::JobDone {
                                idx: job.idx,
                                key: job.key,
                                label: job.label(),
                                cached: false,
                                final_accuracy: rec.final_accuracy,
                                wall_s: t0.elapsed_s(),
                            });
                            None
                        }
                        Err(e) => {
                            let error = format!("persisting record: {e}");
                            progress(SweepEvent::JobFailed {
                                idx: job.idx,
                                label: job.label(),
                                error: error.clone(),
                            });
                            Some(error)
                        }
                    }
                }
                Err(e) => {
                    let error = format!("{e:#}");
                    progress(SweepEvent::JobFailed {
                        idx: job.idx,
                        label: job.label(),
                        error: error.clone(),
                    });
                    Some(error)
                }
            }
        })
    };

    // one sidecar refresh for the whole batch (appends skip it)
    store_mutex.into_inner().unwrap().flush_sidecar()?;

    let failed = failures.iter().flatten().count();
    Ok(SweepOutcome {
        total: jobs.len(),
        executed: pending.len() - failed,
        cached: cached.len(),
        failed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::registry::StrategyRegistry;
    use crate::config::FedConfig;
    use crate::store::RunRecord;

    fn tmp_store(name: &str) -> RunStore {
        let dir = std::env::temp_dir().join("fedcompress_sweep_unit").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        RunStore::open(&dir).unwrap()
    }

    fn grid_jobs() -> Vec<SweepJob> {
        let spec = SweepSpec {
            strategies: vec!["fedavg".into(), "fedcompress".into()],
            seeds: vec![1, 2],
            ..SweepSpec::default()
        };
        spec.expand(&FedConfig::quick("cifar10"), &StrategyRegistry::builtin())
            .unwrap()
    }

    #[test]
    fn second_sweep_is_all_cache_hits() {
        let mut store = tmp_store("cache");
        let jobs = grid_jobs();
        let quiet = |_: SweepEvent| {};
        let first = run_sweep(&jobs, &mut store, &SmokeRunner, 4, false, None, &quiet).unwrap();
        assert_eq!(first.executed, 4);
        assert_eq!(first.cached, 0);
        assert_eq!(first.failed, 0);
        assert_eq!(store.len(), 4);
        let second = run_sweep(&jobs, &mut store, &SmokeRunner, 4, false, None, &quiet).unwrap();
        assert_eq!(second.cached, 4, "every job must cache-hit");
        assert_eq!(second.executed, 0, "zero re-execution");
        assert_eq!(store.len(), 4, "no new records");
        // force re-executes and supersedes
        let forced = run_sweep(&jobs, &mut store, &SmokeRunner, 2, true, None, &quiet).unwrap();
        assert_eq!(forced.executed, 4);
        assert_eq!(store.len(), 4, "same keys");
        assert_eq!(store.metas().len(), 8, "history kept");
    }

    /// One failing job neither aborts the sweep nor poisons the store.
    struct FailOne;
    impl JobRunner for FailOne {
        fn run(&self, job: &SweepJob) -> Result<RunRecord> {
            if job.idx == 1 {
                anyhow::bail!("injected failure");
            }
            SmokeRunner.run(job)
        }
        fn kind(&self) -> &'static str {
            "fail-one"
        }
    }

    #[test]
    fn failures_are_isolated_and_retried_next_sweep() {
        let mut store = tmp_store("failures");
        let jobs = grid_jobs();
        let quiet = |_: SweepEvent| {};
        let out = run_sweep(&jobs, &mut store, &FailOne, 2, false, None, &quiet).unwrap();
        assert_eq!(out.failed, 1);
        assert_eq!(out.executed, 3);
        assert_eq!(store.len(), 3, "completed jobs persisted");
        // the retry sweep only re-runs the failure
        let out = run_sweep(&jobs, &mut store, &SmokeRunner, 2, false, None, &quiet).unwrap();
        assert_eq!(out.cached, 3);
        assert_eq!(out.executed, 1);
        assert_eq!(out.failed, 0);
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn progress_events_cover_every_job() {
        use std::sync::Mutex as M;
        let mut store = tmp_store("progress");
        let jobs = grid_jobs();
        let seen = M::new((0usize, 0usize, 0usize)); // planned_total, starts, dones
        run_sweep(&jobs, &mut store, &SmokeRunner, 2, false, None, &|e| {
            let mut g = seen.lock().unwrap();
            match e {
                SweepEvent::Planned { total, .. } => g.0 = total,
                SweepEvent::JobStart { .. } => g.1 += 1,
                SweepEvent::JobDone { .. } => g.2 += 1,
                SweepEvent::JobFailed { .. } => {}
            }
        })
        .unwrap();
        let (planned, starts, dones) = *seen.lock().unwrap();
        assert_eq!(planned, 4);
        assert_eq!(starts, 4);
        assert_eq!(dones, 4);
    }
}
