//! Declarative sweep grids: strategies x fleet presets x seeds x
//! arbitrary config knobs, expanded into content-addressed jobs.
//!
//! A grid comes from CLI flags (`--strategies a,b --fleets x,y
//! --seeds 1,2 --axis c_max=8,16`) or a small `key = value` spec file:
//!
//! ```text
//! # FedCompress budget sweep
//! strategies = fedavg,fedcompress
//! fleets     = ideal,mobile
//! seeds      = 42,43
//! grid.c_max = 8,16,32
//! grid.topk_keep = 0.05,0.1
//! ```
//!
//! `grid.<key>` axes go through `FedConfig::set`, so every `--set`able
//! knob (cluster budgets, compression keeps, learning rates, ...) can
//! be swept; unknown keys fail at expansion time, before anything
//! runs.

use anyhow::{bail, Context, Result};

use crate::baselines::registry::StrategyRegistry;
use crate::config::FedConfig;
use crate::sim::FleetPreset;
use crate::store::run_key;

/// One swept config knob: a `FedConfig::set` key and its values.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GridAxis {
    pub key: String,
    pub values: Vec<String>,
}

/// The declarative grid. Empty dimensions default at expansion time:
/// no strategies -> every registered strategy; no fleets -> the base
/// config's preset; no seeds -> the base config's seed.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepSpec {
    pub strategies: Vec<String>,
    pub fleets: Vec<FleetPreset>,
    pub seeds: Vec<u64>,
    pub axes: Vec<GridAxis>,
}

/// One expanded job: a canonical strategy name, the fully resolved
/// config, and the content key a completed record would carry.
#[derive(Clone, Debug)]
pub struct SweepJob {
    /// position in expansion order (stable across re-runs)
    pub idx: usize,
    pub strategy: String,
    pub cfg: FedConfig,
    pub key: u64,
}

impl SweepJob {
    /// Compact one-line label for progress output.
    pub fn label(&self) -> String {
        format!(
            "{}/{} fleet={} seed={}",
            self.strategy,
            self.cfg.dataset,
            self.cfg.fleet.preset.name(),
            self.cfg.seed,
        )
    }
}

impl SweepSpec {
    /// Parse a spec file (`key = value` lines, `#` comments).
    pub fn from_file(path: &std::path::Path) -> Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading sweep spec {path:?}"))?;
        SweepSpec::parse(&text)
    }

    pub fn parse(text: &str) -> Result<SweepSpec> {
        let mut spec = SweepSpec::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("sweep spec line {}: expected 'key = values', got '{raw}'", lineno + 1);
            };
            let (key, value) = (key.trim(), value.trim());
            let values = split_top_level(value);
            if values.is_empty() {
                bail!("sweep spec line {}: '{key}' has no values", lineno + 1);
            }
            match key {
                "strategies" => {
                    spec.strategies.extend(values.iter().map(|s| s.to_string()))
                }
                "fleets" => spec.fleets.extend(FleetPreset::parse_list(value)?),
                "seeds" => {
                    for v in &values {
                        spec.seeds.push(
                            v.parse::<u64>()
                                .with_context(|| format!("sweep seed '{v}'"))?,
                        );
                    }
                }
                _ => match key.strip_prefix("grid.") {
                    Some(cfg_key) if !cfg_key.is_empty() => spec.axes.push(GridAxis {
                        key: cfg_key.to_string(),
                        values: values.iter().map(|s| s.to_string()).collect(),
                    }),
                    _ => bail!(
                        "sweep spec line {}: unknown key '{key}' \
                         (use strategies/fleets/seeds/grid.<cfg-key>)",
                        lineno + 1
                    ),
                },
            }
        }
        Ok(spec)
    }

    /// Add one `--axis key=v1,v2` CLI axis. Values split on *top-level*
    /// commas only, so parameterized codec specs sweep cleanly:
    /// `--axis codec=kmeans(c=8,iters=5)|huffman,dense` is two values.
    pub fn push_axis(&mut self, key: &str, values: &str) -> Result<()> {
        let values: Vec<String> = split_top_level(values)
            .into_iter()
            .map(|s| s.to_string())
            .collect();
        if key.is_empty() || values.is_empty() {
            bail!("--axis expects key=v1,v2,..., got '{key}={values:?}'");
        }
        self.axes.push(GridAxis {
            key: key.to_string(),
            values,
        });
        Ok(())
    }

    /// Total job count the grid expands to.
    pub fn size(&self, registry: &StrategyRegistry) -> usize {
        let strategies = if self.strategies.is_empty() {
            registry.names().len()
        } else {
            self.strategies.len()
        };
        strategies
            * self.fleets.len().max(1)
            * self.seeds.len().max(1)
            * self.axes.iter().map(|a| a.values.len()).product::<usize>()
    }

    /// Expand into concrete jobs: every strategy name is canonicalized
    /// against `registry`, every axis value goes through
    /// `FedConfig::set`, every job config is validated, and duplicate
    /// content keys are rejected — all before anything executes.
    pub fn expand(
        &self,
        base: &FedConfig,
        registry: &StrategyRegistry,
    ) -> Result<Vec<SweepJob>> {
        let strategies: Vec<String> = if self.strategies.is_empty() {
            registry.names().iter().map(|s| s.to_string()).collect()
        } else {
            self.strategies.clone()
        };
        // canonicalize (and reject typos) once, up front
        let mut canonical = Vec::with_capacity(strategies.len());
        for name in &strategies {
            canonical.push(registry.build(name, base)?.name().to_string());
        }
        let fleets: Vec<FleetPreset> = if self.fleets.is_empty() {
            vec![base.fleet.preset]
        } else {
            self.fleets.clone()
        };
        let seeds: Vec<u64> = if self.seeds.is_empty() {
            vec![base.seed]
        } else {
            self.seeds.clone()
        };

        let mut jobs: Vec<SweepJob> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for strategy in &canonical {
            for &fleet in &fleets {
                for &seed in &seeds {
                    for combo in cartesian(&self.axes) {
                        let mut cfg = base.clone();
                        cfg.fleet.preset = fleet;
                        cfg.seed = seed;
                        for (k, v) in &combo {
                            cfg.set(k, v).with_context(|| {
                                format!("sweep axis '{k}={v}'")
                            })?;
                        }
                        cfg.validate().with_context(|| {
                            format!("expanded job {strategy} fleet={} seed={seed}", fleet.name())
                        })?;
                        let key = run_key(strategy, &cfg);
                        if !seen.insert(key) {
                            bail!(
                                "sweep grid expands to duplicate jobs \
                                 (e.g. {strategy} seed={seed}: key {key:016x}); \
                                 check for repeated values or a grid axis that \
                                 overrides seed/fleet"
                            );
                        }
                        jobs.push(SweepJob {
                            idx: jobs.len(),
                            strategy: strategy.clone(),
                            cfg,
                            key,
                        });
                    }
                }
            }
        }
        Ok(jobs)
    }
}

/// Split a comma-separated value list at paren depth 0, trimming and
/// dropping empties — so codec stage parameters (`kmeans(c=8,iters=5)`)
/// survive inside one axis value.
fn split_top_level(value: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in value.char_indices() {
        match ch {
            '(' => depth += 1,
            ')' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&value[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&value[start..]);
    out.into_iter()
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect()
}

/// Cartesian product of axis values, deterministic order (first axis
/// slowest). No axes -> one empty combo.
fn cartesian(axes: &[GridAxis]) -> Vec<Vec<(String, String)>> {
    let mut combos: Vec<Vec<(String, String)>> = vec![Vec::new()];
    for axis in axes {
        let mut next = Vec::with_capacity(combos.len() * axis.values.len());
        for combo in &combos {
            for v in &axis.values {
                let mut c = combo.clone();
                c.push((axis.key.clone(), v.clone()));
                next.push(c);
            }
        }
        combos = next;
    }
    combos
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_file_grammar() {
        let spec = SweepSpec::parse(
            "# budget sweep\n\
             strategies = fedavg, fedcompress\n\
             fleets = ideal,mobile # trailing comment\n\
             seeds = 42,43\n\
             grid.c_max = 8,16\n\
             \n\
             grid.topk_keep = 0.1\n",
        )
        .unwrap();
        assert_eq!(spec.strategies, vec!["fedavg", "fedcompress"]);
        assert_eq!(spec.fleets, vec![FleetPreset::Ideal, FleetPreset::Mobile]);
        assert_eq!(spec.seeds, vec![42, 43]);
        assert_eq!(spec.axes.len(), 2);
        assert_eq!(spec.axes[0].values, vec!["8", "16"]);
        assert_eq!(spec.size(&StrategyRegistry::builtin()), 2 * 2 * 2 * 2);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(SweepSpec::parse("strategies fedavg\n").is_err());
        assert!(SweepSpec::parse("seeds = not-a-number\n").is_err());
        assert!(SweepSpec::parse("fleets = marsnet\n").is_err());
        assert!(SweepSpec::parse("frobnicate = 1\n").is_err());
        assert!(SweepSpec::parse("grid. = 1\n").is_err());
        assert!(SweepSpec::parse("seeds =\n").is_err());
    }

    #[test]
    fn expansion_is_deterministic_and_collision_free() {
        let mut spec = SweepSpec {
            strategies: vec!["fedavg".into(), "top-k".into()], // alias on purpose
            seeds: vec![1, 2],
            ..SweepSpec::default()
        };
        spec.push_axis("c_max", "16,32").unwrap();
        let base = FedConfig::quick("cifar10");
        let reg = StrategyRegistry::builtin();
        let jobs = spec.expand(&base, &reg).unwrap();
        assert_eq!(jobs.len(), 2 * 2 * 2);
        assert_eq!(jobs.len(), spec.size(&reg));
        // aliases canonicalize
        assert!(jobs.iter().any(|j| j.strategy == "topk"));
        // keys are all distinct and stable across re-expansion
        let again = spec.expand(&base, &reg).unwrap();
        for (a, b) in jobs.iter().zip(&again) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.idx, b.idx);
        }
        // axis values landed in the configs
        assert!(jobs.iter().any(|j| j.cfg.controller.c_max == 16));
        assert!(jobs.iter().any(|j| j.cfg.controller.c_max == 32));
    }

    #[test]
    fn empty_dimensions_default_sensibly() {
        let base = FedConfig::quick("cifar10");
        let reg = StrategyRegistry::builtin();
        let jobs = SweepSpec::default().expand(&base, &reg).unwrap();
        assert_eq!(jobs.len(), reg.names().len());
        assert!(jobs.iter().all(|j| j.cfg.seed == base.seed));
    }

    #[test]
    fn duplicate_jobs_rejected() {
        let spec = SweepSpec {
            strategies: vec!["fedavg".into()],
            seeds: vec![7, 7],
            ..SweepSpec::default()
        };
        let base = FedConfig::quick("cifar10");
        let err = spec
            .expand(&base, &StrategyRegistry::builtin())
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    /// The headline of the codec API: pipelines sweep as a first-class
    /// axis, orthogonally to strategies and fleets — with parameterized
    /// specs surviving the comma-separated axis grammar.
    #[test]
    fn codec_axis_expands_and_keys_cover_the_spec() {
        let mut spec = SweepSpec {
            strategies: vec!["fedavg".into(), "fedzip".into()],
            ..SweepSpec::default()
        };
        spec.push_axis("codec", "dense,topk(keep=0.2)|kmeans(c=8,iters=5)|huffman")
            .unwrap();
        assert_eq!(
            spec.axes[0].values,
            vec!["dense", "topk(keep=0.2)|kmeans(c=8,iters=5)|huffman"]
        );
        let base = FedConfig::quick("cifar10");
        let reg = StrategyRegistry::builtin();
        let jobs = spec.expand(&base, &reg).unwrap();
        assert_eq!(jobs.len(), 2 * 2);
        // the codec landed in the configs and separates content keys
        let keys: std::collections::BTreeSet<u64> = jobs.iter().map(|j| j.key).collect();
        assert_eq!(keys.len(), 4);
        assert!(jobs.iter().any(|j| j.cfg.codec == "dense"));
        assert!(jobs
            .iter()
            .any(|j| j.cfg.codec == "topk(keep=0.2)|kmeans(c=8,iters=5)|huffman"));
        // a typo'd codec axis fails at expansion with the suggestion
        // (full anyhow chain: the context names the job, the root
        // cause carries the registry's suggestion)
        let mut bad = SweepSpec::default();
        bad.push_axis("codec", "topk|hufman").unwrap();
        let err = format!("{:#}", bad.expand(&base, &reg).unwrap_err());
        assert!(err.contains("did you mean 'huffman'"), "{err}");
    }

    #[test]
    fn spec_file_codec_grid_respects_parens() {
        let spec = SweepSpec::parse(
            "strategies = fedavg\n\
             grid.codec = dense, kmeans(c=8,iters=5)|huffman\n",
        )
        .unwrap();
        assert_eq!(spec.axes.len(), 1);
        assert_eq!(
            spec.axes[0].values,
            vec!["dense", "kmeans(c=8,iters=5)|huffman"]
        );
    }

    /// The edge tier sweeps like any other fleet knob: `edge_of` routes
    /// through `FedConfig::set`, lands in the fleet config, and — being
    /// part of the wire config image — separates the content keys, so a
    /// flat run and its edge-tiered siblings never collide in the store.
    #[test]
    fn edge_of_axis_expands_with_distinct_keys() {
        let mut spec = SweepSpec {
            strategies: vec!["fedavg".into()],
            ..SweepSpec::default()
        };
        spec.push_axis("edge_of", "0,8,64").unwrap();
        let base = FedConfig::quick("cifar10");
        let jobs = spec.expand(&base, &StrategyRegistry::builtin()).unwrap();
        assert_eq!(jobs.len(), 3);
        let keys: std::collections::BTreeSet<u64> = jobs.iter().map(|j| j.key).collect();
        assert_eq!(keys.len(), 3, "edge_of must be content-addressed");
        for (job, want) in jobs.iter().zip([0usize, 8, 64]) {
            assert_eq!(job.cfg.fleet.edge_of, want);
            assert_eq!(job.cfg.fleet.is_ideal(), want == 0);
        }
    }

    #[test]
    fn bad_axis_key_fails_at_expansion() {
        let mut spec = SweepSpec::default();
        spec.push_axis("nonsense", "1,2").unwrap();
        let base = FedConfig::quick("cifar10");
        assert!(spec.expand(&base, &StrategyRegistry::builtin()).is_err());
    }
}
