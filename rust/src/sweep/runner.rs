//! Job runners: how one [`SweepJob`](super::SweepJob) becomes a
//! [`RunRecord`], plus the store-cache helper the experiment drivers
//! (`exp::table1`, `exp::fleet`) share with the orchestrator.
//!
//! Two runners ship:
//!
//! * [`EngineRunner`] — the real thing. Loads a *private* engine
//!   inside the calling worker thread (the PJRT client is Rc-based and
//!   thread-confined, so engine-per-worker isolation is mandatory, not
//!   an optimization), materializes the federated data for the job's
//!   config, and drives `run_federated`.
//! * [`SmokeRunner`] — a deterministic synthetic model of a run (no
//!   PJRT, no artifacts) that exercises every other layer for real:
//!   grid expansion, content keys, parallel execution, record
//!   serialization, the cache probe, `runs diff`, and `export-bench`.
//!   Same key -> bit-identical record (modulo the environment fields
//!   `diff_records` excludes), so cache and drift guarantees are
//!   CI-testable on machines with no accelerator.

use std::path::PathBuf;

use anyhow::{ensure, Context, Result};

use crate::clustering::CentroidState;
use crate::compression::accounting::{CommLedger, Direction};
use crate::compression::codec::dense_bytes;
use crate::config::FedConfig;
use crate::coordinator::events::{Event, EventLog};
use crate::coordinator::metrics::{RoundMetrics, RunResult};
use crate::coordinator::server::{build_data, run_federated_with_data, FederatedData};
use crate::net::proto::{config_image, framed_down, framed_up};
use crate::runtime::Engine;
use crate::sim::FleetPreset;
use crate::store::{run_key, RunRecord, RunStore};
use crate::util::rng::Rng;

use super::SweepJob;

/// Executes one job to a completed record. Implementations must be
/// deterministic in the job's content key: running the same job twice
/// yields records `diff_records` calls identical.
pub trait JobRunner: Sync {
    fn run(&self, job: &SweepJob) -> Result<RunRecord>;
    /// Short name for progress output (`engine` / `smoke`).
    fn kind(&self) -> &'static str;
}

/// The real runner: engine-per-worker isolation over the AOT
/// artifacts.
pub struct EngineRunner {
    pub artifacts_dir: PathBuf,
}

impl JobRunner for EngineRunner {
    fn run(&self, job: &SweepJob) -> Result<RunRecord> {
        // a fresh engine per job, owned entirely by this worker thread
        let engine = Engine::load(&self.artifacts_dir)
            .with_context(|| format!("job {}", job.label()))?;
        let data = build_data(&engine, &job.cfg)?;
        let result = run_federated_with_data(&engine, &job.cfg, &job.strategy, &data)?;
        Ok(RunRecord::from_result(&job.cfg, &result))
    }

    fn kind(&self) -> &'static str {
        "engine"
    }
}

/// The synthetic runner: a cheap, seed-deterministic stand-in run.
/// Accuracy follows a saturating curve, traffic follows the warmup ->
/// compressed byte schedule, and the simulated clock scales with the
/// fleet preset — plausible shapes, zero accelerator.
pub struct SmokeRunner;

impl JobRunner for SmokeRunner {
    fn run(&self, job: &SweepJob) -> Result<RunRecord> {
        let cfg = &job.cfg;
        // fedlint:allow(rng-discipline) -- smoke-runner root stream, seeded by the job's content key
        let mut rng = Rng::new(job.key);
        let p = 2_048usize;
        let dense = dense_bytes(p);
        let compresses = job.strategy != "fedavg";
        let m = ((cfg.clients as f64 * cfg.participation).ceil() as usize).clamp(1, cfg.clients);
        let fleet_slowdown = match cfg.fleet.preset {
            FleetPreset::Ideal => 1.0,
            FleetPreset::Mobile => 3.0,
            FleetPreset::Hostile => 8.0,
        };

        let mut ledger = CommLedger::new();
        let mut events = EventLog::new();
        let mut rounds = Vec::with_capacity(cfg.rounds);
        let mut acc = 0.08 + 0.04 * rng.f64();
        for round in 0..cfg.rounds {
            let compressing = compresses && round >= cfg.warmup_rounds;
            let down = if compresses && round > cfg.warmup_rounds {
                dense / 5
            } else {
                dense
            };
            let up = if compressing { dense / 8 } else { dense };
            events.push(Event::RoundStart {
                round,
                clusters: cfg.controller.c_min,
            });
            for _ in 0..m {
                ledger.record(round, Direction::Down, down, framed_down(down));
                ledger.record(round, Direction::Up, up, framed_up(up));
            }
            acc += (0.92 - acc) * (0.15 + 0.10 * rng.f64());
            let test_loss = (1.0 - acc).max(0.05) * 2.3;
            events.push(Event::Evaluated {
                round,
                accuracy: acc,
                loss: test_loss,
            });
            rounds.push(RoundMetrics {
                round,
                accuracy: acc,
                test_loss,
                score: 2.0 + acc + 0.1 * rng.f64(),
                client_mean_ce: 1.5 * (1.0 - acc),
                clusters: cfg.controller.c_min,
                up_bytes: up * m,
                down_bytes: down * m,
                // wall-clock is an environment fact; the synthetic run
                // did no real work, and diff_records ignores it anyway
                wall_ms: 0.0,
                round_sim_ms: fleet_slowdown * (50.0 + ((down + up) * m) as f64 / 1e4),
                stragglers: 0,
                dropped: 0,
            });
        }
        let final_model_bytes = if compresses { dense / 6 } else { dense };
        let result = RunResult {
            strategy: leak_free_name(&job.strategy),
            dataset: cfg.dataset.clone(),
            rounds,
            final_theta: Vec::new(),
            final_accuracy: (acc + 0.01).min(0.95),
            final_model_bytes,
            dense_model_bytes: dense,
            ledger,
            events,
            final_centroids: CentroidState {
                mu: vec![0.0; cfg.controller.c_min],
                mask: vec![1.0; cfg.controller.c_min],
                c_max: cfg.controller.c_min,
                active: cfg.controller.c_min,
            },
        };
        Ok(RunRecord::from_result(cfg, &result))
    }

    fn kind(&self) -> &'static str {
        "smoke"
    }
}

/// `RunResult.strategy` is `&'static str` (registry names). The smoke
/// runner resolves job names to the registry's static name instead of
/// leaking.
fn leak_free_name(name: &str) -> &'static str {
    use crate::baselines::registry::StrategyRegistry;
    let reg = StrategyRegistry::builtin();
    for n in reg.names() {
        if n == name {
            return n;
        }
    }
    // expand() canonicalizes against the same registry, so this is
    // unreachable for orchestrated jobs; keep a stable fallback for
    // hand-built ones
    "unknown"
}

/// Hit/miss tally of a store-backed driver (`table1`, `fleet`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
}

impl CacheStats {
    pub fn note(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
    }

    pub fn total(&self) -> usize {
        self.hits + self.misses
    }
}

/// Run-or-load with content-addressed caching: if `store` already
/// holds a record for `(strategy, cfg)`, return it (verifying that
/// the stored config image actually matches — a 64-bit key collision
/// must fail loudly, not silently serve the wrong experiment);
/// otherwise execute on `engine` and append. Returns the record and
/// whether it was a cache hit.
pub fn run_or_cached(
    engine: &Engine,
    cfg: &FedConfig,
    strategy: &str,
    data: &FederatedData,
    store: Option<&mut RunStore>,
) -> Result<(RunRecord, bool)> {
    let key = run_key(strategy, cfg);
    match store {
        Some(store) => {
            if let Some(rec) = store.get(key)? {
                verify_cached(&rec, strategy, cfg)?;
                return Ok((rec, true));
            }
            let result = run_federated_with_data(engine, cfg, strategy, data)?;
            // keys are computed from *canonical* names; an alias here
            // would probe one key and store under another, silently
            // defeating the cache — refuse instead
            ensure!(
                result.strategy == strategy,
                "run_or_cached needs the canonical strategy name \
                 '{}', not alias '{strategy}'",
                result.strategy,
            );
            let rec = RunRecord::from_result(cfg, &result);
            store.append(&rec)?;
            Ok((rec, false))
        }
        None => {
            let result = run_federated_with_data(engine, cfg, strategy, data)?;
            Ok((RunRecord::from_result(cfg, &result), false))
        }
    }
}

/// A cached record must describe the exact experiment asked for.
pub fn verify_cached(rec: &RunRecord, strategy: &str, cfg: &FedConfig) -> Result<()> {
    ensure!(
        rec.strategy == strategy && rec.cfg_image == config_image(cfg),
        "record key 0x{:016x} collides: stored run is {} but the requested \
         experiment is {} with a different config image",
        rec.key,
        rec.strategy,
        strategy,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::registry::StrategyRegistry;
    use crate::store::diff_records;
    use crate::sweep::SweepSpec;

    fn smoke_job(strategy: &str, seed: u64) -> SweepJob {
        let mut cfg = FedConfig::quick("cifar10");
        cfg.seed = seed;
        SweepJob {
            idx: 0,
            strategy: strategy.to_string(),
            cfg: cfg.clone(),
            key: run_key(strategy, &cfg),
        }
    }

    #[test]
    fn smoke_runner_is_deterministic_per_key() {
        let a = SmokeRunner.run(&smoke_job("fedcompress", 1)).unwrap();
        let b = SmokeRunner.run(&smoke_job("fedcompress", 1)).unwrap();
        assert!(diff_records(&a, &b).is_identical());
        let c = SmokeRunner.run(&smoke_job("fedcompress", 2)).unwrap();
        assert!(!diff_records(&a, &c).is_identical());
        // record metadata is coherent
        assert_eq!(a.key, smoke_job("fedcompress", 1).key);
        assert_eq!(a.rounds.len(), a.cfg().unwrap().rounds);
        assert!(a.total_bytes() > 0);
        assert!(a.mcr() > 1.0, "compressing strategy must shrink the model");
        let avg = SmokeRunner.run(&smoke_job("fedavg", 1)).unwrap();
        assert!((avg.mcr() - 1.0).abs() < 1e-12);
        // and the record round-trips its own serialization
        let body = a.to_body_bytes();
        let back = RunRecord::from_body_bytes(&body).unwrap();
        assert!(diff_records(&a, &back).is_identical());
    }

    #[test]
    fn smoke_records_resolve_registry_names() {
        let spec = SweepSpec::default();
        let jobs = spec
            .expand(&FedConfig::quick("cifar10"), &StrategyRegistry::builtin())
            .unwrap();
        for job in &jobs {
            let rec = SmokeRunner.run(job).unwrap();
            assert_eq!(rec.strategy, job.strategy);
            assert_eq!(rec.key, job.key);
        }
    }

    #[test]
    fn verify_cached_rejects_collisions() {
        let job = smoke_job("fedcompress", 1);
        let rec = SmokeRunner.run(&job).unwrap();
        verify_cached(&rec, "fedcompress", &job.cfg).unwrap();
        let mut other = job.cfg.clone();
        other.seed = 99;
        assert!(verify_cached(&rec, "fedcompress", &other).is_err());
        assert!(verify_cached(&rec, "fedavg", &job.cfg).is_err());
    }
}
