//! Hand-rolled CLI layer (clap is not in the vendored crate set).
//!
//! Grammar: `fedcompress <command> [--flag value]... [--switch]...`
//! Flags are collected into an ordered map; commands validate their own
//! flag sets so typos fail loudly.

pub mod args;

pub use args::{Args, ParsedCommand};

pub const USAGE: &str = "\
fedcompress — FedCompress reproduction (rust + JAX + Pallas via PJRT)

USAGE:
    fedcompress <COMMAND> [OPTIONS]

COMMANDS:
    train       run one federated training experiment
    table1      reproduce Table 1 (dAcc/CCR/MCR across strategies)
    table2      reproduce Table 2 (edge inference speedups)
    figure2     reproduce Figure 2 (score vs accuracy correlation)
    ablate-c    ablation: dynamic-C controller vs fixed C
    inspect     print manifest / model / artifact information
    help        show this message

COMMON OPTIONS:
    --dataset <name>        cifar10|cifar100|pathmnist|speechcommands|voxforge
    --strategy <name>       a registered strategy (fedavg|fedzip|
                            fedcompress-noscs|fedcompress|topk|...), or
                            'list' to print the registry
    --preset <paper|quick>  parameter preset (default: quick)
    --config <file.json>    JSON overrides on top of the preset
    --set key=value         single override (repeatable)
    --artifacts <dir>       artifacts directory (default: ./artifacts)
    --out <file>            write CSV/JSON output where applicable
    --datasets a,b,c        subset for table1
    --clusters <n>          deployed cluster count for table2

EXAMPLES:
    fedcompress train --dataset cifar10 --strategy fedcompress --preset quick
    fedcompress train --strategy list
    fedcompress table1 --preset quick --datasets cifar10,voxforge
    fedcompress figure2 --dataset speechcommands --out fig2.csv
";
