//! Hand-rolled CLI layer (clap is not in the vendored crate set).
//!
//! Grammar: `fedcompress <command> [--flag value]... [--switch]...`
//! Flags are collected into an ordered map; commands validate their own
//! flag sets so typos fail loudly.

pub mod args;

pub use args::{Args, ParsedCommand};

pub const USAGE: &str = "\
fedcompress — FedCompress reproduction (rust + JAX + Pallas via PJRT)

USAGE:
    fedcompress <COMMAND> [OPTIONS]

COMMANDS:
    train       run one federated training experiment (in-process)
    serve       run the coordinator over TCP: wait for N workers, then
                train — same seed, same metrics as an in-process run
    worker      run one worker process against a coordinator; strategy,
                config and client ids arrive at handshake
    table1      reproduce Table 1 (dAcc/CCR/MCR across strategies)
    table2      reproduce Table 2 (edge inference speedups)
    figure2     reproduce Figure 2 (score vs accuracy correlation)
    fleet       strategy x fleet scenario table: rounds- and simulated
                time-to-accuracy under ideal/mobile/hostile fleets
    sweep       expand a strategy x fleet x seed x knob grid, run the
                jobs in parallel, and persist every run in a run store;
                jobs whose config hash already has a record are skipped
    runs        query the run store:
                  runs list      one line per stored run
                  runs show      per-round metrics of one record
                  runs tail      render a run's event stream as a live
                                 view (--follow refreshes; works on the
                                 teed stream or replayed from the
                                 record; teed live streams add a
                                 per-phase round-timing column group)
                  runs diff      bit-exact drift check of two records
                                 (or two whole stores via --other)
                  runs compare   grouped comparison table
                  runs export-bench  write BENCH_sweep.json
    bench       perf trajectory:
                  bench run      run the in-process micro-benchmark
                                 suites headlessly and write one
                                 BENCH_<area>.json per area
                  bench diff     compare two BENCH_*.json files row by
                                 row; exit 3 when any median regressed
                                 past the threshold (CI gates on this)
    lint        run fedlint, the self-hosted determinism & wire-safety
                linter, over the crate sources (CI runs this as a gate)
    ablate-c    ablation: dynamic-C controller vs fixed C
    inspect     print manifest / model / artifact information
    help        show this message

COMMON OPTIONS:
    --dataset <name>        cifar10|cifar100|pathmnist|speechcommands|voxforge
    --strategy <name>       a registered strategy (fedavg|fedzip|
                            fedcompress-noscs|fedcompress|topk|...), or
                            'list' to print the registry
    --codec <spec>          codec pipeline overriding the strategy's
                            compressed-upload path: stage names joined
                            by '|' with optional (key=value,...) params,
                            e.g. 'topk(keep=0.2)|kmeans(c=8)|huffman';
                            'list' prints the codec registry. Unset =
                            each strategy's declared default
    --preset <paper|quick>  parameter preset (default: quick)
    --config <file.json>    JSON overrides on top of the preset
    --set key=value         single override (repeatable)
    --artifacts <dir>       artifacts directory (default: ./artifacts)
    --out <file>            write CSV/JSON output where applicable
    --datasets a,b,c        subset for table1
    --clusters <n>          deployed cluster count for table2

NETWORKED TRANSPORT (serve, worker):
    --bind <addr>           serve: listen address (default 127.0.0.1:7878)
    --workers <n>           serve: worker connections to wait for (default 1)
    --timeout-s <s>         serve: per-connection inactivity timeout in
                            real seconds; a silent worker's clients are
                            cut like deadline stragglers (0 = wait
                            forever)
    --handshake-timeout-s <s>  serve: max real seconds to wait for a
                            peer's Hello before dropping the connection
                            (default 30, 0 = wait forever); sugar over
                            --set handshake_timeout_s=<s>
    --connect <addr>        worker: coordinator address
    --edge-of <n>           worker: act as an edge aggregator for up to
                            <n> clients — fold the sub-fleet locally
                            and ship one pre-aggregated upload per
                            round (default 0 = leaf worker)

CHECKPOINTING (train, serve):
    --checkpoint <file>     write the final model + codebook, stamped
                            with the transport kind and fleet preset
    --resume <file>         continue from a checkpoint; a mismatched
                            transport/fleet logs Event::ResumeMismatch

FLEET SIMULATION (train, serve, fleet, figure2, ablate-c):
    --fleet <name>          fleet preset: ideal|mobile|hostile
                            (default ideal; `fleet` runs all three)
    --dropout <p>           extra per-round client dropout prob in [0,1)
    --deadline-s <s>        simulated round reporting deadline, seconds
                            (0 = none; late clients are cut)

RUN STORE (sweep, runs, table1, fleet, table2):
    --store <dir>           run store directory. sweep/runs/table2
                            default to ./runs; table1 and fleet only
                            touch a store when the flag is given.
                            train/serve: also tee a live event stream
                            to <store>/events/<key>.jsonl and persist
                            the finished run (tail it with runs tail)
    --strategies a,b        sweep: strategy axis (default: all registered)
    --fleets a,b            sweep: fleet preset axis ('all' = all three)
    --seeds 1,2,3           sweep: seed axis
    --axis key=v1,v2        sweep: extra config-knob axis (repeatable,
                            any --set key: c_max, topk_keep, rounds,
                            codec, ...; values split on top-level commas
                            only, so codec=kmeans(c=8,iters=5),dense is
                            a two-value axis)
    --spec <file>           sweep: grid spec file (key = value lines:
                            strategies/fleets/seeds/grid.<key>)
    --jobs <n>              sweep: parallel worker threads (default auto)
    --smoke                 sweep: deterministic synthetic runner — no
                            artifacts needed; exercises grid, store,
                            cache, and export end to end
    --force                 sweep: re-run jobs even when cached
    --watch                 sweep: live full-screen progress table
                            instead of per-job lines
    --key <hex>             runs show/tail: record key (unique prefix
                            ok; tail also takes it as a positional)
    --follow                runs tail: keep refreshing the view from
                            the stream file until interrupted
    --a / --b <hex>         runs diff: the two records to compare
    --other <dir>           runs diff: compare all shared keys against
                            a second store
    --csv                   runs list/show/compare: CSV to stdout/--out
    --out <file>            output path (export-bench default:
                            BENCH_sweep.json)
    --from-run <hex>        table2: read the deployed cluster count from
                            a stored run instead of --clusters

BENCH (bench run | bench diff <old> <new>):
    --area <name>           bench run: codec|net|store|aggregate|runtime,
                            'all' (default) for every suite, or 'rounds'
                            to roll the store's teed phase_timing events
                            into BENCH_rounds.json (needs --store)
    --quick                 bench run: shorter sampling windows — same
                            row names as a full run, so quick baselines
                            diff against quick runs (CI uses this)
    --out-dir <dir>         bench run: where BENCH_<area>.json files go
                            (default: current directory)
    --store <dir>           bench run --area rounds: run store whose
                            events/ directory is rolled up
    --threshold-pct <n>     bench diff: max tolerated median slowdown
                            per row, percent (default 25)
    --json                  bench diff: machine-readable report

LINT (lint [paths...]):
    [paths...]              limit the scan to these files/directories
                            (relative to the crate root)
    --rule <name>           run a single rule (det-map-iter,
                            no-panic-decode, no-wallclock-state,
                            rng-discipline, float-order)
    --json                  machine-readable report on stdout
    --out <file>            also write the JSON report to a file
    --root <dir>            crate root to scan (default: auto-detect)
    --config <file>         rule config (default: <root>/fedlint.toml,
                            falling back to the built-in config)

EXAMPLES:
    fedcompress train --dataset cifar10 --strategy fedcompress --preset quick
    fedcompress train --strategy list
    fedcompress train --codec list
    fedcompress train --strategy fedavg --codec 'topk(keep=0.1)|kmeans(c=8)|huffman'
    fedcompress sweep --smoke --axis 'codec=dense,topk|kmeans|huffman'
    fedcompress serve --bind 127.0.0.1:7878 --workers 2 --strategy fedcompress
    fedcompress worker --connect 127.0.0.1:7878
    fedcompress train --fleet mobile --dropout 0.1 --deadline-s 60
    fedcompress table1 --preset quick --datasets cifar10,voxforge
    fedcompress fleet --dataset cifar10 --preset quick --dropout 0.1
    fedcompress figure2 --dataset speechcommands --out fig2.csv
    fedcompress sweep --preset quick --seeds 41,42 --fleets ideal,mobile
    fedcompress sweep --spec grids/budget.sweep --store runs --jobs 8
    fedcompress runs list --store runs
    fedcompress runs show --key 3fa9 --csv --out run.csv
    fedcompress train --store runs           # tee a live event stream
    fedcompress runs tail 3fa9 --store runs --follow
    fedcompress sweep --smoke --watch        # live progress table
    fedcompress runs diff --a 3fa9 --b 81c2
    fedcompress runs export-bench --store runs --out BENCH_sweep.json
    fedcompress table1 --store runs          # cache-hits prior runs
    fedcompress bench run --area codec --quick
    fedcompress bench diff BENCH_codec.json fresh/BENCH_codec.json --threshold-pct 30
    fedcompress lint                         # whole crate, text report
    fedcompress lint --json --out fedlint.json
    fedcompress lint src/net --rule no-panic-decode
";
