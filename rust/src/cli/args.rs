//! Argument parsing: positional command + `--flag value` pairs +
//! repeatable `--set k=v` / `--axis k=v1,v2`, plus one subcommand
//! positional for command families (`runs list`, `runs diff`, ...).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    /// subcommand positional (only the `runs` family takes one)
    pub sub: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub sets: Vec<(String, String)>,
    /// repeatable `--axis key=v1,v2` sweep-grid axes
    pub axes: Vec<(String, String)>,
    /// free positional arguments (`lint` paths, `runs tail` keys)
    pub positionals: Vec<String>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParsedCommand {
    Train,
    Serve,
    Worker,
    Table1,
    Table2,
    Figure2,
    Fleet,
    Sweep,
    Runs,
    Bench,
    Lint,
    AblateC,
    Inspect,
    Help,
}

/// Flags that take no value.
const SWITCHES: [&str; 8] = [
    "verbose", "csv", "smoke", "force", "json", "watch", "follow", "quick",
];

/// Commands that take a subcommand positional (`runs list`, ...).
const SUBCOMMAND_FAMILIES: [&str; 2] = ["runs", "bench"];

/// Commands that accept free positional arguments (`lint src/net`,
/// `runs tail <key>`, `bench diff <old> <new>`).
const POSITIONAL_COMMANDS: [&str; 3] = ["lint", "runs", "bench"];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        if argv.is_empty() {
            args.command = "help".into();
            return Ok(args);
        }
        args.command = argv[0].clone();
        let mut i = 1;
        if SUBCOMMAND_FAMILIES.contains(&args.command.as_str()) {
            if let Some(sub) = argv.get(1).filter(|a| !a.starts_with("--")) {
                args.sub = Some(sub.clone());
                i = 2;
            }
        }
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                if POSITIONAL_COMMANDS.contains(&args.command.as_str()) {
                    args.positionals.push(a.clone());
                    i += 1;
                    continue;
                }
                bail!("unexpected positional argument '{a}'");
            };
            if SWITCHES.contains(&name) {
                args.flags.insert(name.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            let Some(value) = argv.get(i + 1) else {
                bail!("flag '--{name}' needs a value");
            };
            if name == "set" || name == "axis" {
                let Some((k, v)) = value.split_once('=') else {
                    bail!("--{name} expects key=value, got '{value}'");
                };
                if name == "set" {
                    args.sets.push((k.to_string(), v.to_string()));
                } else {
                    args.axes.push((k.to_string(), v.to_string()));
                }
            } else {
                args.flags.insert(name.to_string(), value.clone());
            }
            i += 2;
        }
        Ok(args)
    }

    pub fn command(&self) -> Result<ParsedCommand> {
        Ok(match self.command.as_str() {
            "train" => ParsedCommand::Train,
            "serve" => ParsedCommand::Serve,
            "worker" => ParsedCommand::Worker,
            "table1" => ParsedCommand::Table1,
            "table2" => ParsedCommand::Table2,
            "figure2" => ParsedCommand::Figure2,
            "fleet" => ParsedCommand::Fleet,
            "sweep" => ParsedCommand::Sweep,
            "runs" => ParsedCommand::Runs,
            "bench" => ParsedCommand::Bench,
            "lint" => ParsedCommand::Lint,
            "ablate-c" => ParsedCommand::AblateC,
            "inspect" => ParsedCommand::Inspect,
            "help" | "--help" | "-h" => ParsedCommand::Help,
            other => bail!("unknown command '{other}' (try 'help')"),
        })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// Reject flags outside a command's allowed set (typo guard).
    pub fn restrict(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("flag '--{k}' not valid for '{}'", self.command);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = Args::parse(&v(&[
            "train", "--dataset", "cifar10", "--preset", "quick",
        ]))
        .unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Train);
        assert_eq!(a.flag("dataset"), Some("cifar10"));
        assert_eq!(a.flag_or("missing", "x"), "x");
    }

    #[test]
    fn parses_repeatable_sets() {
        let a = Args::parse(&v(&[
            "train", "--set", "rounds=3", "--set", "beta=0.5",
        ]))
        .unwrap();
        assert_eq!(a.sets.len(), 2);
        assert_eq!(a.sets[0], ("rounds".into(), "3".into()));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(&v(&["train", "stray"])).is_err());
        assert!(Args::parse(&v(&["train", "--dataset"])).is_err());
        assert!(Args::parse(&v(&["train", "--set", "noequals"])).is_err());
        let a = Args::parse(&v(&["frobnicate"])).unwrap();
        assert!(a.command().is_err());
    }

    #[test]
    fn fleet_command_and_flags_parse() {
        let a = Args::parse(&v(&[
            "fleet", "--fleet", "mobile", "--dropout", "0.1", "--deadline-s", "30",
        ]))
        .unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Fleet);
        assert_eq!(a.flag("fleet"), Some("mobile"));
        assert_eq!(a.flag("dropout"), Some("0.1"));
        assert_eq!(a.flag("deadline-s"), Some("30"));
    }

    #[test]
    fn restrict_catches_typos() {
        let a = Args::parse(&v(&["table2", "--clusterz", "16"])).unwrap();
        assert!(a.restrict(&["dataset", "clusters"]).is_err());
        let b = Args::parse(&v(&["table2", "--clusters", "16"])).unwrap();
        assert!(b.restrict(&["dataset", "clusters"]).is_ok());
    }

    #[test]
    fn serve_and_worker_commands_parse() {
        let a = Args::parse(&v(&[
            "serve", "--bind", "0.0.0.0:7878", "--workers", "4", "--timeout-s", "30",
            "--handshake-timeout-s", "5",
        ]))
        .unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Serve);
        assert_eq!(a.flag("bind"), Some("0.0.0.0:7878"));
        assert_eq!(a.flag("workers"), Some("4"));
        assert_eq!(a.flag("timeout-s"), Some("30"));
        assert_eq!(a.flag("handshake-timeout-s"), Some("5"));
        let b = Args::parse(&v(&[
            "worker", "--connect", "10.0.0.1:7878", "--edge-of", "8",
        ]))
        .unwrap();
        assert_eq!(b.command().unwrap(), ParsedCommand::Worker);
        assert_eq!(b.flag("connect"), Some("10.0.0.1:7878"));
        assert_eq!(b.flag("edge-of"), Some("8"));
    }

    #[test]
    fn empty_argv_is_help() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Help);
    }

    #[test]
    fn runs_family_takes_a_subcommand() {
        let a = Args::parse(&v(&["runs", "list", "--store", "out"])).unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Runs);
        assert_eq!(a.sub.as_deref(), Some("list"));
        assert_eq!(a.flag("store"), Some("out"));
        // no subcommand is fine (the command handler decides)
        let b = Args::parse(&v(&["runs", "--store", "out"])).unwrap();
        assert_eq!(b.sub, None);
        // other commands still reject positionals
        assert!(Args::parse(&v(&["train", "list"])).is_err());
    }

    #[test]
    fn lint_command_takes_path_positionals_and_switches() {
        let a = Args::parse(&v(&[
            "lint", "src/net", "src/codec/stages.rs", "--json", "--rule", "det-map-iter",
        ]))
        .unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Lint);
        assert_eq!(a.positionals, vec!["src/net", "src/codec/stages.rs"]);
        assert_eq!(a.flag("json"), Some("true"));
        assert_eq!(a.flag("rule"), Some("det-map-iter"));
        // positionals stay rejected everywhere else
        assert!(Args::parse(&v(&["train", "src/net"])).is_err());
    }

    #[test]
    fn runs_tail_takes_key_positional_and_follow_switch() {
        let a = Args::parse(&v(&[
            "runs", "tail", "a1b2c3d4e5f60718", "--store", "out", "--follow",
        ]))
        .unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Runs);
        assert_eq!(a.sub.as_deref(), Some("tail"));
        assert_eq!(a.positionals, vec!["a1b2c3d4e5f60718"]);
        assert_eq!(a.flag("store"), Some("out"));
        assert_eq!(a.flag("follow"), Some("true"));
        // --watch is a sweep switch, not a valued flag
        let b = Args::parse(&v(&["sweep", "--watch", "--smoke"])).unwrap();
        assert_eq!(b.flag("watch"), Some("true"));
        assert_eq!(b.flag("smoke"), Some("true"));
    }

    #[test]
    fn bench_family_parses_run_and_diff_forms() {
        let a = Args::parse(&v(&[
            "bench", "run", "--area", "codec", "--quick", "--out-dir", ".",
        ]))
        .unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Bench);
        assert_eq!(a.sub.as_deref(), Some("run"));
        assert_eq!(a.flag("area"), Some("codec"));
        assert_eq!(a.flag("quick"), Some("true"));
        let b = Args::parse(&v(&[
            "bench", "diff", "BENCH_codec.json", "fresh/BENCH_codec.json",
            "--threshold-pct", "30", "--json",
        ]))
        .unwrap();
        assert_eq!(b.sub.as_deref(), Some("diff"));
        assert_eq!(
            b.positionals,
            vec!["BENCH_codec.json", "fresh/BENCH_codec.json"]
        );
        assert_eq!(b.flag("threshold-pct"), Some("30"));
        assert_eq!(b.flag("json"), Some("true"));
    }

    #[test]
    fn sweep_flags_and_axes_parse() {
        let a = Args::parse(&v(&[
            "sweep", "--strategies", "fedavg,topk", "--seeds", "1,2", "--axis",
            "c_max=8,16", "--axis", "topk_keep=0.1,0.2", "--smoke", "--force",
        ]))
        .unwrap();
        assert_eq!(a.command().unwrap(), ParsedCommand::Sweep);
        assert_eq!(a.axes.len(), 2);
        assert_eq!(a.axes[0], ("c_max".into(), "8,16".into()));
        assert_eq!(a.flag("smoke"), Some("true"));
        assert_eq!(a.flag("force"), Some("true"));
        assert!(Args::parse(&v(&["sweep", "--axis", "noequals"])).is_err());
    }
}
