//! FedZip (Malekijoo 2021) as a strategy plugin: upstream magnitude
//! prune -> per-upload k-means (fixed cluster count, 15 in the paper)
//! -> Huffman; downstream stays dense (FedZip only optimizes the
//! client->server direction). Clients train plain CE.
//!
//! The upload path is *declared*, not hand-rolled: literally the
//! `topk(keep)|kmeans(c,iters=25)|huffman` pipeline built from codec
//! registry parts (byte-identical to the historical encoder — same
//! prune, same k-means fit on the same RNG stream, same adaptive
//! entropy coding). `--codec <spec>` swaps in any other pipeline.

use anyhow::Result;

use super::wire::{upload_pipeline, WireBlob};
use crate::codec::{stream, CodecInput, Pipeline};
use crate::config::FedConfig;
use crate::coordinator::strategy::{
    FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::util::rng::Rng;

/// FedZip's declared upload pipeline for a config.
pub fn default_spec(cfg: &FedConfig) -> String {
    format!(
        "topk(keep={})|kmeans(c={},iters=25)|huffman",
        cfg.fedzip_keep, cfg.fedzip_clusters
    )
}

pub struct FedZip {
    upload: Pipeline,
}

impl FedZip {
    pub fn new(cfg: &FedConfig) -> Result<FedZip> {
        Ok(FedZip {
            upload: upload_pipeline(cfg, &default_spec(cfg))?,
        })
    }
}

impl FedStrategy for FedZip {
    fn name(&self) -> &'static str {
        "fedzip"
    }

    fn encode_download(&self, _ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        Ok(WireBlob::dense(&model.theta))
    }

    fn encode_upload(
        &self,
        _ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        rng: &mut Rng,
    ) -> Result<WireBlob> {
        WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: input.theta,
                centroids: Some(input.centroids),
                stream: stream::upload(input.client),
            },
            rng,
        )
    }

    fn finalize(&self, env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        let mut rng = env.base.fork(9_999);
        let blob = WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: &model.theta,
                centroids: Some(&model.centroids),
                stream: stream::FINAL,
            },
            &mut rng,
        )?;
        Ok(FinalModel {
            theta: blob.theta,
            wire_bytes: blob.bytes,
        })
    }
}
