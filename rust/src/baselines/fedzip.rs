//! FedZip (Malekijoo 2021) as a strategy plugin: upstream magnitude
//! prune -> per-upload k-means (fixed cluster count, 15 in the paper)
//! -> Huffman; downstream stays dense (FedZip only optimizes the
//! client->server direction). Clients train plain CE.

use anyhow::Result;

use super::wire::{kmeans_blob, WireBlob};
use crate::coordinator::strategy::{
    FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::util::rng::Rng;

pub struct FedZip;

impl FedStrategy for FedZip {
    fn name(&self) -> &'static str {
        "fedzip"
    }

    fn encode_download(&self, _ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        Ok(WireBlob::dense(&model.theta))
    }

    fn encode_upload(
        &self,
        ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        rng: &mut Rng,
    ) -> Result<WireBlob> {
        kmeans_blob(
            input.theta,
            ctx.cfg.fedzip_clusters,
            ctx.cfg.fedzip_keep,
            rng,
        )
    }

    fn finalize(&self, env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        let mut rng = env.base.fork(9_999);
        let blob = kmeans_blob(
            &model.theta,
            env.cfg.fedzip_clusters,
            env.cfg.fedzip_keep,
            &mut rng,
        )?;
        Ok(FinalModel {
            theta: blob.theta,
            wire_bytes: blob.bytes,
        })
    }
}
