//! Strategy plugins and their wire policies.
//!
//! Each baseline is a [`crate::coordinator::strategy::FedStrategy`]
//! implementation resolved by name through [`registry::StrategyRegistry`];
//! the round loop (`coordinator::server`) never branches on which one
//! is running — the paper's compatibility claim (the aggregation rule
//! and round loop stay identical) is now a structural property.
//!
//! Wire policy is *declared*, not hand-rolled: every strategy builds
//! [`crate::codec`] pipelines from registry parts at construction
//! (`fedzip` is literally `topk|kmeans|huffman`) and `--codec <spec>`
//! swaps the compressed-upload pipeline of any strategy, so pipelines
//! sweep orthogonally to strategies.
//!
//! * [`fedavg`]      — dense FedAvg baseline.
//! * [`fedzip`]      — pruned + clustered + Huffman uploads (Malekijoo 2021).
//! * [`fedcompress`] — the paper's method and its no-SCS ablation.
//! * [`topk`]        — top-k sparsification uploads (API-openness proof).
//! * [`wire`]        — shared byte-exact wire-blob building blocks.

pub mod fedavg;
pub mod fedcompress;
pub mod fedzip;
pub mod registry;
pub mod topk;
pub mod wire;

pub use registry::{StrategyInfo, StrategyRegistry};
pub use wire::{WireBlob, WirePayloadMismatch, WireSizeMismatch};
