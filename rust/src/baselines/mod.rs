//! Wire policies per strategy: what actually crosses the (simulated)
//! network in each direction, byte-exact. This is where FedAvg, FedZip
//! and the two FedCompress variants differ — the aggregation rule and
//! the round loop stay identical (the paper's compatibility claim).

pub mod wire;

pub use wire::{encode_download, encode_upload, WireBlob};
