//! The paper's method as strategy plugins: FedCompress (adaptive weight
//! clustering + server-side distillation) and its ablation without
//! Self-Compression on Server.
//!
//! * `FedCompress` — clients train with L_wc once warmup ends; uploads
//!   hard-snap to the client's learned centroids; SCS re-distills the
//!   aggregate on OOD data and the plateau controller grows the cluster
//!   count; downstream re-encodes the SCS output (both directions
//!   compressed — the paper's headline).
//! * `FedCompressNoScs` — clients train with L_wc but the server never
//!   re-clusters, so assignments drift and the wire stays dense during
//!   training (CCR ~ 1, Table 1); only the *final* model is snapped
//!   (MCR ~ 1.6-1.8). See DESIGN.md §3.

use anyhow::Result;

use super::wire::{upload_pipeline, WireBlob};
use crate::client::trainer::evaluate;
use crate::clustering::{CentroidState, ClusterController};
use crate::codec::{stream, CodecInput, CodecRegistry, Pipeline};
use crate::compression::codec::quantize_and_encode;
use crate::config::FedConfig;
use crate::coordinator::accumulate::AggOutput;
use crate::coordinator::events::{Event, EventLog};
use crate::coordinator::strategy::{
    ClientTrainOpts, FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::data::Dataset;
use crate::runtime::literals::{literal_scalar_f32, literal_to_f32, Arg};
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// SelfCompress (Algorithm 1, lines 20-28): distill the aggregated
/// model (teacher) into a re-clustered student on OOD data, then snap.
/// Returns (snapped_student, mean_kl).
fn self_compress(
    engine: &Engine,
    cfg: &FedConfig,
    teacher: &[f32],
    centroids: &mut CentroidState,
    ood_data: &Dataset,
    rng: &mut Rng,
) -> Result<(Vec<f32>, f64)> {
    let ds = &cfg.dataset;
    let batch = engine.manifest.batch;
    let mut student = teacher.to_vec();
    let mut mu = centroids.mu.clone();
    let mask = centroids.mask.clone();
    let mut kl_sum = 0.0f64;
    let mut steps = 0usize;

    for _epoch in 0..cfg.server_epochs {
        for (xs, _ys) in ood_data.epoch_batches(batch, rng) {
            let out = engine.run(
                ds,
                "distill_step",
                &[
                    Arg::F32(&student),
                    Arg::F32(teacher),
                    Arg::F32(&mu),
                    Arg::F32(&mask),
                    Arg::F32(&xs),
                    Arg::Scalar(cfg.lr_server),
                    Arg::Scalar(cfg.beta),
                    Arg::Scalar(cfg.temperature),
                ],
            )?;
            student = literal_to_f32(&out[0])?;
            mu = literal_to_f32(&out[1])?;
            kl_sum += literal_scalar_f32(&out[3])? as f64;
            steps += 1;
        }
    }
    centroids.mu = mu;

    // hard snap to the learned codebook: the downstream wire model
    let codebook = centroids.active_codebook();
    let (_, snapped) = quantize_and_encode(&student, &codebook);
    Ok((snapped, kl_sum / steps.max(1) as f64))
}

/// Full FedCompress: weight-clustered training, snapped wire both
/// directions (the declared `codebook|huffman` pipeline), SCS, dynamic
/// cluster count. `--codec <spec>` swaps the upload pipeline; the
/// downstream keeps the strategy's declared codec (SCS guarantees the
/// dispatched model is centroid-structured, which is what makes the
/// snap lossless there).
pub struct FedCompress {
    controller: ClusterController,
    download: Pipeline,
    upload: Pipeline,
}

impl FedCompress {
    pub fn new(cfg: &FedConfig) -> Result<FedCompress> {
        Ok(FedCompress {
            controller: ClusterController::new(cfg.controller.clone()),
            download: CodecRegistry::builtin().build("codebook|huffman")?,
            upload: upload_pipeline(cfg, "codebook|huffman")?,
        })
    }
}

impl FedStrategy for FedCompress {
    fn name(&self) -> &'static str {
        "fedcompress"
    }

    fn resume(&mut self, cfg: &FedConfig, scores: &[f64]) -> Result<()> {
        // replay exactly the observations the original run's controller
        // saw: `post_aggregate` observes once compression engages and
        // only for rounds with survivors (a fully-lost round records
        // score 0.0 and skips the hook), so a resumed run's plateau
        // window/patience state matches the uninterrupted run's.
        for (round, &score) in scores.iter().enumerate() {
            if round >= cfg.warmup_rounds && score != 0.0 {
                let _ = self.controller.observe(score);
            }
        }
        Ok(())
    }

    fn round_start(&mut self, ctx: &RoundContext<'_>, model: &mut ServerModel) -> Result<()> {
        // warmup boundary: re-seed the codebook from the *trained*
        // weight distribution, not the init one
        if ctx.round == ctx.cfg.warmup_rounds {
            let mut rng = ctx.base.fork(60_000 + ctx.round as u64);
            let c = model.centroids.active;
            let c_max = model.centroids.c_max;
            model.centroids = CentroidState::init_from_weights(&model.theta, c, c_max, &mut rng);
        }
        Ok(())
    }

    fn client_train_opts(&self, ctx: &RoundContext<'_>) -> ClientTrainOpts {
        ClientTrainOpts {
            weight_clustering: ctx.compressing,
        }
    }

    fn encode_download(&self, ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        // dense until the first SCS has produced a clustered model
        if !ctx.down_compressed {
            return Ok(WireBlob::dense(&model.theta));
        }
        let input = CodecInput {
            theta: &model.theta,
            centroids: Some(&model.centroids),
            stream: stream::DOWNLOAD,
        };
        // fedlint:allow(rng-discipline) -- placeholder stream: no stage of the declared pipeline draws randomness
        WireBlob::encode(&self.download, &input, &mut Rng::new(0))
    }

    fn encode_upload(
        &self,
        ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        rng: &mut Rng,
    ) -> Result<WireBlob> {
        // dense during warmup; snapped to the client's learned
        // centroids afterwards
        if !ctx.compressing {
            return Ok(WireBlob::dense(input.theta));
        }
        WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: input.theta,
                centroids: Some(input.centroids),
                stream: stream::upload(input.client),
            },
            rng,
        )
    }

    fn aggregate(
        &mut self,
        _ctx: &RoundContext<'_>,
        model: &mut ServerModel,
        agg: AggOutput,
    ) -> Result<f64> {
        // unmodified FedAvg on theta plus the centroid-table average
        // (paper Algorithm 1, line 7), both from the streaming fold
        model.theta = agg.theta;
        model.centroids.mu = agg.mu;
        Ok(agg.score)
    }

    fn post_aggregate(
        &mut self,
        ctx: &RoundContext<'_>,
        env: &ServerEnv<'_>,
        model: &mut ServerModel,
        score: f64,
        events: &mut EventLog,
    ) -> Result<()> {
        if !ctx.compressing {
            return Ok(());
        }
        // --- server-side self-compression ---------------------------------
        let mut scs_rng = env.base.fork(50_000 + ctx.round as u64);
        if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
            let (pre_acc, _) =
                evaluate(env.engine, &env.cfg.dataset, &env.data.test, &model.theta)?;
            crate::debug!("round {}: pre-SCS aggregated acc={pre_acc:.4}", ctx.round);
        }
        let teacher = model.theta.clone();
        let (snapped, kl) = self_compress(
            env.engine,
            env.cfg,
            &teacher,
            &mut model.centroids,
            &env.data.ood,
            &mut scs_rng,
        )?;
        crate::debug!("round {}: SCS mean KL={kl:.4}", ctx.round);
        events.push(Event::SelfCompress {
            round: ctx.round,
            mean_kl: kl,
        });
        model.theta = snapped;

        // --- dynamic cluster count ----------------------------------------
        let next_c = self.controller.observe(score);
        if next_c > model.centroids.active {
            events.push(Event::ControllerGrow {
                round: ctx.round,
                from: model.centroids.active,
                to: next_c,
            });
            model.centroids.grow_to(next_c);
        }
        Ok(())
    }

    fn finalize(&self, env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        let mut rng = env.base.fork(9_999);
        let blob = WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: &model.theta,
                centroids: Some(&model.centroids),
                stream: stream::FINAL,
            },
            &mut rng,
        )?;
        Ok(FinalModel {
            theta: blob.theta,
            wire_bytes: blob.bytes,
        })
    }
}

/// Ablation: weight-clustered training without server re-clustering.
/// Dense on the wire during training (CCR ~ 1); only the *final* model
/// is compressed, through the declared `kmeans|huffman` pipeline at
/// the controller's floor C.
pub struct FedCompressNoScs {
    upload: Pipeline,
    final_codec: Pipeline,
}

impl FedCompressNoScs {
    pub fn new(cfg: &FedConfig) -> Result<FedCompressNoScs> {
        let c = cfg.controller.c_min.max(8);
        Ok(FedCompressNoScs {
            upload: upload_pipeline(cfg, "dense")?,
            final_codec: CodecRegistry::builtin()
                .build(&format!("kmeans(c={c},iters=25)|huffman"))?,
        })
    }
}

impl FedStrategy for FedCompressNoScs {
    fn name(&self) -> &'static str {
        "fedcompress-noscs"
    }

    fn client_train_opts(&self, ctx: &RoundContext<'_>) -> ClientTrainOpts {
        ClientTrainOpts {
            weight_clustering: ctx.compressing,
        }
    }

    fn encode_download(&self, _ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        Ok(WireBlob::dense(&model.theta))
    }

    fn encode_upload(
        &self,
        ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        rng: &mut Rng,
    ) -> Result<WireBlob> {
        if !ctx.compressing {
            return Ok(WireBlob::dense(input.theta));
        }
        WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: input.theta,
                centroids: Some(input.centroids),
                stream: stream::upload(input.client),
            },
            rng,
        )
    }

    fn aggregate(
        &mut self,
        _ctx: &RoundContext<'_>,
        model: &mut ServerModel,
        agg: AggOutput,
    ) -> Result<f64> {
        model.theta = agg.theta;
        model.centroids.mu = agg.mu;
        Ok(agg.score)
    }

    fn finalize(&self, env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        // final-model-only compression: k-means at the controller's
        // floor C (training never grew it — no score feedback loop)
        let mut rng = env.base.fork(9_998);
        let blob = WireBlob::encode(
            &self.final_codec,
            &CodecInput {
                theta: &model.theta,
                centroids: Some(&model.centroids),
                stream: stream::FINAL,
            },
            &mut rng,
        )?;
        Ok(FinalModel {
            theta: blob.theta,
            wire_bytes: blob.bytes,
        })
    }
}
