//! FedAvg (McMahan 2017) as a strategy plugin: dense f32 both
//! directions, plain CE training, unmodified sample-count aggregation.
//! The baseline every Table-1 ratio is measured against.

use anyhow::Result;

use super::wire::WireBlob;
use crate::compression::codec::dense_bytes;
use crate::coordinator::strategy::{
    FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::util::rng::Rng;

pub struct FedAvg;

impl FedStrategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn encode_download(&self, _ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        Ok(WireBlob::dense(&model.theta))
    }

    fn encode_upload(
        &self,
        _ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        _rng: &mut Rng,
    ) -> Result<WireBlob> {
        Ok(WireBlob::dense(input.theta))
    }

    fn finalize(&self, _env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        Ok(FinalModel {
            theta: model.theta.clone(),
            wire_bytes: dense_bytes(model.theta.len()),
        })
    }
}
