//! FedAvg (McMahan 2017) as a strategy plugin: dense f32 both
//! directions, plain CE training, unmodified sample-count aggregation.
//! The baseline every Table-1 ratio is measured against.
//!
//! Declares the `dense` codec pipeline for every direction; a `--codec
//! <spec>` override swaps the upload pipeline in once warmup ends
//! (turning FedAvg into a compressed-upload variant without touching
//! this file).

use anyhow::Result;

use super::wire::{upload_pipeline, WireBlob};
use crate::codec::{stream, CodecInput, Pipeline};
use crate::compression::codec::dense_bytes;
use crate::coordinator::strategy::{
    FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::util::rng::Rng;

pub struct FedAvg {
    upload: Pipeline,
}

impl FedAvg {
    pub fn new(cfg: &crate::config::FedConfig) -> Result<FedAvg> {
        Ok(FedAvg {
            upload: upload_pipeline(cfg, "dense")?,
        })
    }
}

impl FedStrategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn encode_download(&self, _ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        Ok(WireBlob::dense(&model.theta))
    }

    fn encode_upload(
        &self,
        ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        rng: &mut Rng,
    ) -> Result<WireBlob> {
        if !ctx.compressing {
            return Ok(WireBlob::dense(input.theta));
        }
        WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: input.theta,
                centroids: Some(input.centroids),
                stream: stream::upload(input.client),
            },
            rng,
        )
    }

    fn finalize(&self, _env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        Ok(FinalModel {
            theta: model.theta.clone(),
            wire_bytes: dense_bytes(model.theta.len()),
        })
    }
}
