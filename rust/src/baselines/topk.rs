//! Top-k sparsification baseline — the "prove the API is open" plugin:
//! a genuinely new strategy built on the existing `sparsify` + `bitio`
//! machinery without touching the coordinator.
//!
//! Upstream, each client keeps only the top `topk_keep` fraction of
//! weights by magnitude and ships (position, value) pairs: positions
//! bit-packed at ceil(log2 n) bits, values as raw f32. Downstream stays
//! dense (like FedZip). The final deliverable is the sparse-encoded
//! aggregate. Clients train plain CE.
//!
//! Wire layout (little-endian):
//!   u32 magic 'FCS1' | u32 n | u32 k | u8 bits |
//!   bit-packed positions (k * bits, LSB-first) | f32 values[k]

use anyhow::{bail, Result};

use super::wire::{WireBlob, WireCodec};
use crate::compression::codec::index_bits;
use crate::compression::sparsify::magnitude_prune;
use crate::coordinator::strategy::{
    FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::rng::Rng;

const MAGIC: u32 = 0x4643_5331; // "FCS1"

/// Sparse-encode a weight vector: magnitude-prune to `keep`, then pack
/// survivors as (position, value). Returns the exact wire bytes and the
/// pruned vector the receiver reconstructs.
pub fn encode_topk(theta: &[f32], keep: f64) -> (Vec<u8>, Vec<f32>) {
    let mut pruned = theta.to_vec();
    magnitude_prune(&mut pruned, keep);
    let survivors: Vec<(usize, f32)> = pruned
        .iter()
        .enumerate()
        .filter(|(_, w)| **w != 0.0)
        .map(|(i, w)| (i, *w))
        .collect();

    let n = theta.len();
    let bits = index_bits(n.max(2));
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(survivors.len() as u32).to_le_bytes());
    out.push(bits as u8);
    let mut w = BitWriter::new();
    for (pos, _) in &survivors {
        w.write(*pos as u32, bits);
    }
    out.extend_from_slice(w.as_bytes());
    for (_, v) in &survivors {
        out.extend_from_slice(&v.to_le_bytes());
    }
    (out, pruned)
}

fn take(bytes: &[u8], i: usize, n: usize) -> Result<&[u8]> {
    if i + n > bytes.len() {
        bail!("truncated topk blob");
    }
    Ok(&bytes[i..i + n])
}

/// Decode a sparse blob back to the dense (pruned) weight vector.
pub fn decode_topk(bytes: &[u8]) -> Result<Vec<f32>> {
    let take = |i: usize, n: usize| take(bytes, i, n);
    if u32::from_le_bytes(take(0, 4)?.try_into()?) != MAGIC {
        bail!("bad topk magic");
    }
    let n = u32::from_le_bytes(take(4, 4)?.try_into()?) as usize;
    let k = u32::from_le_bytes(take(8, 4)?.try_into()?) as usize;
    let bits = take(12, 1)?[0] as u32;
    if k > n {
        bail!("topk blob claims {k} survivors of {n} params");
    }
    if bits != index_bits(n.max(2)) {
        bail!("topk blob bit width {bits} does not match {n} params");
    }
    let pos_bytes = (k * bits as usize).div_ceil(8);
    let mut r = BitReader::new(take(13, pos_bytes)?);
    let mut positions = Vec::with_capacity(k);
    for _ in 0..k {
        match r.read(bits) {
            Some(p) if (p as usize) < n => positions.push(p as usize),
            Some(p) => bail!("position {p} out of range {n}"),
            None => bail!("truncated position stream"),
        }
    }
    let mut theta = vec![0.0f32; n];
    let vals = take(13 + pos_bytes, 4 * k)?;
    for (j, &pos) in positions.iter().enumerate() {
        theta[pos] = f32::from_le_bytes(vals[4 * j..4 * j + 4].try_into()?);
    }
    Ok(theta)
}

/// The plugin: top-k sparsified uploads, dense downstream.
pub struct TopK;

impl FedStrategy for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encode_download(&self, _ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        Ok(WireBlob::dense(&model.theta))
    }

    fn encode_upload(
        &self,
        ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        _rng: &mut Rng,
    ) -> Result<WireBlob> {
        let (bytes, theta) = encode_topk(input.theta, ctx.cfg.topk_keep);
        Ok(WireBlob {
            bytes: bytes.len(),
            theta,
            codec: WireCodec::Sparse,
            payload: bytes,
        })
    }

    fn finalize(&self, env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        let (bytes, theta) = encode_topk(&model.theta, env.cfg.topk_keep);
        Ok(FinalModel {
            theta,
            wire_bytes: bytes.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::codec::dense_bytes;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_is_exact_on_the_pruned_vector() {
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..10_000).map(|_| rng.normal() * 0.2).collect();
        let (bytes, pruned) = encode_topk(&theta, 0.1);
        let decoded = decode_topk(&bytes).unwrap();
        assert_eq!(decoded, pruned);
        let kept = pruned.iter().filter(|w| **w != 0.0).count();
        assert!((995..=1005).contains(&kept), "{kept}");
    }

    #[test]
    fn wire_beats_dense_substantially_at_10_percent() {
        let mut rng = Rng::new(2);
        let theta: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let (bytes, _) = encode_topk(&theta, 0.1);
        let ratio = dense_bytes(theta.len()) as f64 / bytes.len() as f64;
        // ~ (32 bits) / (0.1 * (32 + log2 n) bits) ~ 6-7x at n=20k
        assert!(ratio > 5.0, "ratio {ratio}");
    }

    #[test]
    fn decode_rejects_corruption() {
        let mut rng = Rng::new(3);
        let theta: Vec<f32> = (0..500).map(|_| rng.normal()).collect();
        let (bytes, _) = encode_topk(&theta, 0.2);
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(decode_topk(&bad).is_err());
        let mut short = bytes.clone();
        short.truncate(bytes.len() / 2);
        assert!(decode_topk(&short).is_err());
    }

    #[test]
    fn keep_one_keeps_everything() {
        let theta = vec![1.0f32, -2.0, 3.0, 0.5];
        let (bytes, pruned) = encode_topk(&theta, 1.0);
        assert_eq!(pruned, theta);
        assert_eq!(decode_topk(&bytes).unwrap(), theta);
    }
}
