//! Top-k sparsification baseline — the "prove the API is open" plugin:
//! a genuinely new strategy built without touching the coordinator.
//!
//! Upstream, each client keeps only the top `topk_keep` fraction of
//! weights by magnitude and ships (position, value) pairs; downstream
//! stays dense (like FedZip). The final deliverable is the
//! sparse-encoded aggregate. Clients train plain CE.
//!
//! The wire format lives in the codec layer now
//! ([`crate::codec::stages::TopkStage`], registered as `topk`): the
//! strategy just declares the single-stage `topk(keep=...)` pipeline.
//! [`encode_topk`]/[`decode_topk`] remain as one-shot helpers over the
//! same stage machinery.

use anyhow::Result;

use super::wire::{upload_pipeline, WireBlob};
use crate::codec::stages::{sparse_decode, sparse_encode};
use crate::codec::{stream, CodecInput, Pipeline};
use crate::compression::sparsify::magnitude_prune;
use crate::config::FedConfig;
use crate::coordinator::strategy::{
    FedStrategy, FinalModel, RoundContext, ServerEnv, ServerModel, UploadInput,
};
use crate::util::rng::Rng;

/// Sparse-encode a weight vector: magnitude-prune to `keep`, then pack
/// survivors as (position, value). Returns the exact wire bytes and the
/// pruned vector the receiver reconstructs.
pub fn encode_topk(theta: &[f32], keep: f64) -> (Vec<u8>, Vec<f32>) {
    let mut pruned = theta.to_vec();
    magnitude_prune(&mut pruned, keep);
    (sparse_encode(&pruned), pruned)
}

/// Decode a sparse blob back to the dense (pruned) weight vector.
pub fn decode_topk(bytes: &[u8]) -> Result<Vec<f32>> {
    Ok(sparse_decode(bytes)?)
}

/// The plugin: top-k sparsified uploads, dense downstream.
pub struct TopK {
    upload: Pipeline,
}

impl TopK {
    pub fn new(cfg: &FedConfig) -> Result<TopK> {
        Ok(TopK {
            upload: upload_pipeline(cfg, &format!("topk(keep={})", cfg.topk_keep))?,
        })
    }
}

impl FedStrategy for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encode_download(&self, _ctx: &RoundContext<'_>, model: &ServerModel) -> Result<WireBlob> {
        Ok(WireBlob::dense(&model.theta))
    }

    fn encode_upload(
        &self,
        _ctx: &RoundContext<'_>,
        input: &UploadInput<'_>,
        rng: &mut Rng,
    ) -> Result<WireBlob> {
        WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: input.theta,
                centroids: Some(input.centroids),
                stream: stream::upload(input.client),
            },
            rng,
        )
    }

    fn finalize(&self, env: &ServerEnv<'_>, model: &ServerModel) -> Result<FinalModel> {
        let mut rng = env.base.fork(9_999);
        let blob = WireBlob::encode(
            &self.upload,
            &CodecInput {
                theta: &model.theta,
                centroids: Some(&model.centroids),
                stream: stream::FINAL,
            },
            &mut rng,
        )?;
        Ok(FinalModel {
            theta: blob.theta,
            wire_bytes: blob.bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::codec::dense_bytes;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_is_exact_on_the_pruned_vector() {
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..10_000).map(|_| rng.normal() * 0.2).collect();
        let (bytes, pruned) = encode_topk(&theta, 0.1);
        let decoded = decode_topk(&bytes).unwrap();
        assert_eq!(decoded, pruned);
        let kept = pruned.iter().filter(|w| **w != 0.0).count();
        assert!((995..=1005).contains(&kept), "{kept}");
    }

    #[test]
    fn wire_beats_dense_substantially_at_10_percent() {
        let mut rng = Rng::new(2);
        let theta: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let (bytes, _) = encode_topk(&theta, 0.1);
        let ratio = dense_bytes(theta.len()) as f64 / bytes.len() as f64;
        // ~ (32 bits) / (0.1 * (32 + log2 n) bits) ~ 6-7x at n=20k
        assert!(ratio > 5.0, "ratio {ratio}");
    }

    #[test]
    fn decode_rejects_corruption() {
        let mut rng = Rng::new(3);
        let theta: Vec<f32> = (0..500).map(|_| rng.normal()).collect();
        let (bytes, _) = encode_topk(&theta, 0.2);
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(decode_topk(&bad).is_err());
        let mut short = bytes.clone();
        short.truncate(bytes.len() / 2);
        assert!(decode_topk(&short).is_err());
    }

    #[test]
    fn keep_one_keeps_everything() {
        let theta = vec![1.0f32, -2.0, 3.0, 0.5];
        let (bytes, pruned) = encode_topk(&theta, 1.0);
        assert_eq!(pruned, theta);
        assert_eq!(decode_topk(&bytes).unwrap(), theta);
    }

    /// The strategy helper and the registered `topk` stage are the same
    /// machinery: the plugin's declared pipeline produces the identical
    /// wire image.
    #[test]
    fn strategy_pipeline_matches_the_helper() {
        use crate::codec::{Codec, CodecInput, CodecRegistry};
        let mut rng = Rng::new(4);
        let theta: Vec<f32> = (0..3000).map(|_| rng.normal()).collect();
        let (bytes, pruned) = encode_topk(&theta, 0.15);
        let pipe = CodecRegistry::builtin().build("topk(keep=0.15)").unwrap();
        let blob = pipe
            .encode(&CodecInput::floats(&theta), &mut Rng::new(0))
            .unwrap();
        assert_eq!(blob.payload, bytes);
        assert_eq!(blob.theta, pruned);
    }
}
