//! Wire-blob building blocks shared by the strategy plugins.
//!
//! A `WireBlob` is what actually crossed the (simulated) network in one
//! direction: the exact byte count, the encoded payload, the model the
//! receiver reconstructs — quantization is part of the transport, so
//! sender and receiver agree on the decoded weights — and the
//! self-describing codec spec that decodes the payload. Blobs are
//! produced by [`crate::codec`] pipelines ([`WireBlob::encode`]);
//! *which* pipeline a strategy uses per direction/round lives in the
//! plugin implementations (`baselines::fedavg` etc.), not in any
//! central `match`, and any codec registered on both ends of a
//! transport crosses it — there is no in-process-only format anymore.

use std::fmt;

use anyhow::Result;

use crate::clustering::CentroidState;
use crate::codec::stages::dense_encode;
use crate::codec::{Codec, CodecInput, CodecRegistry, StageBytes};
use crate::compression::codec::dense_bytes;
use crate::util::rng::Rng;

/// What crossed the wire: exact byte count plus the model the receiver
/// reconstructs. `payload` is the actual encoded byte stream (what a
/// networked transport puts on the socket), `spec` is the canonical
/// codec spec the receiver resolves against its registry to decode it,
/// and `stage_bytes` is the per-stage ledger breakdown. The invariant
/// `payload.len() == bytes` (checked by [`WireBlob::ensure_payload`])
/// is what makes the ledger's ideal byte counts honest on a real wire
/// — with the codec redesign it holds for *every* blob, with no
/// exemptions.
pub struct WireBlob {
    pub bytes: usize,
    pub theta: Vec<f32>,
    /// Self-describing wire codec spec (e.g. `topk(keep=0.6)|kmeans(
    /// c=15,iters=25)|huffman`) — what `net::proto` ships ahead of the
    /// payload.
    pub spec: String,
    pub payload: Vec<u8>,
    /// Per-stage wire sizes (the last entry equals `bytes`).
    pub stage_bytes: Vec<StageBytes>,
}

impl WireBlob {
    /// Encode `input` through a codec pipeline into a wire blob.
    pub fn encode(codec: &dyn Codec, input: &CodecInput<'_>, rng: &mut Rng) -> Result<WireBlob> {
        let blob = codec.encode(input, rng)?;
        Ok(WireBlob {
            bytes: blob.payload.len(),
            theta: blob.theta,
            spec: codec.spec(),
            payload: blob.payload,
            stage_bytes: blob.stage_bytes,
        })
    }

    /// Dense f32 transport: lossless, 4 bytes per parameter.
    /// Byte-identical to encoding through the registry's `dense`
    /// pipeline, without constructing one.
    pub fn dense(theta: &[f32]) -> WireBlob {
        let bytes = dense_bytes(theta.len());
        WireBlob {
            bytes,
            theta: theta.to_vec(),
            spec: "dense".to_string(),
            payload: dense_encode(theta),
            stage_bytes: vec![StageBytes {
                stage: "dense".to_string(),
                bytes,
            }],
        }
    }

    /// Check the payload-length invariant the framed ledger and the TCP
    /// transport rely on.
    pub fn ensure_payload(&self) -> Result<(), WirePayloadMismatch> {
        if self.payload.len() != self.bytes {
            return Err(WirePayloadMismatch {
                bytes: self.bytes,
                payload_len: self.payload.len(),
            });
        }
        Ok(())
    }

    /// Check the decoded model against the manifest parameter count.
    /// Debug builds assert; release builds surface the typed error so a
    /// size mismatch can never silently corrupt aggregation.
    pub fn ensure_param_count(&self, expected: usize) -> Result<(), WireSizeMismatch> {
        debug_assert_eq!(
            self.theta.len(),
            expected,
            "wire blob param count mismatch"
        );
        if self.theta.len() != expected {
            return Err(WireSizeMismatch {
                expected,
                got: self.theta.len(),
            });
        }
        Ok(())
    }
}

/// Typed decode-invariant violation: the reconstructed model does not
/// match the manifest's parameter count. Returned (never silently
/// tolerated) by [`WireBlob::ensure_param_count`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireSizeMismatch {
    pub expected: usize,
    pub got: usize,
}

impl fmt::Display for WireSizeMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "wire blob param count mismatch: manifest expects {} params, decoded {}",
            self.expected, self.got
        )
    }
}

impl std::error::Error for WireSizeMismatch {}

/// The payload length does not match the claimed wire byte count — the
/// blob would lie to the framed-byte ledger. Typed like
/// [`WireSizeMismatch`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WirePayloadMismatch {
    pub bytes: usize,
    pub payload_len: usize,
}

impl fmt::Display for WirePayloadMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "wire blob payload length mismatch: claims {} wire bytes, payload is {}",
            self.bytes, self.payload_len
        )
    }
}

impl std::error::Error for WirePayloadMismatch {}

/// Build a strategy's compressed-upload pipeline: the run-wide
/// `--codec <spec>` override when the config carries one, the
/// strategy's declared default otherwise. Resolution fails early (at
/// strategy construction) with the registry's typo suggestion.
pub fn upload_pipeline(
    cfg: &crate::config::FedConfig,
    default_spec: &str,
) -> Result<crate::codec::Pipeline> {
    let spec = if cfg.codec.is_empty() {
        default_spec
    } else {
        cfg.codec.as_str()
    };
    Ok(CodecRegistry::builtin().build(spec)?)
}

/// FedZip upstream policy as a one-shot helper: magnitude prune to
/// `keep`, fit a fresh `clusters`-entry k-means codebook on the pruned
/// vector, entropy-code — literally the `topk|kmeans|huffman` pipeline
/// built from registry parts (what the `fedzip` plugin declares).
pub fn kmeans_blob(theta: &[f32], clusters: usize, keep: f64, rng: &mut Rng) -> Result<WireBlob> {
    let spec = format!("topk(keep={keep})|kmeans(c={clusters},iters=25)|huffman");
    let pipe = CodecRegistry::builtin().build(&spec)?;
    WireBlob::encode(&pipe, &CodecInput::floats(theta), rng)
}

/// FedCompress policy as a one-shot helper: hard-snap to the active
/// centroid codebook and entropy-code (the `codebook|huffman`
/// pipeline); lossless when the model is already centroid-structured
/// (post-SCS downstream).
pub fn codebook_blob(theta: &[f32], centroids: &CentroidState) -> Result<WireBlob> {
    let pipe = CodecRegistry::builtin().build("codebook|huffman")?;
    let input = CodecInput {
        theta,
        centroids: Some(centroids),
        stream: crate::codec::stream::FINAL,
    };
    // fedlint:allow(rng-discipline) -- placeholder stream: no stage of this pipeline draws randomness
    WireBlob::encode(&pipe, &input, &mut Rng::new(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::codec::dense_bytes;
    use crate::util::rng::Rng;

    fn setup() -> (Vec<f32>, CentroidState, Rng) {
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..5000).map(|_| rng.normal() * 0.2).collect();
        let cents = CentroidState::init_from_weights(&theta, 16, 32, &mut rng);
        (theta, cents, rng)
    }

    #[test]
    fn dense_is_lossless_and_4_bytes_per_param() {
        let (theta, _, _) = setup();
        let blob = WireBlob::dense(&theta);
        assert_eq!(blob.bytes, 4 * theta.len());
        assert_eq!(blob.theta, theta);
        assert!(blob.ensure_param_count(theta.len()).is_ok());
        // the payload is the exact little-endian image of theta
        assert_eq!(blob.spec, "dense");
        assert!(blob.ensure_payload().is_ok());
        let decoded: Vec<f32> = blob
            .payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(decoded, theta);
        // ...and it is byte-identical to the registry's dense pipeline
        let pipe = CodecRegistry::builtin().build("dense").unwrap();
        let via_pipe =
            WireBlob::encode(&pipe, &CodecInput::floats(&theta), &mut Rng::new(0)).unwrap();
        assert_eq!(via_pipe.payload, blob.payload);
        assert_eq!(via_pipe.spec, blob.spec);
        assert_eq!(via_pipe.stage_bytes, blob.stage_bytes);
    }

    /// Every blob must satisfy `payload.len() == bytes` — the invariant
    /// that keeps the framed ledger honest. No codec is exempt.
    #[test]
    fn payload_length_matches_claimed_bytes() {
        let (theta, cents, mut rng) = setup();
        for blob in [
            WireBlob::dense(&theta),
            kmeans_blob(&theta, 15, 0.6, &mut rng).unwrap(),
            codebook_blob(&theta, &cents).unwrap(),
        ] {
            assert!(blob.ensure_payload().is_ok(), "{}", blob.spec);
            assert_eq!(blob.payload.len(), blob.bytes);
            // the per-stage ledger ends at the real payload size
            assert_eq!(blob.stage_bytes.last().unwrap().bytes, blob.bytes);
        }
        // a lying blob is caught with the typed error
        let bad = WireBlob {
            bytes: 10,
            theta: vec![0.0; 4],
            spec: "dense".to_string(),
            payload: vec![0u8; 16],
            stage_bytes: Vec::new(),
        };
        let e = bad.ensure_payload().unwrap_err();
        assert_eq!(e.bytes, 10);
        assert_eq!(e.payload_len, 16);
        assert!(e.to_string().contains("payload length mismatch"));
    }

    #[test]
    fn kmeans_blob_compresses_and_sparsifies() {
        let (theta, _, mut rng) = setup();
        let blob = kmeans_blob(&theta, 15, 0.6, &mut rng).unwrap();
        assert!(blob.bytes < dense_bytes(theta.len()) / 3, "{}", blob.bytes);
        // the zero cluster exists and dominates at keep=0.6
        let zeros = blob.theta.iter().filter(|w| w.abs() < 1e-3).count();
        assert!(zeros as f64 > 0.3 * theta.len() as f64, "{zeros}");
        // the stage ledger traces prune -> cluster -> entropy
        let names: Vec<&str> = blob.stage_bytes.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, ["topk", "kmeans", "huffman"]);
    }

    #[test]
    fn codebook_blob_snaps_into_codebook_and_is_idempotent() {
        let (theta, cents, _) = setup();
        let blob = codebook_blob(&theta, &cents).unwrap();
        assert!(blob.bytes < dense_bytes(theta.len()) / 4);
        let cb = cents.active_codebook();
        for w in &blob.theta {
            assert!(cb.iter().any(|c| c == w));
        }
        // already-snapped model re-encodes losslessly
        let again = codebook_blob(&blob.theta, &cents).unwrap();
        assert_eq!(again.theta, blob.theta);
    }

    #[test]
    fn param_count_mismatch_is_caught() {
        let blob = WireBlob::dense(&[1.0, 2.0]);
        if cfg!(debug_assertions) {
            // debug builds assert loudly
            let r = std::panic::catch_unwind(|| blob.ensure_param_count(3));
            assert!(r.is_err(), "debug_assert should fire on mismatch");
        } else {
            // release builds surface the typed error
            let e = blob.ensure_param_count(3).unwrap_err();
            assert_eq!(e.expected, 3);
            assert_eq!(e.got, 2);
            assert!(e.to_string().contains("param count mismatch"));
        }
    }
}
