//! Upload/download encoding per strategy.
//!
//! * FedAvg            — dense f32 both directions.
//! * FedZip (Malekijoo 2021) — upstream: magnitude prune -> k-means with
//!   a fixed cluster count (15 in the paper) -> Huffman; downstream
//!   stays dense (FedZip only optimizes client->server).
//! * FedCompress w/o SCS — clients train with L_wc but without server
//!   re-clustering the received model is dense and assignments drift,
//!   so the wire stays dense during training (CCR ~ 1, Table 1); only
//!   the *final* model is snapped (MCR ~ 1.6-1.8). See DESIGN.md §3.
//! * FedCompress       — upstream: hard-snap to the client's learned
//!   centroids + codebook codec; downstream: the SCS output re-encoded
//!   the same way (both directions compressed — the paper's headline).

use anyhow::Result;

use crate::clustering::CentroidState;
use crate::compression::codec::{dense_bytes, quantize_and_encode};
use crate::compression::kmeans::kmeans_1d;
use crate::compression::sparsify::magnitude_prune;
use crate::config::{FedConfig, Strategy};
use crate::util::rng::Rng;

/// What crossed the wire: exact byte count plus the model the receiver
/// reconstructs (quantization is part of the transport, so sender and
/// receiver agree on the decoded weights).
pub struct WireBlob {
    pub bytes: usize,
    pub theta: Vec<f32>,
}

/// Encode a client upload. Returns the blob the server decodes.
/// `compressing` is false during FedCompress's dense warmup rounds.
pub fn encode_upload(
    strategy: Strategy,
    cfg: &FedConfig,
    theta: &[f32],
    client_centroids: &CentroidState,
    compressing: bool,
    rng: &mut Rng,
) -> Result<WireBlob> {
    if !compressing && strategy == Strategy::FedCompress {
        return Ok(WireBlob {
            bytes: dense_bytes(theta.len()),
            theta: theta.to_vec(),
        });
    }
    match strategy {
        Strategy::FedAvg | Strategy::FedCompressNoScs => Ok(WireBlob {
            bytes: dense_bytes(theta.len()),
            theta: theta.to_vec(),
        }),
        Strategy::FedZip => {
            let mut pruned = theta.to_vec();
            magnitude_prune(&mut pruned, cfg.fedzip_keep);
            let (codebook, _, _) = kmeans_1d(&pruned, cfg.fedzip_clusters, 25, rng);
            let (enc, quantized) = quantize_and_encode(&pruned, &codebook);
            Ok(WireBlob {
                bytes: enc.wire_bytes(),
                theta: quantized,
            })
        }
        Strategy::FedCompress => {
            let codebook = client_centroids.active_codebook();
            let (enc, quantized) = quantize_and_encode(theta, &codebook);
            if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
                let mse: f64 = theta
                    .iter()
                    .zip(&quantized)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    / theta.len() as f64;
                let span = codebook.last().unwrap() - codebook.first().unwrap();
                crate::debug!(
                    "upload snap: C={} span={:.4} mse={:.6} cb[0..4]={:?}",
                    codebook.len(),
                    span,
                    mse,
                    &codebook[..4.min(codebook.len())]
                );
            }
            Ok(WireBlob {
                bytes: enc.wire_bytes(),
                theta: quantized,
            })
        }
    }
}

/// Encode the server dispatch for the next round. For FedCompress the
/// model is already centroid-structured post-SCS, so the codec is
/// lossless on it; round 0 (fresh init, no structure yet) goes dense.
pub fn encode_download(
    strategy: Strategy,
    compressing: bool,
    theta: &[f32],
    server_centroids: &CentroidState,
) -> Result<WireBlob> {
    match strategy {
        Strategy::FedAvg | Strategy::FedZip | Strategy::FedCompressNoScs => Ok(WireBlob {
            bytes: dense_bytes(theta.len()),
            theta: theta.to_vec(),
        }),
        Strategy::FedCompress => {
            // dense until the first SCS has produced a clustered model
            if !compressing {
                return Ok(WireBlob {
                    bytes: dense_bytes(theta.len()),
                    theta: theta.to_vec(),
                });
            }
            let codebook = server_centroids.active_codebook();
            let (enc, quantized) = quantize_and_encode(theta, &codebook);
            Ok(WireBlob {
                bytes: enc.wire_bytes(),
                theta: quantized,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup() -> (FedConfig, Vec<f32>, CentroidState, Rng) {
        let cfg = FedConfig::quick("cifar10");
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..5000).map(|_| rng.normal() * 0.2).collect();
        let cents = CentroidState::init_from_weights(&theta, 16, 32, &mut rng);
        (cfg, theta, cents, rng)
    }

    #[test]
    fn fedavg_is_dense_and_lossless() {
        let (cfg, theta, cents, mut rng) = setup();
        let up = encode_upload(Strategy::FedAvg, &cfg, &theta, &cents, true, &mut rng).unwrap();
        assert_eq!(up.bytes, 4 * theta.len());
        assert_eq!(up.theta, theta);
    }

    #[test]
    fn fedzip_upload_compresses_but_download_dense() {
        let (cfg, theta, cents, mut rng) = setup();
        let up = encode_upload(Strategy::FedZip, &cfg, &theta, &cents, true, &mut rng).unwrap();
        assert!(up.bytes < 4 * theta.len() / 3, "{}", up.bytes);
        let down = encode_download(Strategy::FedZip, true, &theta, &cents).unwrap();
        assert_eq!(down.bytes, 4 * theta.len());
    }

    #[test]
    fn fedcompress_compresses_both_directions_after_round0() {
        let (cfg, theta, cents, mut rng) = setup();
        let up =
            encode_upload(Strategy::FedCompress, &cfg, &theta, &cents, true, &mut rng).unwrap();
        assert!(up.bytes < 4 * theta.len() / 4);
        // decoded model only contains codebook values
        let cb = cents.active_codebook();
        for w in &up.theta {
            assert!(cb.iter().any(|c| c == w));
        }
        // not compressing yet (warmup) -> dense
        let d0 = encode_download(Strategy::FedCompress, false, &theta, &cents).unwrap();
        assert_eq!(d0.bytes, 4 * theta.len());
        let d1 = encode_download(Strategy::FedCompress, true, &up.theta, &cents).unwrap();
        assert!(d1.bytes < 4 * theta.len() / 4);
        // already-snapped model encodes losslessly
        assert_eq!(d1.theta, up.theta);
    }

    #[test]
    fn noscs_stays_dense_on_the_wire() {
        let (cfg, theta, cents, mut rng) = setup();
        let up = encode_upload(Strategy::FedCompressNoScs, &cfg, &theta, &cents, true, &mut rng)
            .unwrap();
        assert_eq!(up.bytes, 4 * theta.len());
        let down = encode_download(Strategy::FedCompressNoScs, true, &theta, &cents).unwrap();
        assert_eq!(down.bytes, 4 * theta.len());
    }

    #[test]
    fn fedzip_prunes_to_sparse_quantized() {
        let (cfg, theta, cents, mut rng) = setup();
        let up = encode_upload(Strategy::FedZip, &cfg, &theta, &cents, true, &mut rng).unwrap();
        // the zero cluster exists and dominates at keep=0.6
        let zeros = up.theta.iter().filter(|w| w.abs() < 1e-3).count();
        assert!(zeros as f64 > 0.3 * theta.len() as f64, "{zeros}");
    }
}
