//! Wire-blob building blocks shared by the strategy plugins.
//!
//! A `WireBlob` is what actually crossed the (simulated) network in one
//! direction: the exact byte count plus the model the receiver
//! reconstructs — quantization is part of the transport, so sender and
//! receiver agree on the decoded weights. The helpers here are pure
//! codec policy; *which* helper a strategy uses per direction/round
//! lives in the plugin implementations (`baselines::fedavg` etc.), not
//! in any central `match`.
//!
//! * [`WireBlob::dense`]    — raw f32 both ways (FedAvg, warmup rounds,
//!   every compressed strategy's dense direction).
//! * [`kmeans_blob`]        — magnitude prune -> per-upload k-means ->
//!   Huffman/flat codec (FedZip upstream, Malekijoo 2021).
//! * [`codebook_blob`]      — hard-snap to a learned centroid table +
//!   codebook codec (FedCompress both directions once SCS has run).

use std::fmt;

use anyhow::Result;

use crate::clustering::CentroidState;
use crate::compression::codec::{dense_bytes, quantize_and_encode};
use crate::compression::kmeans::kmeans_1d;
use crate::compression::sparsify::magnitude_prune;
use crate::util::rng::Rng;

/// Which self-describing payload format a [`WireBlob`] carries — the
/// tag the networked transport (`net`) uses to decode the payload back
/// into the exact `theta` the sender holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireCodec {
    /// Raw little-endian f32s, 4 bytes per parameter.
    Dense,
    /// `compression::codec` format (codebook + packed/Huffman indices).
    Clustered,
    /// `baselines::topk` sparse format (positions + values).
    Sparse,
    /// Not decodable by the built-in transport. In-process runs carry
    /// it fine (the decoded `theta` travels by reference); the TCP
    /// transport rejects it with a typed error.
    Opaque,
}

impl WireCodec {
    pub fn tag(self) -> u8 {
        match self {
            WireCodec::Dense => 0,
            WireCodec::Clustered => 1,
            WireCodec::Sparse => 2,
            WireCodec::Opaque => 3,
        }
    }

    pub fn from_tag(tag: u8) -> Option<WireCodec> {
        Some(match tag {
            0 => WireCodec::Dense,
            1 => WireCodec::Clustered,
            2 => WireCodec::Sparse,
            3 => WireCodec::Opaque,
            _ => return None,
        })
    }
}

/// What crossed the wire: exact byte count plus the model the receiver
/// reconstructs. `payload` is the actual encoded byte stream (what a
/// networked transport puts on the socket) and `codec` tags its format;
/// the invariant `payload.len() == bytes` (checked by
/// [`WireBlob::ensure_payload`]) is what makes the ledger's ideal byte
/// counts honest on a real wire.
pub struct WireBlob {
    pub bytes: usize,
    pub theta: Vec<f32>,
    pub codec: WireCodec,
    pub payload: Vec<u8>,
}

/// Typed decode-invariant violation: the reconstructed model does not
/// match the manifest's parameter count. Returned (never silently
/// tolerated) by [`WireBlob::ensure_param_count`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireSizeMismatch {
    pub expected: usize,
    pub got: usize,
}

impl fmt::Display for WireSizeMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "wire blob param count mismatch: manifest expects {} params, decoded {}",
            self.expected, self.got
        )
    }
}

impl std::error::Error for WireSizeMismatch {}

/// The payload length does not match the claimed wire byte count — the
/// blob would lie to the framed-byte ledger. Typed like
/// [`WireSizeMismatch`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WirePayloadMismatch {
    pub bytes: usize,
    pub payload_len: usize,
}

impl fmt::Display for WirePayloadMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "wire blob payload length mismatch: claims {} wire bytes, payload is {}",
            self.bytes, self.payload_len
        )
    }
}

impl std::error::Error for WirePayloadMismatch {}

/// Serialize a weight vector as raw little-endian f32s (the `Dense`
/// codec payload).
pub fn dense_payload(theta: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * theta.len());
    for w in theta {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

impl WireBlob {
    /// Dense f32 transport: lossless, 4 bytes per parameter.
    pub fn dense(theta: &[f32]) -> WireBlob {
        WireBlob {
            bytes: dense_bytes(theta.len()),
            theta: theta.to_vec(),
            codec: WireCodec::Dense,
            payload: dense_payload(theta),
        }
    }

    /// Check the payload-length invariant the framed ledger and the TCP
    /// transport rely on. `Opaque` blobs are exempt (they never reach a
    /// socket).
    pub fn ensure_payload(&self) -> Result<(), WirePayloadMismatch> {
        if self.codec != WireCodec::Opaque && self.payload.len() != self.bytes {
            return Err(WirePayloadMismatch {
                bytes: self.bytes,
                payload_len: self.payload.len(),
            });
        }
        Ok(())
    }

    /// Check the decoded model against the manifest parameter count.
    /// Debug builds assert; release builds surface the typed error so a
    /// size mismatch can never silently corrupt aggregation.
    pub fn ensure_param_count(&self, expected: usize) -> Result<(), WireSizeMismatch> {
        debug_assert_eq!(
            self.theta.len(),
            expected,
            "wire blob param count mismatch"
        );
        if self.theta.len() != expected {
            return Err(WireSizeMismatch {
                expected,
                got: self.theta.len(),
            });
        }
        Ok(())
    }
}

/// FedZip upstream policy: magnitude prune to `keep`, fit a fresh
/// `clusters`-entry k-means codebook on the pruned vector, encode.
pub fn kmeans_blob(theta: &[f32], clusters: usize, keep: f64, rng: &mut Rng) -> Result<WireBlob> {
    let mut pruned = theta.to_vec();
    magnitude_prune(&mut pruned, keep);
    let (codebook, _, _) = kmeans_1d(&pruned, clusters, 25, rng);
    let (enc, quantized) = quantize_and_encode(&pruned, &codebook);
    Ok(WireBlob {
        bytes: enc.wire_bytes(),
        theta: quantized,
        codec: WireCodec::Clustered,
        payload: enc.bytes,
    })
}

/// FedCompress policy: hard-snap to the active centroid codebook and
/// encode; lossless when the model is already centroid-structured
/// (post-SCS downstream).
pub fn codebook_blob(theta: &[f32], centroids: &CentroidState) -> Result<WireBlob> {
    let codebook = centroids.active_codebook();
    let (enc, quantized) = quantize_and_encode(theta, &codebook);
    if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
        let mse: f64 = theta
            .iter()
            .zip(&quantized)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / theta.len().max(1) as f64;
        let span = codebook.last().unwrap() - codebook.first().unwrap();
        crate::debug!(
            "codebook snap: C={} span={:.4} mse={:.6} cb[0..4]={:?}",
            codebook.len(),
            span,
            mse,
            &codebook[..4.min(codebook.len())]
        );
    }
    Ok(WireBlob {
        bytes: enc.wire_bytes(),
        theta: quantized,
        codec: WireCodec::Clustered,
        payload: enc.bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::codec::dense_bytes;
    use crate::util::rng::Rng;

    fn setup() -> (Vec<f32>, CentroidState, Rng) {
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..5000).map(|_| rng.normal() * 0.2).collect();
        let cents = CentroidState::init_from_weights(&theta, 16, 32, &mut rng);
        (theta, cents, rng)
    }

    #[test]
    fn dense_is_lossless_and_4_bytes_per_param() {
        let (theta, _, _) = setup();
        let blob = WireBlob::dense(&theta);
        assert_eq!(blob.bytes, 4 * theta.len());
        assert_eq!(blob.theta, theta);
        assert!(blob.ensure_param_count(theta.len()).is_ok());
        // the payload is the exact little-endian image of theta
        assert_eq!(blob.codec, WireCodec::Dense);
        assert!(blob.ensure_payload().is_ok());
        let decoded: Vec<f32> = blob
            .payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(decoded, theta);
    }

    /// Every built-in blob helper must satisfy `payload.len() == bytes`
    /// — the invariant that keeps the framed ledger honest.
    #[test]
    fn payload_length_matches_claimed_bytes() {
        let (theta, cents, mut rng) = setup();
        for blob in [
            WireBlob::dense(&theta),
            kmeans_blob(&theta, 15, 0.6, &mut rng).unwrap(),
            codebook_blob(&theta, &cents).unwrap(),
        ] {
            assert!(blob.ensure_payload().is_ok(), "{:?}", blob.codec);
            assert_eq!(blob.payload.len(), blob.bytes);
        }
        // a lying blob is caught with the typed error
        let bad = WireBlob {
            bytes: 10,
            theta: vec![0.0; 4],
            codec: WireCodec::Dense,
            payload: vec![0u8; 16],
        };
        let e = bad.ensure_payload().unwrap_err();
        assert_eq!(e.bytes, 10);
        assert_eq!(e.payload_len, 16);
        assert!(e.to_string().contains("payload length mismatch"));
        // opaque blobs are exempt (in-process only)
        let opaque = WireBlob {
            bytes: 10,
            theta: vec![0.0; 4],
            codec: WireCodec::Opaque,
            payload: Vec::new(),
        };
        assert!(opaque.ensure_payload().is_ok());
    }

    #[test]
    fn kmeans_blob_compresses_and_sparsifies() {
        let (theta, _, mut rng) = setup();
        let blob = kmeans_blob(&theta, 15, 0.6, &mut rng).unwrap();
        assert!(blob.bytes < dense_bytes(theta.len()) / 3, "{}", blob.bytes);
        // the zero cluster exists and dominates at keep=0.6
        let zeros = blob.theta.iter().filter(|w| w.abs() < 1e-3).count();
        assert!(zeros as f64 > 0.3 * theta.len() as f64, "{zeros}");
    }

    #[test]
    fn codebook_blob_snaps_into_codebook_and_is_idempotent() {
        let (theta, cents, _) = setup();
        let blob = codebook_blob(&theta, &cents).unwrap();
        assert!(blob.bytes < dense_bytes(theta.len()) / 4);
        let cb = cents.active_codebook();
        for w in &blob.theta {
            assert!(cb.iter().any(|c| c == w));
        }
        // already-snapped model re-encodes losslessly
        let again = codebook_blob(&blob.theta, &cents).unwrap();
        assert_eq!(again.theta, blob.theta);
    }

    #[test]
    fn param_count_mismatch_is_caught() {
        let blob = WireBlob::dense(&[1.0, 2.0]);
        if cfg!(debug_assertions) {
            // debug builds assert loudly
            let r = std::panic::catch_unwind(|| blob.ensure_param_count(3));
            assert!(r.is_err(), "debug_assert should fire on mismatch");
        } else {
            // release builds surface the typed error
            let e = blob.ensure_param_count(3).unwrap_err();
            assert_eq!(e.expected, 3);
            assert_eq!(e.got, 2);
            assert!(e.to_string().contains("param count mismatch"));
        }
    }
}
