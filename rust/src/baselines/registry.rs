//! Strategy registry: the open end of the plugin API. The CLI/config
//! layer resolves strategy *names* against this table, so adding a
//! baseline is one `StrategyInfo` entry plus a `FedStrategy` impl — no
//! coordinator edits (see ARCHITECTURE.md for a <20-line walkthrough).

use anyhow::{bail, Result};

use super::fedavg::FedAvg;
use super::fedcompress::{FedCompress, FedCompressNoScs};
use super::fedzip::FedZip;
use super::topk::TopK;
use crate::config::FedConfig;
use crate::coordinator::strategy::FedStrategy;
use crate::util::suggest;

/// Constructor: a fresh, single-run strategy instance for a config.
/// Fallible so strategies can resolve their declared codec pipelines
/// (and the `--codec` override) at construction with a typed error.
pub type StrategyCtor = fn(&FedConfig) -> Result<Box<dyn FedStrategy>>;

pub struct StrategyInfo {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// one-line description shown by `--strategy list`
    pub description: &'static str,
    pub ctor: StrategyCtor,
}

pub struct StrategyRegistry {
    entries: Vec<StrategyInfo>,
}

impl StrategyRegistry {
    /// Empty registry (for embedding custom strategy sets).
    pub fn empty() -> StrategyRegistry {
        StrategyRegistry {
            entries: Vec::new(),
        }
    }

    /// The built-in strategies: Table 1's four columns plus `topk`.
    pub fn builtin() -> StrategyRegistry {
        let mut r = StrategyRegistry::empty();
        r.register(StrategyInfo {
            name: "fedavg",
            aliases: &[],
            description: "dense FedAvg baseline (f32 both directions)",
            ctor: |cfg| Ok(Box::new(FedAvg::new(cfg)?)),
        })
        .unwrap();
        r.register(StrategyInfo {
            name: "fedzip",
            aliases: &[],
            description: "magnitude prune + k-means + Huffman uploads, dense downstream",
            ctor: |cfg| Ok(Box::new(FedZip::new(cfg)?)),
        })
        .unwrap();
        r.register(StrategyInfo {
            name: "fedcompress-noscs",
            aliases: &["noscs"],
            description: "weight-clustered training without server self-compression (ablation)",
            ctor: |cfg| Ok(Box::new(FedCompressNoScs::new(cfg)?)),
        })
        .unwrap();
        r.register(StrategyInfo {
            name: "fedcompress",
            aliases: &[],
            description: "adaptive weight clustering + server-side distillation (the paper)",
            ctor: |cfg| Ok(Box::new(FedCompress::new(cfg)?)),
        })
        .unwrap();
        r.register(StrategyInfo {
            name: "topk",
            aliases: &["top-k"],
            description: "top-k magnitude sparsification uploads, dense downstream",
            ctor: |cfg| Ok(Box::new(TopK::new(cfg)?)),
        })
        .unwrap();
        r
    }

    /// Add an entry; fails on a name/alias collision or a name `build`
    /// could never resolve (lookup is lowercase, so names must be too).
    pub fn register(&mut self, info: StrategyInfo) -> Result<()> {
        let mut new_names = vec![info.name];
        new_names.extend_from_slice(info.aliases);
        for n in &new_names {
            if n.is_empty() || n.chars().any(|c| c.is_ascii_uppercase()) {
                bail!("strategy name '{n}' must be non-empty lowercase");
            }
        }
        for e in &self.entries {
            let mut taken = vec![e.name];
            taken.extend_from_slice(e.aliases);
            if let Some(dup) = new_names.iter().find(|n| taken.contains(n)) {
                bail!("strategy name '{dup}' already registered");
            }
        }
        self.entries.push(info);
        Ok(())
    }

    pub fn entries(&self) -> &[StrategyInfo] {
        &self.entries
    }

    /// Canonical names, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// Build a fresh strategy instance by name or alias
    /// (case-insensitive). Unknown names fail with the closest
    /// registered name suggested.
    pub fn build(&self, name: &str, cfg: &FedConfig) -> Result<Box<dyn FedStrategy>> {
        let want = name.to_ascii_lowercase();
        for e in &self.entries {
            if e.name == want || e.aliases.contains(&want.as_str()) {
                return (e.ctor)(cfg);
            }
        }
        let known = self.names().join(", ");
        match self.suggest(&want) {
            Some(s) => {
                bail!("unknown strategy '{name}' — did you mean '{s}'? (registered: {known})")
            }
            None => bail!("unknown strategy '{name}' (registered: {known})"),
        }
    }

    /// Closest registered name/alias by edit distance, if plausibly a
    /// typo (shared `util::suggest` machinery — same behavior as the
    /// codec registry's unknown-name errors).
    pub fn suggest(&self, name: &str) -> Option<&'static str> {
        suggest::closest(
            name,
            self.entries
                .iter()
                .flat_map(|e| std::iter::once(e.name).chain(e.aliases.iter().copied())),
        )
    }

    /// Render the `--strategy list` table.
    pub fn render_list(&self) -> String {
        let mut s = String::from("registered strategies:\n");
        for e in &self.entries {
            let alias = if e.aliases.is_empty() {
                String::new()
            } else {
                format!(" (alias: {})", e.aliases.join(", "))
            };
            s.push_str(&format!("  {:<18} {}{}\n", e.name, e.description, alias));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_name_builds() {
        let reg = StrategyRegistry::builtin();
        let cfg = FedConfig::quick("cifar10");
        for name in reg.names() {
            let s = reg.build(name, &cfg).unwrap();
            assert_eq!(s.name(), name);
        }
    }

    #[test]
    fn aliases_resolve_to_canonical_plugins() {
        let reg = StrategyRegistry::builtin();
        let cfg = FedConfig::quick("cifar10");
        assert_eq!(reg.build("noscs", &cfg).unwrap().name(), "fedcompress-noscs");
        assert_eq!(reg.build("FedAvg", &cfg).unwrap().name(), "fedavg");
        assert_eq!(reg.build("top-k", &cfg).unwrap().name(), "topk");
    }

    #[test]
    fn unknown_name_suggests_closest() {
        let reg = StrategyRegistry::builtin();
        let cfg = FedConfig::quick("cifar10");
        let err = reg.build("fedcompres", &cfg).unwrap_err().to_string();
        assert!(err.contains("did you mean 'fedcompress'"), "{err}");
        let err = reg.build("sgd", &cfg).unwrap_err().to_string();
        assert!(err.contains("unknown strategy"), "{err}");
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut reg = StrategyRegistry::builtin();
        let dup = StrategyInfo {
            name: "fedavg",
            aliases: &[],
            description: "dup",
            ctor: |cfg| Ok(Box::new(FedAvg::new(cfg)?)),
        };
        assert!(reg.register(dup).is_err());
    }

    /// A `--codec` override flows from the config into every built-in
    /// strategy's upload pipeline at construction; a bad spec fails
    /// with the codec registry's suggestion.
    #[test]
    fn codec_override_resolves_or_fails_at_build() {
        let reg = StrategyRegistry::builtin();
        let mut cfg = FedConfig::quick("cifar10");
        cfg.codec = "topk(keep=0.2)|kmeans(c=8,iters=10)|huffman".to_string();
        for name in reg.names() {
            reg.build(name, &cfg)
                .unwrap_or_else(|e| panic!("{name} with --codec override: {e}"));
        }
        cfg.codec = "topk|hufman".to_string();
        let err = reg.build("fedavg", &cfg).unwrap_err().to_string();
        assert!(err.contains("did you mean 'huffman'"), "{err}");
    }

    #[test]
    fn list_mentions_every_name() {
        let reg = StrategyRegistry::builtin();
        let list = reg.render_list();
        for name in reg.names() {
            assert!(list.contains(name), "{name} missing from list");
        }
    }
}
